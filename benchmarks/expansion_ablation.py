"""Ablation (beyond-paper): Theorem IV.1 predicts the decoding error
improves with the spectral expansion lambda at fixed replication d.
Compare vertex-transitive graphs of equal d and n but different lambda:
hypercube (lambda = 2) vs best-of random circulants vs random regular,
plus the d=2 cycle as the degenerate case. The whole cross-graph table
is ONE ``sweep_campaign`` call (schemes of equal machine count share
one straggler draw), and each row carries the leading covariance
spectrum via the block-Lanczos ``covariance_topk`` path -- the
beyond-the-norm view Thm IV.1's variance story motivates."""

from __future__ import annotations

import time
from typing import Dict, List

from repro.core import (CampaignEntry, cycle_graph, graph_assignment,
                        hypercube_graph, random_regular_graph,
                        sweep_campaign)
from repro.core.graphs import lps_like_cayley_expander


def run(p: float = 0.3, trials: int = 300,
        backend: str = "auto") -> List[Dict]:
    """``backend`` selects the batched decoding engine ('numpy'/'jax'/
    'auto'); all graphs run through one campaign pass (a single-point
    grid here; equal-m graphs face identical straggler draws), with
    lambda via the dispatching spectral path (FFT for the
    cycle/circulant, dense for the small rest) and the top-3 covariance
    spectrum from block Lanczos."""
    cases = [
        ("cycle_n64_d2", cycle_graph(64)),
        ("hypercube_d4", hypercube_graph(4)),              # n=16, lam=2
        ("circulant_n16_d4", lps_like_cayley_expander(16, 4, seed=0)),
        ("random_regular_n16_d4", random_regular_graph(16, 4, seed=0)),
        ("random_regular_n64_d4", random_regular_graph(64, 4, seed=0)),
        ("random_regular_n64_d6", random_regular_graph(64, 6, seed=0)),
    ]
    entries = [CampaignEntry(graph_assignment(g, name=name), "optimal",
                             label=name) for name, g in cases]
    camp = sweep_campaign(entries, (p,), trials=trials, backend=backend,
                          cov=False, cov_topk=3)
    rows = []
    for name, g in cases:
        mc = camp[name][0]
        rows.append({"graph": name, "n": g.n, "d": g.replication_factor,
                     "lambda": g.spectral_expansion(), "p": p,
                     "error": mc["mean_error"],
                     "cov_top3": [round(x, 6) for x in mc["cov_topk"]]})
    return rows


def main(fast: bool = False):
    t0 = time.time()
    rows = run(trials=100 if fast else 300)
    for r in rows:
        print(",".join(f"{k}={v:.4g}" if isinstance(v, float) else
                       f"{k}={v}" for k, v in r.items()))
    by = {r["graph"]: r for r in rows}
    # d=2 cycle is far worse than any d=4 graph ...
    assert by["cycle_n64_d2"]["error"] > \
        2 * by["random_regular_n64_d4"]["error"]
    # ... and d=6 beats d=4 at the same n (exponential-in-d decay)
    assert by["random_regular_n64_d6"]["error"] <= \
        by["random_regular_n64_d4"]["error"] + 1e-3
    print(f"# expansion_ablation done in {time.time() - t0:.1f}s")
    return rows


if __name__ == "__main__":
    main()
