"""Adversarial stragglers (Section V / Table I): measured worst-case
error of the expander scheme vs the FRC, against the paper's bounds.

- graph scheme error must respect Cor V.2:
    (1/n)|alpha - 1|^2 <= (2d - lam)/(2d) * p/(1-p)
  and the Remark V.4 lower bound p/2 is approachable by the attack.
- the FRC suffers ~p (whole groups erased).
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import (CampaignEntry, adversarial_mask,
                        expander_assignment, frc_assignment, theory)
from repro.core.sweep import sweep_campaign

P_GRID = (0.05, 0.1, 0.15, 0.2, 0.25, 0.3)


def run(m: int = 6552, d: int = 6, vertex_transitive: bool = True
        ) -> List[Dict]:
    A = expander_assignment(m, d, vertex_transitive=vertex_transitive,
                            seed=0)
    F = frc_assignment(m, d)
    # lambda via the dispatching spectral path: matrix-free Lanczos at
    # the n=2184 LPS scale instead of a dense eigendecomposition.
    lam = A.graph.spectral_expansion()
    # Both schemes' whole attack grids through one campaign: each entry
    # carries its (P, 1, m) adversarial mask stack (Def I.3 attacks are
    # deterministic -- one "trial" per grid point), debias off so rows
    # report the raw worst-case (1/n)|alpha - 1|^2 of the tables.
    camp = sweep_campaign(
        [CampaignEntry(A, "optimal", label="ours", debias=False,
                       masks=np.stack([adversarial_mask(A, p)
                                       for p in P_GRID])[:, None, :]),
         CampaignEntry(F, "optimal", label="frc", debias=False,
                       masks=np.stack([adversarial_mask(F, p)
                                       for p in P_GRID])[:, None, :])],
        P_GRID, trials=1, cov=False)
    rows = []
    for i, p in enumerate(P_GRID):
        rows.append({
            "m": m, "d": d, "p": p, "lambda": lam,
            "ours_adversarial": camp["ours"][i]["mean_error"],
            "frc_adversarial": camp["frc"][i]["mean_error"],
            "cor_v2_bound": theory.adversarial_bound_graph(p, d, lam),
            "graph_lower_bound": theory.adversarial_lower_bound_graph(p),
            "frc_theory": theory.frc_adversarial_error(p),
        })
    return rows


def main(fast: bool = False):
    t0 = time.time()
    rows = run(m=312 if fast else 6552, d=6)
    for r in rows:
        print(",".join(f"{k}={v:.4g}" if isinstance(v, float) else
                       f"{k}={v}" for k, v in r.items()))
    for r in rows:
        # Cor V.2 upper bound must hold for the attacked graph scheme.
        assert r["ours_adversarial"] <= r["cor_v2_bound"] + 1e-9, r
        # the FRC attack should be much worse than ours for these p
        assert r["frc_adversarial"] >= r["ours_adversarial"], r
    print(f"# adversarial done in {time.time() - t0:.1f}s")
    return rows


if __name__ == "__main__":
    main()
