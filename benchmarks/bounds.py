"""Lower-bound validation (Props A.1 / A.3, Table III): Monte-Carlo
decoding errors must respect the paper's information-theoretic bounds,
and the FRC must meet Prop A.3 with equality (it is the optimum)."""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import (expander_assignment, frc_assignment,
                        monte_carlo_error, theory)


def run(m: int = 48, d: int = 4, trials: int = 400) -> List[Dict]:
    A = expander_assignment(m, d, vertex_transitive=False, seed=0)
    F = frc_assignment(m, d)
    rows = []
    for p in (0.1, 0.2, 0.3):
        opt = monte_carlo_error(A, p, trials=trials, method="optimal")
        fix = monte_carlo_error(A, p, trials=trials, method="fixed")
        frc = monte_carlo_error(F, p, trials=trials, method="optimal")
        rows.append({
            "p": p, "d": d, "trials": trials, "n": A.n,
            "ours_optimal": opt["mean_error"],
            "ours_fixed": fix["mean_error"],
            "frc_optimal": frc["mean_error"],
            "bound_any": theory.lower_bound_any_decoding(p, d),
            "bound_fixed": theory.lower_bound_fixed_decoding(p, d),
        })
    return rows


def main(fast: bool = False):
    t0 = time.time()
    rows = run(trials=150 if fast else 400)
    for r in rows:
        print(",".join(f"{k}={v:.4g}" for k, v in r.items()))
    for r in rows:
        slack = 0.85  # Monte-Carlo noise allowance
        # The p^d erasure event is rare; only assert the lower bound
        # when the expected number of observed erasures is resolvable.
        expected_events = r["trials"] * r["n"] * r["bound_any"]
        if expected_events >= 5:
            assert r["ours_optimal"] >= r["bound_any"] * slack, r
            assert abs(r["frc_optimal"] - r["bound_any"]) <= \
                0.5 * r["bound_any"] + 5e-3, r
        assert r["ours_fixed"] >= r["bound_fixed"] * slack, r
    print(f"# bounds done in {time.time() - t0:.1f}s")
    return rows


if __name__ == "__main__":
    main()
