"""Figures 4/5 reproduction: convergence of coded gradient descent on
least squares under random stragglers.

Simulated regime (paper Section VIII-B second regime, scaled so the CPU
run stays in seconds by default): coded GD with {ours+optimal,
ours+fixed, FRC+optimal, expander-of-[6], uncoded ignore-stragglers}.
The uncoded baseline runs d times as many iterations (Remark VIII.1).
Step sizes come from a small grid search, as in the paper.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import (BernoulliStragglers, LeastSquares,
                        adjacency_assignment, expander_assignment,
                        frc_assignment, gcod, precompute_alphas,
                        random_regular_graph, uncoded_assignment,
                        uncoded_gd)


def _grid_best(run_fn, lrs) -> Dict:
    best = None
    for lr in lrs:
        tr = run_fn(lr)
        err = tr.errors[-1]
        if not np.isfinite(err):
            continue
        if best is None or err < best["final_error"]:
            best = {"final_error": err, "lr": lr,
                    "errors": tr.errors}
    return best or {"final_error": float("inf"), "lr": None,
                    "errors": []}


def run(m: int = 312, d: int = 6, N: int = 312, k: int = 40,
        p: float = 0.2, steps: int = 50, noise: float = 1.0,
        seed: int = 0, n_lrs: int = 8) -> List[Dict]:
    # Each scheme has its own block count; the underlying data (same N,
    # k, seed) is identical, only the row partition differs.
    def prob_with(n_blocks):
        return LeastSquares.synthetic(N=N, k=k, noise=noise,
                                      n_blocks=n_blocks, seed=seed)
    prob = prob_with(2 * m // d)       # ours: n = 2m/d
    prob_frc = prob_with(m // d)       # FRC: n = m/d
    lrs = np.geomspace(1e-5, 3e-1, n_lrs)
    model = lambda: BernoulliStragglers(m=m, p=p)
    A_ours = expander_assignment(m, d, vertex_transitive=False, seed=0)
    A_frc = frc_assignment(m, d)

    rows = []

    def add(name, run_fn):
        best = _grid_best(run_fn, lrs)
        rows.append({"scheme": name, "p": p,
                     "final_error": best["final_error"],
                     "lr": best["lr"],
                     "first_error": best["errors"][0]
                     if best["errors"] else float("nan")})

    # The straggler draws only depend on (model, seed), not on lr, so
    # each scheme's mask stream is decoded once by the batched engine
    # and replayed across the whole step-size grid.
    def pre(assignment, method, n_steps=steps):
        return precompute_alphas(assignment, model(), steps=n_steps,
                                 method=method, p=p, seed=seed)

    al_opt = pre(A_ours, "optimal")
    add("ours_optimal", lambda lr: gcod(
        prob, A_ours, model(), steps=steps, lr=lr, method="optimal",
        p=p, seed=seed, alphas=al_opt))
    al_fix = pre(A_ours, "fixed")
    add("ours_fixed", lambda lr: gcod(
        prob, A_ours, model(), steps=steps, lr=lr, method="fixed",
        p=p, seed=seed, alphas=al_fix))
    al_frc = pre(A_frc, "optimal")
    add("frc_optimal", lambda lr: gcod(
        prob_frc, A_frc, model(), steps=steps, lr=lr, method="optimal",
        p=p, seed=seed, alphas=al_frc))
    # expander code of [6]: adjacency assignment on m vertices. The
    # problem must be re-blocked to n=m blocks.
    prob6 = prob_with(m)
    A6 = adjacency_assignment(random_regular_graph(m, d, seed=3),
                              name="expander6")
    al_6 = pre(A6, "fixed")
    add("expander6_fixed", lambda lr: gcod(
        prob6, A6, model(), steps=steps, lr=lr, method="fixed", p=p,
        seed=seed, alphas=al_6))
    # uncoded with d-times more iterations (Remark VIII.1)
    al_unc = pre(uncoded_assignment(m), "fixed", n_steps=d * steps)
    add("uncoded_ignore", lambda lr: uncoded_gd(
        prob6, m, p, steps=d * steps, lr=lr, seed=seed, alphas=al_unc))
    return rows


def main(fast: bool = False):
    t0 = time.time()
    rows = run(m=104 if fast else 312, d=4 if fast else 6,
               N=104 if fast else 312, k=20 if fast else 40,
               steps=30 if fast else 50, n_lrs=5 if fast else 8)
    for r in rows:
        print(",".join(f"{k}={v:.4g}" if isinstance(v, float) else
                       f"{k}={v}" for k, v in r.items()))
    by = {r["scheme"]: r["final_error"] for r in rows}
    # paper claims: optimal < fixed; optimal comparable-or-better than
    # expander-of-[6]; coded beats uncoded.
    assert by["ours_optimal"] <= by["ours_fixed"] * 1.05
    assert by["ours_optimal"] <= by["expander6_fixed"] * 1.05
    print(f"# convergence done in {time.time() - t0:.1f}s")
    return rows


if __name__ == "__main__":
    main()
