"""Coded-vs-uncoded train-step benchmark on the 8-virtual-device mesh.

Measures, for the real ``repro.dist`` runtime (smoke config, (4, 2)
mesh of virtual CPU devices):

* per-step wall time (median over timed steps, compile excluded),
* unique tokens/s (global batch x seq len / step time -- replicated
  coded compute is overhead, not throughput),
* host-side decode latency: per-step ``CodingRuntime.step_weights``
  (sample + cached O(m) optimal decode) and the batched
  ``decode_batch`` path, in microseconds.

Nine rows: the replicated coded step (GSPMD combine), the
deduplicated coded step (each unique block once, weighted by
v = A @ w -- the path that closes the replication-factor gap), the
manual ``coded_allreduce`` collective, the uncoded baseline, the
compression-composed dedup steps (int8 / sign / packed 1-bit sign
through the fused quantized combine, with measured
comm-bytes-per-step columns), and the streaming-vs-materialising
manual pair at m = 8 machines (two per worker shard, so the
``lax.scan`` streaming accumulator genuinely halves the live
per-chunk gradients). Every row carries a ``memory`` column: the
compiled step's XLA ``memory_analysis`` (argument/output/temp/program
bytes) plus the peak host-visible live-buffer bytes sampled across
the timed steps. Inline acceptance pins the dedup step strictly under
the replicated one and the streaming step's temp bytes strictly under
the materialising manual's; the comm-bytes acceptances (int8 <= 0.3x,
sign_packed <= 0.05x float32) live in
``roofline_report.comm_report``.

The measurement loop runs in a subprocess because the virtual-device
count must land in XLA_FLAGS before jax initialises; ``main`` (the
``benchmarks.run`` entry) spawns it and returns the parsed report,
which run.py writes to BENCH_train.json.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time

N_DEVICES = 8


def _measure_one(scheme: str, decoding: str, *, steps: int,
                 seq_len: int, block_size: int, path: str = "replicated",
                 collective: str = "gspmd",
                 compress: str = "none",
                 machines: int = 0,
                 stream_chunk: int = 0) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import CodingConfig, get_config
    from repro.core import compress as compress_mod
    from repro.data.pipeline import CodedBatcher, SyntheticLM
    from repro.dist import coded_train, sharding as rules
    from repro.launch.mesh import make_test_mesh
    from repro.models import model as M
    from repro.optim import optimizers as opt_mod

    dedup = path == "dedup"
    codec = (None if compress == "none"
             else compress_mod.get_codec(compress))
    cfg = get_config("qwen1.5-4b").smoke_variant()
    mesh = make_test_mesh((N_DEVICES // 2, 2))
    # ``machines`` > the data-axis size gives each worker shard a
    # block of several machines -- the regime where the streaming
    # accumulator holds fewer live gradients than the materialised
    # manual combine.
    m_workers = machines or mesh.shape["data"]
    coding = CodingConfig(scheme=scheme, replication=2, decoding=decoding,
                          straggler_p=0.2, seed=0)
    runtime = coded_train.CodingRuntime(coding, m_workers)
    assignment = runtime.assignment
    global_batch = assignment.n * block_size
    source = SyntheticLM(cfg.vocab_size, seq_len, seed=0)
    batcher = CodedBatcher(assignment, shuffle_seed=0)
    emit = batcher.unique_blocks if dedup else batcher.code_batch

    params = M.init_params(cfg, jax.random.PRNGKey(0))
    optimizer = opt_mod.get_optimizer("adamw", 1e-3)
    opt_state = optimizer.init(params)
    pshard = rules.named(mesh, rules.safe_param_specs(params, mesh))
    repl = rules.replicated(mesh)

    comp_rows = assignment.n if dedup else m_workers
    comp_state = (compress_mod.init_state(params, comp_rows)
                  if codec else None)
    if collective == "manual":
        train_step = coded_train.make_manual_collective_train_step(
            cfg, optimizer, mesh, compress=compress if codec else None,
            streaming_chunk=stream_chunk or None)
    else:
        train_step = coded_train.make_train_step(
            cfg, optimizer, dedup=dedup,
            norm_scale=coded_train.dedup_norm_scale(assignment),
            compress=compress if codec else None)
    step_times, decode_times = [], []
    with mesh:
        params = jax.device_put(params, pshard)
        # Shapes are static: shardings + jit once, outside the loop
        # (the same hoisting the async driver does).
        batch0 = emit(source.batch(global_batch, 0))
        bshard = (rules.block_shardings if dedup
                  else rules.batch_shardings)(mesh, batch0)
        if codec:
            comp_state = jax.device_put(comp_state, repl)
            step_fn = jax.jit(
                train_step,
                in_shardings=(pshard, None, repl, bshard, repl),
                out_shardings=(pshard, None, repl, None))
        else:
            step_fn = jax.jit(train_step,
                              in_shardings=(pshard, None, bshard, repl),
                              out_shardings=(pshard, None, None))
        # Compiled-program memory accounting: lower the jitted step on
        # abstract stand-ins (no allocation) and read XLA's
        # memory_analysis -- the column the streaming-vs-materialising
        # acceptance compares.
        sds = lambda t: jax.tree.map(  # noqa: E731
            lambda x: jax.ShapeDtypeStruct(jnp.asarray(x).shape,
                                           jnp.asarray(x).dtype), t)
        wv_sds = jax.ShapeDtypeStruct((m_workers,), jnp.float32)
        abstract = ((sds(params), sds(opt_state), sds(comp_state),
                     sds(batch0), wv_sds) if codec else
                    (sds(params), sds(opt_state), sds(batch0), wv_sds))
        mem = step_fn.lower(*abstract).compile().memory_analysis()
        memory = {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "generated_code_bytes": int(
                mem.generated_code_size_in_bytes),
        }
        live_peak = 0
        for step in range(steps):
            batch_np = batch0 if step == 0 else \
                emit(source.batch(global_batch, step))
            batch = {k: jax.device_put(jnp.asarray(v), bshard[k])
                     for k, v in batch_np.items()}
            t0 = time.perf_counter()
            w, _ = runtime.step_weights()
            wv = runtime.block_weights(w) if dedup else w
            decode_times.append(time.perf_counter() - t0)
            wv = jax.device_put(jnp.asarray(wv, jnp.float32), repl)
            t0 = time.perf_counter()
            if codec:
                params, opt_state, comp_state, metrics = step_fn(
                    params, opt_state, comp_state, batch, wv)
            else:
                params, opt_state, metrics = step_fn(params, opt_state,
                                                     batch, wv)
            jax.block_until_ready(metrics["loss"])
            step_times.append(time.perf_counter() - t0)
            # Live-buffer sample: every jax.Array alive after the step
            # (params, opt state, batch, metrics, residuals), peak
            # across steps -- the host-visible companion to the
            # compiled temp-bytes column.
            live_peak = max(live_peak, sum(
                int(x.nbytes) for x in jax.live_arrays()))
    memory["live_bytes_peak"] = live_peak
    warm = step_times[2:] or step_times  # first steps pay compile
    step_s = float(np.median(warm))
    # Batched host decode over one lookahead horizon of fresh masks.
    rng = np.random.default_rng(1)
    masks = rng.random((256, m_workers)) >= 0.2
    t0 = time.perf_counter()
    runtime.decode_batch(masks)
    batched_us = (time.perf_counter() - t0) / masks.shape[0] * 1e6
    # Measured comm payload: the bytes of the arrays the combine
    # actually consumed this run (quantized payload + scale sideband,
    # or full float32 gradients), next to the float32 baseline at the
    # same row count -- the columns the roofline comm report audits.
    comm = compress_mod.comm_bytes_per_step(codec, comp_rows, params)
    comm_f32 = compress_mod.comm_bytes_per_step(None, comp_rows, params)
    return {
        "scheme": scheme,
        "decoding": decoding,
        "path": path,
        "collective": collective,
        "compress": compress,
        "stream_chunk": stream_chunk,
        "memory": memory,
        "comm_bytes_per_step": comm,
        "comm_bytes_per_step_float32": comm_f32,
        "m_workers": m_workers,
        "global_batch": global_batch,
        "seq_len": seq_len,
        "step_ms": round(step_s * 1e3, 2),
        "tokens_per_s": round(global_batch * seq_len / step_s, 1),
        "decode_us_per_step": round(
            float(np.mean(decode_times[1:] or decode_times)) * 1e6, 1),
        "decode_us_per_mask_batched": round(batched_us, 1),
        "decode_calls": runtime.decode_calls,
        "final_loss": float(metrics["loss"]),
    }


def _measure_chaos(steps: int) -> dict:
    """Chaos row: the full elastic-fault-tolerance loop through the
    real train driver, in-process (this worker already owns the 8
    virtual devices). Kills one of the 4 coded machines a third of the
    way in and reports detection latency, steps trained on the
    degraded mask, the elastic re-assignment record, and the final
    loss against the identical no-failure run -- straggler sampling
    off on both sides so injected chaos is the only difference."""
    from repro.launch import train as train_mod

    kill_step = max(2, steps // 3)
    base = ["--arch", "qwen1.5-4b", "--steps", str(steps),
            "--seq-len", "32", "--block-size", "2",
            "--straggler-p", "0",
            "--log-every", str(max(1, steps // 2))]
    clean = train_mod.main(base)
    t0 = time.perf_counter()
    chaotic = train_mod.main(base + ["--chaos", f"kill:1@{kill_step}"])
    wall = time.perf_counter() - t0
    ch = chaotic["chaos"]
    return {
        "spec": f"kill:1@{kill_step}",
        "steps": steps,
        "wall_s": round(wall, 2),
        "steps_to_detect": ch["steps_to_detect"],
        "degraded_steps": ch["degraded_steps"],
        "reassignments": ch["reassignments"],
        "events": ch["events"],
        "m_final": ch["m_final"],
        "generations": ch["generations"],
        "final_loss": chaotic["last_loss"],
        "final_loss_clean": clean["last_loss"],
        "loss_gap": round(chaotic["last_loss"] - clean["last_loss"],
                          4),
    }


def worker(full: bool) -> None:
    steps = 24 if full else 8
    kw = dict(steps=steps, seq_len=64, block_size=4)
    report = {
        "n_virtual_devices": N_DEVICES,
        "steps_timed": steps,
        "runs": [
            _measure_one("expander", "optimal", path="replicated", **kw),
            _measure_one("expander", "optimal", path="dedup", **kw),
            _measure_one("expander", "optimal", path="replicated",
                         collective="manual", **kw),
            _measure_one("uncoded", "fixed", path="replicated", **kw),
            # compression-composed rows: same dedup geometry, int8 /
            # sign / packed 1-bit sign codecs through the fused
            # quantized (or packed-sign) combine
            _measure_one("expander", "optimal", path="dedup",
                         compress="int8", **kw),
            _measure_one("expander", "optimal", path="dedup",
                         compress="sign", **kw),
            _measure_one("expander", "optimal", path="dedup",
                         compress="sign_packed", **kw),
            # streaming-vs-materialising manual pair: m = 8 machines on
            # the 4-shard data axis (two per shard) so the scan-chunked
            # combine holds half the live gradients
            _measure_one("expander", "optimal", path="replicated",
                         collective="manual", machines=8, **kw),
            _measure_one("expander", "optimal", path="replicated",
                         collective="manual", machines=8,
                         stream_chunk=1, **kw),
        ],
        # elastic fault tolerance: kill + detect + re-assign vs the
        # no-failure run, through the real driver
        "chaos": _measure_chaos(steps),
    }
    print("BENCH_TRAIN_JSON:" + json.dumps(report))


def find_run(runs, **want) -> dict:
    return next(r for r in runs
                if all(r.get(k) == v for k, v in want.items()))


def main(fast: bool = True) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={N_DEVICES}")
    cmd = [sys.executable, "-m", "benchmarks.train_step", "--worker"]
    if not fast:
        cmd.append("--full")
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=1800,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    if proc.returncode != 0:
        raise RuntimeError(f"train_step worker failed:\n{proc.stdout}"
                           f"\n{proc.stderr}")
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("BENCH_TRAIN_JSON:")][-1]
    report = json.loads(line.split(":", 1)[1])
    for run in report["runs"]:
        label = f"{run['scheme']}/{run['path']}/{run['collective']}"
        if run.get("compress", "none") != "none":
            label += f"/{run['compress']}"
        if run.get("stream_chunk"):
            label += f"/stream{run['stream_chunk']}"
        mem = run.get("memory", {})
        mb = 1024 ** 2
        print(f"  {label}: {run['step_ms']:.1f} ms/step, "
              f"{run['tokens_per_s']:.0f} tok/s, decode "
              f"{run['decode_us_per_step']:.0f} us/step "
              f"(batched {run['decode_us_per_mask_batched']:.0f} us/mask)"
              f", temp {mem.get('temp_bytes', 0) / mb:.0f}MB "
              f"live {mem.get('live_bytes_peak', 0) / mb:.0f}MB")
    runs = report["runs"]
    repl = find_run(runs, scheme="expander", path="replicated",
                    collective="gspmd", compress="none")
    dedup = find_run(runs, scheme="expander", path="dedup",
                     compress="none")
    uncoded = find_run(runs, scheme="uncoded")
    # Acceptance: deduplication must beat recomputing every block d
    # times; host decode must stay off the step critical path.
    assert dedup["step_ms"] < repl["step_ms"], \
        (f"dedup step ({dedup['step_ms']} ms) must beat the replicated "
         f"coded step ({repl['step_ms']} ms)")
    assert repl["decode_us_per_step"] < 0.2 * repl["step_ms"] * 1e3, \
        "host decode must stay off the step critical path"
    # Memory acceptance: the scan-chunked streaming combine must hold
    # strictly fewer compiled temp bytes (the per-machine gradient
    # working set) than the materialising manual step at the same
    # m = 8 geometry.
    manual8 = find_run(runs, collective="manual", m_workers=8,
                       stream_chunk=0)
    stream8 = find_run(runs, collective="manual", m_workers=8,
                       stream_chunk=1)
    assert stream8["memory"]["temp_bytes"] < \
        manual8["memory"]["temp_bytes"], \
        (f"streaming temp bytes ({stream8['memory']['temp_bytes']}) "
         f"must undercut the materialising manual step "
         f"({manual8['memory']['temp_bytes']})")
    print(f"  streaming/materialising temp bytes: "
          f"{stream8['memory']['temp_bytes'] / manual8['memory']['temp_bytes']:.2f}x")
    print(f"  dedup/uncoded step ratio: "
          f"{dedup['step_ms'] / uncoded['step_ms']:.2f}x "
          f"(replicated was {repl['step_ms'] / uncoded['step_ms']:.2f}x)")
    # Chaos acceptance: the kill must be detected and re-assigned
    # exactly once, and the post-failure run must land at the clean
    # run's noise floor (the paper's convergence-under-stragglers
    # claim, under real detection instead of sampled masks).
    chaos = report["chaos"]
    assert len(chaos["reassignments"]) == 1, \
        f"expected one elastic re-assignment, got {chaos}"
    assert chaos["m_final"] == 3 and chaos["generations"] == 2
    assert all(v <= 4 for v in chaos["steps_to_detect"].values()), \
        f"detection latency too high: {chaos['steps_to_detect']}"
    assert abs(chaos["loss_gap"]) < 0.6, \
        (f"chaos run ended {chaos['loss_gap']} off the clean run "
         f"({chaos['final_loss']} vs {chaos['final_loss_clean']})")
    print(f"  chaos {chaos['spec']}: detect "
          f"{chaos['steps_to_detect']} steps, degraded "
          f"{chaos['degraded_steps']}, final loss "
          f"{chaos['final_loss']:.3f} vs clean "
          f"{chaos['final_loss_clean']:.3f}")
    return report


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.worker:
        worker(args.full)
    else:
        print(json.dumps(main(fast=not args.full), indent=2))
