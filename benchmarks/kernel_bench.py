"""Kernel micro-benchmarks: wall time of the pure-jnp reference path on
CPU (the Pallas kernels are TPU-targeted; interpret-mode timing is a
Python emulation and not meaningful, so it is validated for
correctness in tests and only counted here), plus derived bandwidth.
Prints ``name,us_per_call,derived`` CSV rows per the harness contract.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.batched_decoding import batched_optimal_alpha_graph
from repro.core.graphs import random_regular_graph
from repro.kernels.batched_alpha import ref as ba_ref
from repro.kernels.coded_combine import ref as cc_ref
from repro.kernels.decode_attention import ref as da_ref
from repro.kernels.rmsnorm import ref as rn_ref
from repro.kernels.spectral_matvec import ref as sm_ref


def _time(fn, *args, reps=20):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def batched_alpha_rows(fast: bool = False):
    """Rows for the batched decoding subsystem: the fused error
    reduction oracle and end-to-end engine throughput per backend."""
    rng = np.random.default_rng(1)
    rows = []

    trials, n = (512, 1024) if fast else (2048, 2048)
    a = rng.normal(loc=1.0, scale=0.1, size=(trials, n))
    us = _time(ba_ref.fused_error, a, 1.01, reps=10)
    gb = a.size * 8 / 1e9
    rows.append(("batched_alpha_fused_error_ref", us,
                 f"{gb / (us / 1e6):.1f}GB/s"))

    g = random_regular_graph(256, 4, seed=0)  # m=512 machines
    t_b = 256 if fast else 1024
    masks = rng.random((t_b, g.m)) >= 0.2
    for backend in ("numpy", "jax"):
        fn = lambda m_: batched_optimal_alpha_graph(g, m_, backend=backend)
        us = _time(fn, masks, reps=3)
        rows.append((f"batched_alpha_engine_{backend}", us,
                     f"{t_b / (us / 1e6):.0f}trials/s"))
    return rows


def main(fast: bool = False):
    rng = np.random.default_rng(0)
    rows = []

    rows_n = 2048 if fast else 8192
    x = jnp.asarray(rng.normal(size=(rows_n, 1024)), jnp.float32)
    s = jnp.asarray(rng.normal(size=1024), jnp.float32)
    f = jax.jit(rn_ref.rmsnorm)
    us = _time(f, x, s)
    gb = 2 * x.size * 4 / 1e9
    rows.append(("rmsnorm_ref", us, f"{gb / (us / 1e6):.1f}GB/s"))

    B, H, KVH, S, Dh = 4, 16, 4, (2048 if fast else 8192), 128
    q = jnp.asarray(rng.normal(size=(B, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KVH, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KVH, Dh)), jnp.float32)
    lengths = jnp.full((B,), S, jnp.int32)
    f = jax.jit(da_ref.decode_attention)
    us = _time(f, q, k, v, lengths)
    gb = 2 * k.size * 4 / 1e9
    rows.append(("decode_attention_ref", us, f"{gb / (us / 1e6):.1f}GB/s"))

    nb, D = 16, (1 << 20 if fast else 1 << 22)
    g = jnp.asarray(rng.normal(size=(nb, D)), jnp.float32)
    w = jnp.asarray(rng.normal(size=nb), jnp.float32)
    f = jax.jit(cc_ref.coded_combine)
    us = _time(f, g, w)
    gb = g.size * 4 / 1e9
    rows.append(("coded_combine_ref", us, f"{gb / (us / 1e6):.1f}GB/s"))

    # Matrix-free spectral pipeline: tall-skinny Gram matvec oracle at
    # the transposed LPS covariance orientation (n=2184 rows, 30 cols).
    R, k = (2184, 30) if fast else (8736, 64)
    x = rng.normal(size=(R, k))
    v = rng.normal(size=k)
    us = _time(sm_ref.gram_matvec, x, v, reps=50)
    gb = 2 * x.size * 8 / 1e9  # x streamed twice per matvec
    rows.append(("spectral_matvec_gram_ref", us,
                 f"{gb / (us / 1e6):.1f}GB/s"))

    # Lockstep/batched form (the campaign's blocked-Lanczos matvec):
    # all B slices per call, at the regime-2 campaign stack size.
    B = 12
    xb = rng.normal(size=(B, R, k))
    vb = rng.normal(size=(B, k))
    us_b = _time(sm_ref.gram_matvec_batch, xb, vb, reps=20)
    gb_b = 2 * xb.size * 8 / 1e9
    rows.append(("spectral_matvec_gram_batch_ref", us_b,
                 f"{gb_b / (us_b / 1e6):.1f}GB/s"))

    rows.extend(batched_alpha_rows(fast=fast))

    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows


if __name__ == "__main__":
    main()
