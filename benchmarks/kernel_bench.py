"""Declarative kernel bench-and-tolerance registry.

One ``KernelSpec`` per kernel: name -> (timed op, oracle, shape maker,
rtol). ``run_specs`` times the op (wall time of the pure-jnp reference
path on CPU -- the Pallas kernels are TPU-targeted; interpret-mode
timing is a Python emulation and not meaningful, so kernels are
validated for correctness in tests and only *counted* here), checks it
against its oracle at the registered tolerance, and emits the
``name,us_per_call,derived`` CSV rows per the harness contract. New
kernels -- e.g. serve-path decode shapes -- get bench rows and oracle
checks by appending a spec, not by copy-pasting a timing block.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.batched_decoding import batched_optimal_alpha_graph
from repro.core.graphs import random_regular_graph
from repro.kernels.batched_alpha import ref as ba_ref
from repro.kernels.coded_combine import ref as cc_ref
from repro.kernels.decode_attention import ops as da_ops, ref as da_ref
from repro.kernels.rmsnorm import ref as rn_ref
from repro.kernels.spectral_matvec import ref as sm_ref


def _time(fn, *args, reps=20):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def _gbps(nbytes: int):
    """derived-column formatter: effective bandwidth from bytes moved."""
    return lambda us: f"{nbytes / 1e9 / (us / 1e6):.1f}GB/s"


def _rate(count: int, unit: str):
    return lambda us: f"{count / (us / 1e6):.0f}{unit}"


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """One registered kernel benchmark.

    ``make(fast)`` builds the argument tuple and the derived-column
    formatter; ``op`` is timed; ``oracle`` (optional) is evaluated once
    on the same arguments and compared at ``rtol`` -- the registration
    IS the tolerance contract.
    """
    name: str
    make: Callable[[bool], Tuple[tuple, Callable[[float], str]]]
    op: Callable
    oracle: Optional[Callable] = None
    rtol: float = 1e-5
    atol: float = 1e-6
    reps: int = 20


def _mk_rmsnorm(fast: bool):
    rng = np.random.default_rng(0)
    rows = 2048 if fast else 8192
    x = jnp.asarray(rng.normal(size=(rows, 1024)), jnp.float32)
    s = jnp.asarray(rng.normal(size=1024), jnp.float32)
    return (x, s), _gbps(2 * x.size * 4)


def _mk_decode_attention(fast: bool, *, B=4, H=16, KVH=4, S=None,
                         Dh=128, seed=0):
    rng = np.random.default_rng(seed)
    S = S if S is not None else (2048 if fast else 8192)
    q = jnp.asarray(rng.normal(size=(B, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KVH, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KVH, Dh)), jnp.float32)
    lengths = jnp.full((B,), S, jnp.int32)
    return (q, k, v, lengths), _gbps(2 * k.size * 4)


def _mk_decode_attention_pool(fast: bool):
    # The serving pool's shape regime: n_slots rows, ragged fill (each
    # request at a different position), short-ish caches.
    args, _ = _mk_decode_attention(fast, B=16, H=16, KVH=4,
                                   S=(512 if fast else 2048), Dh=128,
                                   seed=1)
    q, k, v, _ = args
    lengths = jnp.asarray(
        np.random.default_rng(1).integers(1, k.shape[1] + 1, k.shape[0]),
        jnp.int32)
    return (q, k, v, lengths), _gbps(2 * k.size * 4)


def _mk_coded_combine(fast: bool):
    rng = np.random.default_rng(0)
    nb, D = 16, (1 << 20 if fast else 1 << 22)
    g = jnp.asarray(rng.normal(size=(nb, D)), jnp.float32)
    w = jnp.asarray(rng.normal(size=nb), jnp.float32)
    return (g, w), _gbps(g.size * 4)


def _mk_quantized_combine(nb: int, codec: str):
    """Quantized-combine bench inputs: int8 payload (full int8 grid or
    sign's {-1, 0, 1}) + per-row scales + decode weights with
    straggler zeros. The derived column reports effective bandwidth
    over the *compressed* bytes actually streamed -- 1 byte/component
    vs the float32 combine's 4."""
    def make(fast: bool):
        rng = np.random.default_rng(0)
        D = 1 << 20 if fast else 1 << 22
        if codec == "sign":
            payload = np.sign(rng.normal(size=(nb, D)))
        else:
            payload = rng.integers(-127, 128, size=(nb, D))
        q = jnp.asarray(payload, jnp.int8)
        s = jnp.asarray(rng.uniform(0.5, 1.5, size=nb), jnp.float32)
        w = rng.normal(size=nb).astype(np.float32)
        w[rng.random(nb) < 0.2] = 0.0  # decoded straggler weights
        return (q, s, jnp.asarray(w)), _gbps(q.size)
    return make


@jax.jit
def _dequantized_combine_oracle(q, s, w):
    """Materialise the float32 gradients and run the plain combine --
    exactly the allocation the fused path exists to avoid."""
    return cc_ref.coded_combine(q.astype(jnp.float32) * s[:, None], w)


def _mk_packed_sign_combine(nb: int):
    """Packed 1-bit sign combine bench inputs: (nb, d/8) uint8
    bit-planes + per-row scales + decode weights with straggler zeros.
    The derived column reports effective bandwidth over the *packed*
    bytes streamed -- 1 bit/component vs the float32 combine's 32."""
    def make(fast: bool):
        rng = np.random.default_rng(0)
        D = 1 << 20 if fast else 1 << 22
        q = jnp.asarray(rng.integers(0, 256, size=(nb, D // 8)),
                        jnp.uint8)
        s = jnp.asarray(rng.uniform(0.5, 1.5, size=nb), jnp.float32)
        w = rng.normal(size=nb).astype(np.float32)
        w[rng.random(nb) < 0.2] = 0.0  # decoded straggler weights
        return (q, s, jnp.asarray(w), D), _gbps(q.size)
    return make


def _mk_gram(fast: bool):
    # Tall-skinny Gram matvec oracle at the transposed LPS covariance
    # orientation (x streamed twice per matvec).
    rng = np.random.default_rng(0)
    R, k = (2184, 30) if fast else (8736, 64)
    x = rng.normal(size=(R, k))
    v = rng.normal(size=k)
    return (x, v), _gbps(2 * x.size * 8)


def _mk_gram_batch(fast: bool):
    # Lockstep/batched form (the campaign's blocked-Lanczos matvec) at
    # the regime-2 campaign stack size.
    rng = np.random.default_rng(0)
    R, k = (2184, 30) if fast else (8736, 64)
    B = 12
    xb = rng.normal(size=(B, R, k))
    vb = rng.normal(size=(B, k))
    return (xb, vb), _gbps(2 * xb.size * 8)


def _mk_fused_error(fast: bool):
    rng = np.random.default_rng(1)
    trials, n = (512, 1024) if fast else (2048, 2048)
    a = rng.normal(loc=1.0, scale=0.1, size=(trials, n))
    return (a, 1.01), _gbps(a.size * 8)


def _alpha_engine(backend):
    g = random_regular_graph(256, 4, seed=0)  # m=512 machines
    return lambda masks: batched_optimal_alpha_graph(
        g, masks, backend=backend)


def _mk_alpha_engine(fast: bool):
    rng = np.random.default_rng(1)
    t_b = 256 if fast else 1024
    masks = rng.random((t_b, 512)) >= 0.2
    return (masks,), _rate(t_b, "trials/s")


REGISTRY: List[KernelSpec] = [
    KernelSpec("rmsnorm_ref", _mk_rmsnorm, jax.jit(rn_ref.rmsnorm)),
    KernelSpec("decode_attention_ref", _mk_decode_attention,
               jax.jit(da_ref.decode_attention),
               oracle=da_ops.decode_attention, rtol=1e-5),
    KernelSpec("decode_attention_serve_pool", _mk_decode_attention_pool,
               jax.jit(da_ref.decode_attention),
               oracle=da_ops.decode_attention, rtol=1e-5),
    KernelSpec("coded_combine_ref", _mk_coded_combine,
               jax.jit(cc_ref.coded_combine)),
    # Compression-composed combine: replicated (nb = m = 16) and dedup
    # (nb = n = 32) row counts, int8 and sign payloads, each checked
    # against the dequantize-then-combine float32 oracle. The chain
    # and the einsum differ only by float32 accumulation order, hence
    # the scaled-atol style tolerance.
    KernelSpec("quantized_combine_int8_ref",
               _mk_quantized_combine(16, "int8"),
               jax.jit(cc_ref.quantized_combine),
               oracle=_dequantized_combine_oracle, rtol=2e-5, atol=1e-3,
               reps=10),
    KernelSpec("quantized_combine_sign_ref",
               _mk_quantized_combine(16, "sign"),
               jax.jit(cc_ref.quantized_combine),
               oracle=_dequantized_combine_oracle, rtol=2e-5, atol=1e-3,
               reps=10),
    KernelSpec("quantized_combine_int8_dedup_ref",
               _mk_quantized_combine(32, "int8"),
               jax.jit(cc_ref.quantized_combine),
               oracle=_dequantized_combine_oracle, rtol=2e-5, atol=1e-3,
               reps=10),
    KernelSpec("quantized_combine_sign_dedup_ref",
               _mk_quantized_combine(32, "sign"),
               jax.jit(cc_ref.quantized_combine),
               oracle=_dequantized_combine_oracle, rtol=2e-5, atol=1e-3,
               reps=10),
    # Packed 1-bit sign combine at the same replicated/dedup row
    # counts, checked against the float64 unpack-then-combine oracle
    # (np.unpackbits decode -- an independent reading of the bit
    # convention).
    KernelSpec("packed_sign_combine_ref",
               _mk_packed_sign_combine(16),
               jax.jit(cc_ref.packed_sign_combine, static_argnums=3),
               oracle=cc_ref.packed_sign_combine_np, rtol=2e-5,
               atol=1e-3, reps=10),
    KernelSpec("packed_sign_combine_dedup_ref",
               _mk_packed_sign_combine(32),
               jax.jit(cc_ref.packed_sign_combine, static_argnums=3),
               oracle=cc_ref.packed_sign_combine_np, rtol=2e-5,
               atol=1e-3, reps=10),
    KernelSpec("spectral_matvec_gram_ref", _mk_gram, sm_ref.gram_matvec,
               reps=50),
    KernelSpec("spectral_matvec_gram_batch_ref", _mk_gram_batch,
               sm_ref.gram_matvec_batch, reps=20),
]

# Batched decoding subsystem: the fused error reduction oracle and
# end-to-end engine throughput per backend. The jax engine's oracle is
# the numpy engine -- a genuine cross-backend check.
BATCHED_ALPHA_REGISTRY: List[KernelSpec] = [
    KernelSpec("batched_alpha_fused_error_ref", _mk_fused_error,
               ba_ref.fused_error,
               oracle=lambda a, s: np.mean((a * s - 1.0) ** 2, axis=1),
               rtol=1e-12, reps=10),
    KernelSpec("batched_alpha_engine_numpy", _mk_alpha_engine,
               _alpha_engine("numpy"), reps=3),
    KernelSpec("batched_alpha_engine_jax", _mk_alpha_engine,
               _alpha_engine("jax"), oracle=_alpha_engine("numpy"),
               rtol=1e-9, reps=3),
]


def run_specs(specs: Sequence[KernelSpec], fast: bool = False):
    """Time + oracle-check each spec; returns (name, us, derived) rows."""
    rows = []
    for spec in specs:
        args, derived = spec.make(fast)
        if spec.oracle is not None:
            got = np.asarray(spec.op(*args))
            want = np.asarray(spec.oracle(*args))
            np.testing.assert_allclose(
                got, want, rtol=spec.rtol, atol=spec.atol,
                err_msg=f"{spec.name}: op diverged from oracle")
        us = _time(spec.op, *args, reps=spec.reps)
        rows.append((spec.name, us, derived(us)))
    return rows


def batched_alpha_rows(fast: bool = False):
    """Rows for the batched decoding subsystem (reused standalone by
    ``benchmarks.run`` for the decoding report)."""
    return run_specs(BATCHED_ALPHA_REGISTRY, fast)


def main(fast: bool = False):
    rows = run_specs(REGISTRY, fast)
    rows.extend(batched_alpha_rows(fast=fast))
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows


if __name__ == "__main__":
    main()
