"""Assemble the #Roofline table from the dry-run JSON artifacts
(experiments/dryrun/*.json): per (arch x shape x mesh), the three
roofline terms, the dominant bottleneck, MODEL_FLOPS/HLO_FLOPs, and a
one-line what-would-move-it-down note."""

from __future__ import annotations

import glob
import json
import os
from typing import List

NOTES = {
    "collective": ("shrink activation reshards: bf16 collectives, "
                   "seq-dim sharding, fewer per-microbatch psums"),
    "memory": ("raise arithmetic intensity: bf16 dots, larger fused "
               "blocks, keep attention tiles VMEM-resident"),
    "compute": ("reduce redundant FLOPs: causal block skip, lower "
                "remat, smaller replication d"),
}


def load(dirpath: str = "experiments/dryrun") -> List[dict]:
    rows = []
    for fn in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(fn) as f:
            rows.append(json.load(f))
    return rows


def table(rows: List[dict]) -> str:
    out = ["| arch | shape | mesh | Tc(ms) | Tm(ms) | Tx(ms) | "
           "bottleneck | useful | note |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        mesh = "2x16x16" if r.get("multi_pod") else "16x16"
        if r.get("status") == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | {mesh} | - | - "
                       f"| - | skipped | - | {r['reason']} |")
            continue
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {mesh} | - | - "
                       f"| - | ERROR | - | {r.get('error', '')[:60]} |")
            continue
        rl = r["roofline"]
        dom = rl["dominant"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {mesh} "
            f"| {rl['t_compute_s'] * 1e3:.1f} "
            f"| {rl['t_memory_s'] * 1e3:.1f} "
            f"| {rl['t_collective_s'] * 1e3:.1f} "
            f"| {dom} | {rl['useful_flops_ratio']:.2f} "
            f"| {NOTES[dom]} |")
    return "\n".join(out)


def main(fast: bool = False):
    rows = load()
    if not rows:
        print("# no dry-run artifacts found (run repro.launch.dryrun)")
        return []
    print(table(rows))
    n_ok = sum(r.get("status") == "ok" for r in rows)
    print(f"# roofline_report: {n_ok} ok / {len(rows)} rows")
    return rows


if __name__ == "__main__":
    main()
