"""Assemble the #Roofline table from the dry-run JSON artifacts
(experiments/dryrun/*.json): per (arch x shape x mesh), the three
roofline terms, the dominant bottleneck, MODEL_FLOPS/HLO_FLOPs, and a
one-line what-would-move-it-down note.

``comm_report`` is the communication-side companion over the measured
train-step report (BENCH_train.json): per-run comm-bytes-per-step
against the float32 baseline at the same row count, with a per-codec
expected-ratio table (uncompressed exactly 1.0x, int8/sign <= 0.3x,
packed 1-bit sign <= 0.05x float32 at replication d = 2) -- the
compression side of the comms-tax story the coded combine carries."""

from __future__ import annotations

import glob
import json
import os
from typing import List

NOTES = {
    "collective": ("shrink activation reshards: bf16 collectives, "
                   "seq-dim sharding, fewer per-microbatch psums"),
    "memory": ("raise arithmetic intensity: bf16 dots, larger fused "
               "blocks, keep attention tiles VMEM-resident"),
    "compute": ("reduce redundant FLOPs: causal block skip, lower "
                "remat, smaller replication d"),
}


def load(dirpath: str = "experiments/dryrun") -> List[dict]:
    rows = []
    for fn in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(fn) as f:
            rows.append(json.load(f))
    return rows


def table(rows: List[dict]) -> str:
    out = ["| arch | shape | mesh | Tc(ms) | Tm(ms) | Tx(ms) | "
           "bottleneck | useful | note |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        mesh = "2x16x16" if r.get("multi_pod") else "16x16"
        if r.get("status") == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | {mesh} | - | - "
                       f"| - | skipped | - | {r['reason']} |")
            continue
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {mesh} | - | - "
                       f"| - | ERROR | - | {r.get('error', '')[:60]} |")
            continue
        rl = r["roofline"]
        dom = rl["dominant"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {mesh} "
            f"| {rl['t_compute_s'] * 1e3:.1f} "
            f"| {rl['t_memory_s'] * 1e3:.1f} "
            f"| {rl['t_collective_s'] * 1e3:.1f} "
            f"| {dom} | {rl['useful_flops_ratio']:.2f} "
            f"| {NOTES[dom]} |")
    return "\n".join(out)


# Per-codec wire-ratio ceilings vs the float32 combine at the same
# row count (replication d = 2). Exact values at smoke scale: int8
# ~0.25 (1 byte/component + a float32 scale per row-leaf pair), sign
# ~0.25 (1 byte/component too -- the unpacked payload), sign_packed
# ~0.031 (1 bit/component packed 8-per-byte). ``None`` means the ratio
# must be exactly 1.0 (uncompressed runs ship the full gradients).
EXPECTED_COMM_RATIO = {
    "none": None,
    "int8": 0.3,
    "sign": 0.3,
    "sign_packed": 0.05,
}

# Codecs every train report must carry a run for -- the compression
# rows the benchmark suite is contracted to measure.
REQUIRED_CODECS = ("int8", "sign_packed")


def comm_report(train_report: dict) -> List[dict]:
    """Comm-bytes table + acceptance over a train_step report.

    Each run row already carries measured ``comm_bytes_per_step`` (the
    payload arrays its combine consumed) and the float32 baseline at
    the same (machine/block) row count. Prints the per-run ratio table
    and enforces the ``EXPECTED_COMM_RATIO`` ceiling for every codec
    present (exactly 1.0x for uncompressed runs), plus the presence of
    the ``REQUIRED_CODECS`` rows.
    """
    runs = [r for r in train_report.get("runs", [])
            if "comm_bytes_per_step" in r]
    if not runs:
        print("# comm_report: no comm-bytes columns in train report")
        return []
    out = []
    print("| scheme | path | compress | comm MB/step | f32 MB/step "
          "| ratio |")
    print("|---|---|---|---|---|---|")
    for r in runs:
        ratio = r["comm_bytes_per_step"] / r["comm_bytes_per_step_float32"]
        codec = r.get("compress", "none")
        out.append({"scheme": r["scheme"], "path": r["path"],
                    "compress": codec,
                    "comm_bytes_per_step": r["comm_bytes_per_step"],
                    "comm_bytes_per_step_float32":
                        r["comm_bytes_per_step_float32"],
                    "ratio": round(ratio, 4)})
        print(f"| {r['scheme']} | {r['path']} "
              f"| {codec} "
              f"| {r['comm_bytes_per_step'] / 1e6:.2f} "
              f"| {r['comm_bytes_per_step_float32'] / 1e6:.2f} "
              f"| {ratio:.3f} |")
        assert codec in EXPECTED_COMM_RATIO, \
            f"no expected comm ratio registered for codec {codec!r}"
        ceiling = EXPECTED_COMM_RATIO[codec]
        if ceiling is None:
            assert ratio == 1.0, "uncompressed runs must ship 1.0x"
        else:
            assert ratio <= ceiling, (
                f"{codec} comm ratio {ratio:.3f} must be <= "
                f"{ceiling}x float32 ({r['scheme']}/{r['path']})")
    for codec in REQUIRED_CODECS:
        assert any(r["compress"] == codec for r in out), \
            f"train report must carry a {codec} compression run"
    ok = ", ".join(f"{c} <= {EXPECTED_COMM_RATIO[c]}x"
                   for c in sorted(set(r["compress"] for r in out))
                   if EXPECTED_COMM_RATIO.get(c) is not None)
    print(f"# comm_report: {len(out)} rows, acceptance ok ({ok})")
    return out


def main(fast: bool = False):
    rows = load()
    if not rows:
        print("# no dry-run artifacts found (run repro.launch.dryrun)")
        return []
    print(table(rows))
    n_ok = sum(r.get("status") == "ok" for r in rows)
    print(f"# roofline_report: {n_ok} ok / {len(rows)} rows")
    return rows


if __name__ == "__main__":
    main()
