"""Assemble the #Roofline table from the dry-run JSON artifacts
(experiments/dryrun/*.json): per (arch x shape x mesh), the three
roofline terms, the dominant bottleneck, MODEL_FLOPS/HLO_FLOPs, and a
one-line what-would-move-it-down note.

``comm_report`` is the communication-side companion over the measured
train-step report (BENCH_train.json): per-run comm-bytes-per-step
against the float32 baseline at the same row count, with the inline
acceptance that the int8 codec cuts the combine's wire payload to
<= 0.3x float32 at replication d = 2 -- the compression side of the
comms-tax story the coded combine carries."""

from __future__ import annotations

import glob
import json
import os
from typing import List

NOTES = {
    "collective": ("shrink activation reshards: bf16 collectives, "
                   "seq-dim sharding, fewer per-microbatch psums"),
    "memory": ("raise arithmetic intensity: bf16 dots, larger fused "
               "blocks, keep attention tiles VMEM-resident"),
    "compute": ("reduce redundant FLOPs: causal block skip, lower "
                "remat, smaller replication d"),
}


def load(dirpath: str = "experiments/dryrun") -> List[dict]:
    rows = []
    for fn in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(fn) as f:
            rows.append(json.load(f))
    return rows


def table(rows: List[dict]) -> str:
    out = ["| arch | shape | mesh | Tc(ms) | Tm(ms) | Tx(ms) | "
           "bottleneck | useful | note |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        mesh = "2x16x16" if r.get("multi_pod") else "16x16"
        if r.get("status") == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | {mesh} | - | - "
                       f"| - | skipped | - | {r['reason']} |")
            continue
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {mesh} | - | - "
                       f"| - | ERROR | - | {r.get('error', '')[:60]} |")
            continue
        rl = r["roofline"]
        dom = rl["dominant"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {mesh} "
            f"| {rl['t_compute_s'] * 1e3:.1f} "
            f"| {rl['t_memory_s'] * 1e3:.1f} "
            f"| {rl['t_collective_s'] * 1e3:.1f} "
            f"| {dom} | {rl['useful_flops_ratio']:.2f} "
            f"| {NOTES[dom]} |")
    return "\n".join(out)


def comm_report(train_report: dict) -> List[dict]:
    """Comm-bytes table + acceptance over a train_step report.

    Each run row already carries measured ``comm_bytes_per_step`` (the
    payload arrays its combine consumed) and the float32 baseline at
    the same (machine/block) row count. Prints the per-run ratio table
    and enforces: every int8 run ships <= 0.3x the float32 bytes
    (at d = 2 the exact ratio is ~0.25: 1 byte/component + one float32
    scale per row-leaf pair, against 4 bytes/component).
    """
    runs = [r for r in train_report.get("runs", [])
            if "comm_bytes_per_step" in r]
    if not runs:
        print("# comm_report: no comm-bytes columns in train report")
        return []
    out = []
    print("| scheme | path | compress | comm MB/step | f32 MB/step "
          "| ratio |")
    print("|---|---|---|---|---|---|")
    for r in runs:
        ratio = r["comm_bytes_per_step"] / r["comm_bytes_per_step_float32"]
        out.append({"scheme": r["scheme"], "path": r["path"],
                    "compress": r.get("compress", "none"),
                    "comm_bytes_per_step": r["comm_bytes_per_step"],
                    "comm_bytes_per_step_float32":
                        r["comm_bytes_per_step_float32"],
                    "ratio": round(ratio, 4)})
        print(f"| {r['scheme']} | {r['path']} "
              f"| {r.get('compress', 'none')} "
              f"| {r['comm_bytes_per_step'] / 1e6:.2f} "
              f"| {r['comm_bytes_per_step_float32'] / 1e6:.2f} "
              f"| {ratio:.3f} |")
        if r.get("compress") == "int8":
            assert ratio <= 0.3, (
                f"int8 comm ratio {ratio:.3f} must be <= 0.3x float32 "
                f"({r['scheme']}/{r['path']})")
        if r.get("compress", "none") == "none":
            assert ratio == 1.0, "uncompressed runs must ship 1.0x"
    assert any(r["compress"] == "int8" for r in out), \
        "train report must carry an int8 compression run"
    print(f"# comm_report: {len(out)} rows, int8 acceptance <= 0.3x ok")
    return out


def main(fast: bool = False):
    rows = load()
    if not rows:
        print("# no dry-run artifacts found (run repro.launch.dryrun)")
        return []
    print(table(rows))
    n_ok = sum(r.get("status") == "ok" for r in rows)
    print(f"# roofline_report: {n_ok} ok / {len(rows)} rows")
    return rows


if __name__ == "__main__":
    main()
