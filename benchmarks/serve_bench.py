"""Coded-serving benchmark: tokens/s + synthetic TTFT tails.

Two halves, both against the real ``repro.serve`` engine on the
8-virtual-device mesh (smoke config):

* **Engine runs** -- drain the same request set through the
  continuous-batching engine three ways: coded prefill (expander d=2)
  under Bernoulli stragglers, coded at p=0, and the uncoded d=1
  baseline. Reports measured tokens/s and per-request synthetic TTFT,
  and runs the differential pins inline: the coded p=0 token streams
  must be bit-identical to the uncoded single-replica streams AND to
  the sequential-batching reference loop.
* **Latency quantiles** -- ``serve.latency.simulate_shard_ttft`` over
  thousands of pre-decoded rounds (``CodingRuntime.weights_lookahead``)
  at m=32 replicas: paired coded/uncoded TTFT samples per straggler
  model, reduced to p50/p99 rows.

Inline acceptance (the paper's claim, in serving clothes): coded p99 <
uncoded p99 under the Bernoulli model at d=2 -- one deadline + rare
retries instead of waiting out the slowest device -- with p50 within
the jitter of the single-replica latency. The subprocess exists
because the virtual-device count must land in XLA_FLAGS before jax
initialises; ``main`` (the ``benchmarks.run`` entry) spawns it and
returns the report run.py writes to BENCH_serve.json.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

N_DEVICES = 8
M_REPLICAS = 32


def _engine_run(cfg, params, mesh, requests, *, scheme: str, p: float,
                slots: int, max_len: int) -> dict:
    from repro.configs import CodingConfig
    from repro import serve as S

    coding = CodingConfig(scheme=scheme, replication=2,
                          straggler_model="bernoulli", straggler_p=p,
                          seed=0)
    eng = S.ServeEngine(cfg, params, n_slots=slots, max_len=max_len,
                        mesh=mesh, coding=coding, m_replicas=8,
                        log_every=8)
    for r in requests:
        eng.submit(r)
    summary = eng.run()
    summary.update(scheme=scheme, straggler_p=p)
    return {"summary": summary, "results": eng.results()}


def worker(full: bool) -> None:
    import numpy as np

    from repro.configs import CodingConfig, get_config
    from repro.dist import coded_train
    from repro.launch.mesh import make_test_mesh
    from repro.models import model as M
    from repro import serve as S

    import jax

    # --- engine half: real device runs -------------------------------
    cfg = get_config("qwen1.5-4b").smoke_variant()
    mesh = make_test_mesh((N_DEVICES // 2, 2))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    n_req = 24 if full else 12
    slots, max_len, new_tokens = 8, 48, 8
    rng = np.random.default_rng(0)
    requests = [S.Request(uid=i,
                          prompt=rng.integers(0, cfg.vocab_size,
                                              12 - (i % 4)),
                          max_new_tokens=new_tokens)
                for i in range(n_req)]

    runs = {
        "coded": _engine_run(cfg, params, mesh, requests,
                             scheme="expander", p=0.2,
                             slots=slots, max_len=max_len),
        "coded_p0": _engine_run(cfg, params, mesh, requests,
                                scheme="expander", p=0.0,
                                slots=slots, max_len=max_len),
        "uncoded": _engine_run(cfg, params, mesh, requests,
                               scheme="uncoded", p=0.0,
                               slots=slots, max_len=max_len),
    }
    ref = S.sequential_serve(params, cfg, requests, n_slots=slots,
                             max_len=max_len)
    stream_ok = all(
        np.array_equal(runs["coded_p0"]["results"][r.uid],
                       runs["uncoded"]["results"][r.uid])
        and np.array_equal(runs["coded_p0"]["results"][r.uid],
                           ref[r.uid])
        for r in requests)

    # --- latency half: paired TTFT quantiles over many rounds --------
    rounds = 20000 if full else 6000
    lat_model = S.ReplicaLatencyModel(m=M_REPLICAS)
    lat_rows = []
    coded_p99 = uncoded_p99 = None
    for model, p in (("bernoulli", 0.2), ("markov", 0.2)):
        coding = CodingConfig(scheme="expander", replication=2,
                              straggler_model=model, straggler_p=p,
                              seed=1)
        rt = coded_train.CodingRuntime(coding, M_REPLICAS, debias=False)
        W, alive = rt.weights_lookahead(rounds)
        lat_rng = np.random.default_rng(2)
        lat = np.stack([lat_model.latencies(a, lat_rng) for a in alive])
        coded, uncoded = S.simulate_shard_ttft(
            rt.assignment, W, alive, lat,
            deadline_ms=lat_model.deadline_ms,
            straggle_ms=lat_model.straggle_ms)
        c_row = S.percentile_row("expander_d2", model, p, coded)
        u_row = S.percentile_row("uncoded", model, p, uncoded)
        lat_rows += [c_row, u_row]
        if model == "bernoulli":
            coded_p99, uncoded_p99 = c_row["p99_ms"], u_row["p99_ms"]

    report = {
        "n_virtual_devices": N_DEVICES,
        "m_replicas_sim": M_REPLICAS,
        "rounds_sim": rounds,
        "requests": n_req,
        "engine": {k: v["summary"] for k, v in runs.items()},
        "latency_rows": lat_rows,
        "acceptance": {
            "token_stream_bit_identical_at_p0": bool(stream_ok),
            "coded_p99_ms": coded_p99,
            "uncoded_p99_ms": uncoded_p99,
            "coded_p99_lt_uncoded": bool(coded_p99 < uncoded_p99),
        },
    }
    print("BENCH_SERVE_JSON:" + json.dumps(report))


def main(fast: bool = True) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={N_DEVICES}")
    cmd = [sys.executable, "-m", "benchmarks.serve_bench", "--worker"]
    if not fast:
        cmd.append("--full")
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=1800,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    if proc.returncode != 0:
        raise RuntimeError(f"serve_bench worker failed:\n{proc.stdout}"
                           f"\n{proc.stderr}")
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("BENCH_SERVE_JSON:")][-1]
    report = json.loads(line.split(":", 1)[1])
    for name, s in report["engine"].items():
        ttft = (f", TTFT p50 {s['ttft_p50_ms']:.1f} ms "
                f"p99 {s['ttft_p99_ms']:.1f} ms"
                if "ttft_p50_ms" in s else "")
        print(f"  engine[{name}]: {s['tokens_per_s']:.1f} tok/s over "
              f"{s['requests']} reqs, {s['retries']} retries{ttft}")
    for row in report["latency_rows"]:
        print(f"  sim[{row['scheme']}/{row['straggler_model']} "
              f"p={row['p']}]: p50 {row['p50_ms']:.2f} ms, "
              f"p99 {row['p99_ms']:.2f} ms")
    acc = report["acceptance"]
    # Acceptance: scheduling/coding must never change the tokens, and
    # d=2 replication must bound the tail below the slowest device.
    assert acc["token_stream_bit_identical_at_p0"], \
        "coded p=0 streams diverged from the single-replica oracle"
    assert acc["coded_p99_lt_uncoded"], \
        (f"coded p99 {acc['coded_p99_ms']} ms must beat uncoded "
         f"{acc['uncoded_p99_ms']} ms under bernoulli stragglers")
    print(f"  acceptance: bit-identical streams at p=0; coded p99 "
          f"{acc['coded_p99_ms']:.2f} ms < uncoded "
          f"{acc['uncoded_p99_ms']:.2f} ms")
    return report


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.worker:
        worker(args.full)
    else:
        print(json.dumps(main(fast=not args.full), indent=2))
