"""Figure 3 reproduction: decoding error E[|alpha-bar - 1|^2]/n and
covariance norm |Cov(alpha-bar)|_2 vs straggler probability p.

Two regimes, exactly as Section VIII:
  regime 1: m=24 machines, d=3, random 3-regular graph on 16 vertices.
  regime 2: m=6552, d=6, the LPS X^{5,13} Ramanujan graph (2184 vertices).

Schemes: ours+optimal, ours+fixed, expander-of-[6] (adjacency
assignment; optimal decoding at m=24, fixed at m=6552 as in the paper),
and the FRC optimum p^d/(1-p^d) plotted in closed form (the paper does
the same).

Each regime's whole cross-scheme p-grid now runs through ONE
``sweep_campaign`` call (shared uniforms per machine count, stacked
fixed-decode GEMM, warm-started labels, blocked-Lanczos covariance
norms at the LPS scale); per-(scheme, p) values are bit-identical to
per-scheme ``sweep_error`` / per-point ``monte_carlo_error`` calls,
which ``sweep_report`` verifies and times for BENCH_sweep.json.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import (AdaptivePolicy, StaticPolicy,
                        adjacency_assignment, decode, expander_assignment,
                        monte_carlo_error, policy_regret_report,
                        random_regular_graph, scheme_zoo_entries, spectral,
                        sweep_campaign, sweep_error, theory)
from repro.core.compress import compression_campaign
from repro.core.step_weights import (make_straggler_model,
                                     sample_mask_stream)

P_GRID = (0.05, 0.1, 0.15, 0.2, 0.25, 0.3)


def regime1(trials: int = 200, seed: int = 0) -> List[Dict]:
    A = expander_assignment(24, 3, vertex_transitive=False, seed=1)
    adj = adjacency_assignment(random_regular_graph(24, 3, seed=2),
                               name="expander[6]")
    camp = sweep_campaign(
        [(A, "optimal"), (A, "fixed"), (adj, "optimal")], P_GRID,
        trials=trials, seed=seed)
    opt = camp[f"{A.name}:optimal"]
    fix = camp[f"{A.name}:fixed"]
    exp6 = camp["expander[6]:optimal"]
    rows = []
    for i, p in enumerate(P_GRID):
        rows.append({
            "regime": "m24_d3", "p": p,
            "ours_optimal": opt[i]["mean_error"],
            "ours_optimal_cov": opt[i]["cov_norm"],
            "ours_fixed": fix[i]["mean_error"],
            "ours_fixed_cov": fix[i]["cov_norm"],
            "expander6_optimal": exp6[i]["mean_error"],
            "frc_optimal(theory)": theory.frc_random_error(p, 3),
            "lower_bound": theory.lower_bound_any_decoding(p, 3),
            "fixed_lower_bound": theory.lower_bound_fixed_decoding(p, 3),
        })
    return rows


def regime2(trials: int = 30, seed: int = 0) -> List[Dict]:
    A = expander_assignment(6552, 6, vertex_transitive=True, seed=0)
    camp = sweep_campaign([(A, "optimal"), (A, "fixed")], P_GRID,
                          trials=trials, seed=seed)
    opt = camp[f"{A.name}:optimal"]
    fix = camp[f"{A.name}:fixed"]
    rows = []
    for i, p in enumerate(P_GRID):
        rows.append({
            "regime": "m6552_d6_LPS", "p": p,
            "ours_optimal": opt[i]["mean_error"],
            "ours_optimal_cov": opt[i]["cov_norm"],
            "ours_fixed": fix[i]["mean_error"],
            "ours_fixed_cov": fix[i]["cov_norm"],
            "frc_optimal(theory)": theory.frc_random_error(p, 6),
            "lower_bound": theory.lower_bound_any_decoding(p, 6),
            "fixed_lower_bound": theory.lower_bound_fixed_decoding(p, 6),
        })
    return rows


def speed_report(fast: bool = False) -> Dict:
    """Decoder throughput at the paper's m=6552 LPS scale: the historical
    per-trial ``decode`` loop vs the batched engine driving
    ``monte_carlo_error`` (mask sampling + batched decode + fused
    debias/error; the O(n^2) covariance step is off on both sides since
    the seed harness paid it once per call, not per trial).

    Feeds BENCH_decoding.json via ``benchmarks.run`` so the perf
    trajectory of the decoding path is machine-trackable across PRs.
    """
    m, d, p = 6552, 6, 0.1
    scalar_trials = 3 if fast else 10
    batched_trials = 1000
    A = expander_assignment(m, d, vertex_transitive=True, seed=0)

    rng = np.random.default_rng(0)
    masks = rng.random((scalar_trials, m)) >= p
    t0 = time.perf_counter()
    for t in range(scalar_trials):
        decode(A, masks[t], method="optimal")
    scalar_s = time.perf_counter() - t0

    # Warm once at the benchmark shape so the jit compile (paid once per
    # (graph, batch) shape) is not billed to steady-state throughput.
    monte_carlo_error(A, p, trials=batched_trials, method="optimal",
                      cov=False)
    t0 = time.perf_counter()
    monte_carlo_error(A, p, trials=batched_trials, method="optimal",
                      cov=False)
    batched_s = time.perf_counter() - t0

    scalar_tps = scalar_trials / scalar_s
    batched_tps = batched_trials / batched_s
    return {
        "m": m, "d": d, "p": p, "graph": "LPS X^{5,13}",
        "scalar": {"trials": scalar_trials, "seconds": scalar_s,
                   "trials_per_sec": scalar_tps},
        "batched": {"trials": batched_trials, "seconds": batched_s,
                    "trials_per_sec": batched_tps},
        "speedup": batched_tps / scalar_tps,
        "note": ("scalar = per-mask optimal_decode_graph (the seed "
                 "monte_carlo path); batched = full monte_carlo_error "
                 "(sampling + batched decode + fused error), cov off"),
    }


def sweep_report() -> Dict:
    """Grid-seconds + spectral-norm timings for BENCH_sweep.json.

    Deliberately paper-scale in every mode (no ``fast`` knob): the
    report's contract is the regime-2 grid at m=6552, and the whole
    thing is ~25 s dominated by the historical per-point baseline it
    exists to compare against.

    Times the full regime-2 p-grid (6 p-points, cov on, trials=30, the
    paper's m=6552 LPS scheme) two ways: the historical loop of
    ``monte_carlo_error`` per p-point (dense n x n covariance SVD each)
    vs one ``sweep_error`` pass (shared uniforms, warm-started labels,
    matrix-free Lanczos covariance). Verifies the sweep acceptance
    contract inline: mean/std bit-identical to the per-point loop,
    covariance norms within 1e-6 relative of the dense SVD. Also times
    the spectral primitives at the same scale (dense vs matrix-free
    |Cov|_2, per-slice vs blocked lockstep Lanczos; dense vs Lanczos
    lambda_2 of the LPS graph; the FFT circulant spectrum the
    best-of-20 expander search now uses), and the multi-scheme
    ``sweep_campaign`` against the sequential per-scheme
    ``sweep_error`` loop on the same grid -- with its own inline
    acceptance: bit-identical mean/std, cov within tolerance, and a
    >= 1.25x hard speedup floor (measured ~1.6-2.0x).

    Also runs the compression campaign (error vs p vs bits) at the
    regime-1 m=24 d=3 scheme: none/sign/int8 codecs under optimal
    decoding plus the majority-vote signSGD degenerate fixed decoding,
    with inline sanity acceptance -- int8 stays within 10% (+1e-3
    absolute floor) of the uncompressed decoding error at every p,
    while both sign entries sit strictly above it (1-bit quantization
    noise dominates the straggler term at this scale).
    """
    m, d, trials = 6552, 6, 30
    A = expander_assignment(m, d, vertex_transitive=True, seed=0)
    n = A.n

    t0 = time.perf_counter()
    per_point = [monte_carlo_error(A, p, trials=trials, method="optimal",
                                   seed=0) for p in P_GRID]
    loop_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    rows = sweep_error(A, P_GRID, trials=trials, method="optimal", seed=0,
                       cov_method="lanczos")
    sweep_s = time.perf_counter() - t0

    bit_identical = all(
        r["mean_error"] == q["mean_error"] and
        r["std_error"] == q["std_error"]
        for r, q in zip(rows, per_point))
    cov_rel = max(abs(r["cov_norm"] - q["cov_norm"]) /
                  max(abs(q["cov_norm"]), 1e-30)
                  for r, q in zip(rows, per_point))
    # Acceptance contract, enforced (CI runs this via benchmarks.run):
    # shared-uniform bit-identity and 1e-6-relative matrix-free cov.
    # The 1e-6 bound is a float64 property: on TPU the Gram matvec runs
    # the float32 Pallas kernel, so only a coarse sanity bound applies.
    from repro.kernels.spectral_matvec import ops as _sm_ops

    cov_tol = 5e-3 if _sm_ops.uses_pallas() else 1e-6
    if not bit_identical:
        raise AssertionError(
            "sweep_error diverged from per-point monte_carlo_error: "
            f"{rows} vs {per_point}")
    if cov_rel > cov_tol:
        raise AssertionError(
            f"matrix-free cov norm off by {cov_rel:.3e} rel "
            f"(> {cov_tol:g})")

    # Spectral primitive timings at the same (trials, n) / n scales.
    rng = np.random.default_rng(0)
    ab = rng.normal(loc=1.0, scale=0.05, size=(trials, n))
    t0 = time.perf_counter()
    dense_norm = spectral.covariance_spectral_norm(ab, method="dense")
    cov_dense_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    lanczos_norm = spectral.covariance_spectral_norm(ab, method="lanczos")
    cov_lanczos_s = time.perf_counter() - t0

    g = A.graph
    # graph_lambda2 is lru-cached; time the uncached implementation.
    lam2_impl = spectral.graph_lambda2.__wrapped__
    t0 = time.perf_counter()
    lam2_dense = lam2_impl(g, "dense")
    lam2_dense_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    lam2_lanczos = lam2_impl(g, "lanczos")
    lam2_lanczos_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    spectral.circulant_spectrum(n, tuple(range(1, d // 2 + 1)))
    fft_s = time.perf_counter() - t0

    # Blocked-Lanczos primitive at the campaign's stacked scale: all
    # S*P = 12 regime-2 covariance norms in one lockstep pass vs the
    # per-slice Lanczos loop.
    stack = rng.normal(loc=1.0, scale=0.05, size=(12, trials, n))
    t0 = time.perf_counter()
    per_slice = spectral.covariance_spectral_norm_batch(
        stack, method="lanczos")
    cov_loop_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    blocked = spectral.covariance_spectral_norm_batch(
        stack, method="blocked")
    cov_blocked_s = time.perf_counter() - t0
    blocked_rel = float(np.max(np.abs(blocked - per_slice) /
                               np.maximum(np.abs(per_slice), 1e-30)))

    # Campaign vs the sequential per-scheme loop on the same Figure-3
    # grid: one sweep_campaign (shared masks, stacked fixed GEMM,
    # blocked cov) against sweep_error per scheme. Acceptance enforced
    # inline (CI runs this via benchmarks.run): bit-identical mean/std
    # per (scheme, p), cov within the matrix-free tolerance, and a real
    # end-to-end speedup (>= 1.25 hard floor for CI noise; the
    # committed report shows the measured ~1.8-1.9x).
    entries = [(A, "optimal"), (A, "fixed")]
    t0 = time.perf_counter()
    seq = {f"{A.name}:{method}": sweep_error(
        A, P_GRID, trials=trials, method=method, seed=0,
        cov_method="lanczos") for _, method in entries}
    seq_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    camp = sweep_campaign(entries, P_GRID, trials=trials, seed=0,
                          cov_method="blocked")
    camp_s = time.perf_counter() - t0
    camp_cov_rel = 0.0
    for label, rows_seq in seq.items():
        for r_c, r_s in zip(camp[label], rows_seq):
            if r_c["mean_error"] != r_s["mean_error"] or \
                    r_c["std_error"] != r_s["std_error"]:
                raise AssertionError(
                    f"campaign diverged from per-scheme sweep_error at "
                    f"{label} p={r_s['p']}: {r_c} vs {r_s}")
            camp_cov_rel = max(
                camp_cov_rel,
                abs(r_c["cov_norm"] - r_s["cov_norm"]) /
                max(abs(r_s["cov_norm"]), 1e-30))
    if camp_cov_rel > cov_tol:
        raise AssertionError(
            f"campaign blocked cov off by {camp_cov_rel:.3e} rel "
            f"(> {cov_tol:g})")
    campaign_speedup = seq_s / camp_s
    if campaign_speedup < 1.25:
        raise AssertionError(
            f"campaign speedup {campaign_speedup:.2f}x < 1.25x over the "
            f"sequential per-scheme loop ({seq_s:.3f}s vs {camp_s:.3f}s)")

    # Compression grid: error vs p vs bits at the regime-1 scheme
    # (m=24, d=3 -- the campaign simulates dim-512 gradient vectors per
    # trial, so the paper-scale m=6552 scheme would dominate the whole
    # report for no extra signal).
    A_c = expander_assignment(24, 3, vertex_transitive=False, seed=1)
    comp_trials, comp_dim = 200, 512
    t0 = time.perf_counter()
    comp_rows = compression_campaign(A_c, P_GRID, trials=comp_trials,
                                     dim=comp_dim, seed=0)
    comp_s = time.perf_counter() - t0
    by_p: Dict[float, Dict[str, float]] = {}
    for r in comp_rows:
        by_p.setdefault(r["p"], {})[
            f"{r['codec']}:{r['decoding']}"] = r["mean_error"]
    for p, errs in by_p.items():
        none_e = errs["none:optimal"]
        if errs["int8:optimal"] > none_e * 1.10 + 1e-3:
            raise AssertionError(
                f"int8 decoding error {errs['int8:optimal']:.3e} at "
                f"p={p} exceeds 1.10x uncompressed ({none_e:.3e}) "
                f"+ 1e-3: 8-bit quantization noise should be in the "
                f"straggler-error noise floor")
        for key in ("sign:optimal", "sign:majority_vote"):
            if errs[key] <= none_e:
                raise AssertionError(
                    f"{key} error {errs[key]:.3e} at p={p} should "
                    f"exceed the uncompressed error {none_e:.3e}")

    # Scheme zoo: the cross-paper comparison grid (expander + FRC +
    # cyclic-MDS + BIBD + random-d-regular at the shared m = q(q+1) =
    # 12) through ONE sweep_campaign draw, each scheme's rows checked
    # bit-for-bit against its own per-point monte_carlo_error oracle.
    # Acceptance enforced inline (CI runs this via benchmarks.run).
    zoo_entries = scheme_zoo_entries(3, seed=0)
    zoo_trials = 256
    t0 = time.perf_counter()
    zoo_camp = sweep_campaign(zoo_entries, P_GRID, trials=zoo_trials,
                              seed=0, cov=False)
    zoo_camp_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    zoo_rows = {}
    for e in zoo_entries:
        label = e.resolved_label()
        for i, p in enumerate(P_GRID):
            oracle = monte_carlo_error(e.assignment, p,
                                       trials=zoo_trials, seed=0,
                                       method=e.method, cov=False)
            row = zoo_camp[label][i]
            if row["mean_error"] != oracle["mean_error"] or \
                    row["std_error"] != oracle["std_error"]:
                raise AssertionError(
                    f"scheme-zoo campaign diverged from per-point "
                    f"monte_carlo_error at {label} p={p}: {row} vs "
                    f"{oracle}")
        zoo_rows[label] = [
            {"p": r["p"], "mean_error": r["mean_error"]}
            for r in zoo_camp[label]]
    zoo_oracle_s = time.perf_counter() - t0

    # Adaptive regret: replay one seeded markov mask stream (the
    # stagnant-straggler process of Section VIII) under the adaptive
    # policy vs a grid of static fixed-decoding policies, scored
    # against the omniscient always-optimal baseline. Acceptance
    # (enforced inline): the adaptive policy's post-burn-in regret
    # beats the BEST static fixed policy's.
    A_z = zoo_entries[0].assignment  # expander, m=12
    true_p, persistence, steps, burn_in = 0.15, 8.0, 400, 50
    markov = make_straggler_model(A_z, "markov", true_p,
                                  persistence=persistence)
    _, stream = sample_mask_stream(
        A_z, markov, steps=steps, shuffle=False,
        rng=np.random.default_rng(42))
    fixed_grid = (0.05, 0.1, 0.15, 0.2, 0.3)
    policies = {"adaptive": AdaptivePolicy()}
    for p_f in fixed_grid:
        policies[f"static_fixed(p={p_f})"] = StaticPolicy(
            method="fixed", p=p_f)
    t0 = time.perf_counter()
    regret = policy_regret_report(A_z, stream, policies,
                                  burn_in=burn_in)
    regret_s = time.perf_counter() - t0
    best_fixed = min(v["regret"] for k, v in regret.items()
                     if k.startswith("static_fixed"))
    if regret["adaptive"]["regret"] >= best_fixed:
        raise AssertionError(
            f"adaptive regret {regret['adaptive']['regret']:.3e} does "
            f"not beat the best static fixed policy ({best_fixed:.3e}) "
            f"on the seeded markov stream")

    return {
        "regime2_grid": {
            "m": m, "d": d, "n": n, "graph": "LPS X^{5,13}",
            "p_grid": list(P_GRID), "trials": trials, "cov": True,
            "per_point_seconds": loop_s,
            "sweep_seconds": sweep_s,
            "speedup": loop_s / sweep_s,
            "bit_identical_mean_std": bit_identical,
            "cov_norm_max_rel_diff": cov_rel,
        },
        "campaign": {
            "schemes": list(seq),
            "p_grid": list(P_GRID), "trials": trials,
            "sequential_seconds": seq_s,
            "campaign_seconds": camp_s,
            "speedup": campaign_speedup,
            "bit_identical_mean_std": True,  # enforced above
            "cov_norm_max_rel_diff": camp_cov_rel,
        },
        "spectral": {
            "cov_dense_svd_seconds": cov_dense_s,
            "cov_lanczos_seconds": cov_lanczos_s,
            "cov_rel_diff": abs(lanczos_norm - dense_norm) /
            max(abs(dense_norm), 1e-30),
            "cov_batch12_lanczos_loop_seconds": cov_loop_s,
            "cov_batch12_blocked_seconds": cov_blocked_s,
            "cov_blocked_rel_diff": blocked_rel,
            "lambda2_dense_seconds": lam2_dense_s,
            "lambda2_lanczos_seconds": lam2_lanczos_s,
            "lambda2_abs_diff": abs(lam2_lanczos - lam2_dense),
            "circulant_fft_seconds": fft_s,
        },
        "compression_grid": {
            "m": A_c.m, "d": 3, "graph": "random 3-regular",
            "p_grid": list(P_GRID), "trials": comp_trials,
            "dim": comp_dim, "seconds": comp_s,
            "rows": comp_rows,
        },
        "scheme_zoo": {
            "q": 3, "m": 12, "d": 4,
            "schemes": [e.resolved_label() for e in zoo_entries],
            "p_grid": list(P_GRID), "trials": zoo_trials,
            "campaign_seconds": zoo_camp_s,
            "per_point_oracle_seconds": zoo_oracle_s,
            "bit_identical_to_oracle": True,  # enforced above
            "rows": zoo_rows,
        },
        "adaptive_regret": {
            "scheme": A_z.name, "m": A_z.m,
            "straggler_model": "markov", "true_p": true_p,
            "persistence": persistence, "steps": steps,
            "burn_in": burn_in, "seconds": regret_s,
            "policies": regret,
            "best_static_fixed_regret": best_fixed,
            "adaptive_beats_best_static_fixed": True,  # enforced above
        },
        "note": ("per_point = historical monte_carlo_error loop (dense "
                 "covariance SVD per p); sweep = sweep_error (shared "
                 "uniforms, warm-started labels, matrix-free cov norm); "
                 "campaign = sweep_campaign over [optimal, fixed] vs "
                 "the sequential per-scheme sweep_error loop"),
    }


def main(fast: bool = False):
    t0 = time.time()
    rows = regime1(trials=50 if fast else 200)
    rows += regime2(trials=5 if fast else 30)
    for r in rows:
        print(",".join(f"{k}={v:.4g}" if isinstance(v, float) else
                       f"{k}={v}" for k, v in r.items()))
    # paper claim: optimal decoding is near the p^d/(1-p^d) optimum for
    # small p and far below the fixed-coefficient bound.
    r1 = [r for r in rows if r["regime"] == "m24_d3" and r["p"] <= 0.1]
    for r in r1:
        assert r["ours_optimal"] < r["fixed_lower_bound"], r
    print(f"# decoding_error done in {time.time() - t0:.1f}s")
    return rows


if __name__ == "__main__":
    main()
