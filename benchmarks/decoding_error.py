"""Figure 3 reproduction: decoding error E[|alpha-bar - 1|^2]/n and
covariance norm |Cov(alpha-bar)|_2 vs straggler probability p.

Two regimes, exactly as Section VIII:
  regime 1: m=24 machines, d=3, random 3-regular graph on 16 vertices.
  regime 2: m=6552, d=6, the LPS X^{5,13} Ramanujan graph (2184 vertices).

Schemes: ours+optimal, ours+fixed, expander-of-[6] (adjacency
assignment; optimal decoding at m=24, fixed at m=6552 as in the paper),
and the FRC optimum p^d/(1-p^d) plotted in closed form (the paper does
the same).
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import (adjacency_assignment, decode, expander_assignment,
                        monte_carlo_error, random_regular_graph, theory)

P_GRID = (0.05, 0.1, 0.15, 0.2, 0.25, 0.3)


def regime1(trials: int = 200, seed: int = 0) -> List[Dict]:
    A = expander_assignment(24, 3, vertex_transitive=False, seed=1)
    adj = adjacency_assignment(random_regular_graph(24, 3, seed=2),
                               name="expander[6]")
    rows = []
    for p in P_GRID:
        opt = monte_carlo_error(A, p, trials=trials, method="optimal",
                                seed=seed)
        fix = monte_carlo_error(A, p, trials=trials, method="fixed",
                                seed=seed)
        exp6 = monte_carlo_error(adj, p, trials=trials, method="optimal",
                                 seed=seed)
        rows.append({
            "regime": "m24_d3", "p": p,
            "ours_optimal": opt["mean_error"],
            "ours_optimal_cov": opt["cov_norm"],
            "ours_fixed": fix["mean_error"],
            "ours_fixed_cov": fix["cov_norm"],
            "expander6_optimal": exp6["mean_error"],
            "frc_optimal(theory)": theory.frc_random_error(p, 3),
            "lower_bound": theory.lower_bound_any_decoding(p, 3),
            "fixed_lower_bound": theory.lower_bound_fixed_decoding(p, 3),
        })
    return rows


def regime2(trials: int = 30, seed: int = 0) -> List[Dict]:
    A = expander_assignment(6552, 6, vertex_transitive=True, seed=0)
    rows = []
    for p in P_GRID:
        opt = monte_carlo_error(A, p, trials=trials, method="optimal",
                                seed=seed)
        fix = monte_carlo_error(A, p, trials=trials, method="fixed",
                                seed=seed)
        rows.append({
            "regime": "m6552_d6_LPS", "p": p,
            "ours_optimal": opt["mean_error"],
            "ours_optimal_cov": opt["cov_norm"],
            "ours_fixed": fix["mean_error"],
            "ours_fixed_cov": fix["cov_norm"],
            "frc_optimal(theory)": theory.frc_random_error(p, 6),
            "lower_bound": theory.lower_bound_any_decoding(p, 6),
            "fixed_lower_bound": theory.lower_bound_fixed_decoding(p, 6),
        })
    return rows


def speed_report(fast: bool = False) -> Dict:
    """Decoder throughput at the paper's m=6552 LPS scale: the historical
    per-trial ``decode`` loop vs the batched engine driving
    ``monte_carlo_error`` (mask sampling + batched decode + fused
    debias/error; the O(n^2) covariance step is off on both sides since
    the seed harness paid it once per call, not per trial).

    Feeds BENCH_decoding.json via ``benchmarks.run`` so the perf
    trajectory of the decoding path is machine-trackable across PRs.
    """
    m, d, p = 6552, 6, 0.1
    scalar_trials = 3 if fast else 10
    batched_trials = 1000
    A = expander_assignment(m, d, vertex_transitive=True, seed=0)

    rng = np.random.default_rng(0)
    masks = rng.random((scalar_trials, m)) >= p
    t0 = time.perf_counter()
    for t in range(scalar_trials):
        decode(A, masks[t], method="optimal")
    scalar_s = time.perf_counter() - t0

    # Warm once at the benchmark shape so the jit compile (paid once per
    # (graph, batch) shape) is not billed to steady-state throughput.
    monte_carlo_error(A, p, trials=batched_trials, method="optimal",
                      cov=False)
    t0 = time.perf_counter()
    monte_carlo_error(A, p, trials=batched_trials, method="optimal",
                      cov=False)
    batched_s = time.perf_counter() - t0

    scalar_tps = scalar_trials / scalar_s
    batched_tps = batched_trials / batched_s
    return {
        "m": m, "d": d, "p": p, "graph": "LPS X^{5,13}",
        "scalar": {"trials": scalar_trials, "seconds": scalar_s,
                   "trials_per_sec": scalar_tps},
        "batched": {"trials": batched_trials, "seconds": batched_s,
                    "trials_per_sec": batched_tps},
        "speedup": batched_tps / scalar_tps,
        "note": ("scalar = per-mask optimal_decode_graph (the seed "
                 "monte_carlo path); batched = full monte_carlo_error "
                 "(sampling + batched decode + fused error), cov off"),
    }


def main(fast: bool = False):
    t0 = time.time()
    rows = regime1(trials=50 if fast else 200)
    rows += regime2(trials=5 if fast else 30)
    for r in rows:
        print(",".join(f"{k}={v:.4g}" if isinstance(v, float) else
                       f"{k}={v}" for k, v in r.items()))
    # paper claim: optimal decoding is near the p^d/(1-p^d) optimum for
    # small p and far below the fixed-coefficient bound.
    r1 = [r for r in rows if r["regime"] == "m24_d3" and r["p"] <= 0.1]
    for r in r1:
        assert r["ours_optimal"] < r["fixed_lower_bound"], r
    print(f"# decoding_error done in {time.time() - t0:.1f}s")
    return rows


if __name__ == "__main__":
    main()
