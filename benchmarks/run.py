"""Benchmark harness entry point: one module per paper table/figure.

``python -m benchmarks.run [--full]`` -- fast mode by default so the
whole suite stays in CPU-minutes; --full uses the paper-scale settings
(m=6552 LPS regime etc.).
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma list: decoding_error,convergence,"
                         "adversarial,bounds,kernels,roofline")
    args = ap.parse_args()
    fast = not args.full

    from benchmarks import (adversarial, bounds, convergence,
                            decoding_error, expansion_ablation,
                            kernel_bench, roofline_report)
    suite = {
        "decoding_error": decoding_error.main,   # Fig 3
        "convergence": convergence.main,         # Fig 4/5
        "adversarial": adversarial.main,         # Table I / Cor V.2
        "bounds": bounds.main,                   # Props A.1/A.3
        "expansion": expansion_ablation.main,    # Thm IV.1 lambda ablation
        "kernels": kernel_bench.main,            # TPU-adaptation layer
        "roofline": roofline_report.main,        # Dry-run #Roofline
    }
    wanted = args.only.split(",") if args.only else list(suite)
    t0 = time.time()
    for name in wanted:
        print(f"\n=== {name} ===")
        sys.stdout.flush()
        suite[name](fast=fast)
    print(f"\nall benchmarks done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
