"""Benchmark harness entry point: one module per paper table/figure.

``python -m benchmarks.run [--fast|--full]`` -- fast mode by default so
the whole suite stays in CPU-minutes; --full uses the paper-scale
settings (m=6552 LPS regime etc.). Every run also emits machine-
readable perf reports: ``BENCH_train.json`` (train_step suite),
``BENCH_serve.json`` (serve suite: coded-serving tokens/s + synthetic
TTFT p50/p99 with inline acceptance), and, whenever
``decoding_error`` is in the selected suites:

* ``BENCH_decoding.json`` -- trials/sec for the scalar vs batched
  straggler-decoding paths plus the batched_alpha kernel rows.
* ``BENCH_sweep.json`` -- grid-seconds for the full regime-2 p-grid
  (6 p-points, cov on, trials=30 at m=6552): the historical per-p
  ``monte_carlo_error`` loop vs the ``sweep_error`` engine, AND the
  multi-scheme ``sweep_campaign`` vs the sequential per-scheme
  ``sweep_error`` loop -- each with bit-identity / 1e-6-cov / speedup
  acceptance checks inline -- plus spectral-norm timings (dense
  covariance SVD vs matrix-free Lanczos, per-slice vs blocked lockstep
  Lanczos, dense vs Lanczos graph lambda_2, FFT circulant spectrum),
  the scheme-zoo campaign (expander/FRC/cyclic-MDS/BIBD/random-
  d-regular at the shared m=12, each scheme bit-identical to its
  per-point ``monte_carlo_error`` oracle) and the adaptive-regret row
  (the ``core.adaptive`` policy vs the best static fixed-decoding
  policy on a seeded markov stream; adaptive must win).

Both keep the perf trajectory trackable across PRs.
"""

from __future__ import annotations

import argparse
import json
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--fast", action="store_true",
                    help="fast mode (the default unless --full is given)")
    ap.add_argument("--only", default=None,
                    help="comma list: decoding_error,convergence,"
                         "adversarial,bounds,kernels,roofline,"
                         "train_step,serve")
    ap.add_argument("--bench-json", default="BENCH_decoding.json",
                    help="where to write the decoding perf report")
    ap.add_argument("--sweep-json", default="BENCH_sweep.json",
                    help="where to write the grid-sweep perf report")
    ap.add_argument("--train-json", default="BENCH_train.json",
                    help="where to write the dist train-step report")
    ap.add_argument("--serve-json", default="BENCH_serve.json",
                    help="where to write the coded-serving report")
    args = ap.parse_args()
    if args.full and args.fast:
        ap.error("--fast and --full are mutually exclusive")
    fast = not args.full

    from benchmarks import (adversarial, bounds, convergence,
                            decoding_error, expansion_ablation,
                            kernel_bench, roofline_report, serve_bench,
                            train_step)
    suite = {
        "decoding_error": decoding_error.main,   # Fig 3
        "convergence": convergence.main,         # Fig 4/5
        "adversarial": adversarial.main,         # Table I / Cor V.2
        "bounds": bounds.main,                   # Props A.1/A.3
        "expansion": expansion_ablation.main,    # Thm IV.1 lambda ablation
        "kernels": kernel_bench.main,            # TPU-adaptation layer
        "roofline": roofline_report.main,        # Dry-run #Roofline
        "train_step": train_step.main,           # repro.dist mesh runtime
        "serve": serve_bench.main,               # coded serving engine
    }
    wanted = args.only.split(",") if args.only else list(suite)
    t0 = time.time()
    results = {}
    for name in wanted:
        print(f"\n=== {name} ===")
        sys.stdout.flush()
        results[name] = suite[name](fast=fast)

    if results.get("train_step"):
        report = dict(results["train_step"])
        report["mode"] = "fast" if fast else "full"
        with open(args.train_json, "w") as f:
            json.dump(report, f, indent=2)
        runs = report["runs"]
        repl = train_step.find_run(runs, scheme="expander",
                                   path="replicated",
                                   collective="gspmd", compress="none")
        dedup = train_step.find_run(runs, scheme="expander",
                                    path="dedup", compress="none")
        uncoded = train_step.find_run(runs, scheme="uncoded")
        print(f"wrote {args.train_json}: coded dedup "
              f"{dedup['step_ms']:.1f} ms/step "
              f"({dedup['step_ms'] / uncoded['step_ms']:.2f}x uncoded) "
              f"vs replicated {repl['step_ms']:.1f} ms/step "
              f"({repl['step_ms'] / uncoded['step_ms']:.2f}x) vs "
              f"uncoded {uncoded['step_ms']:.1f} ms/step")
        # comm-bytes companion table + per-codec ceilings (int8/sign
        # <= 0.3x, sign_packed <= 0.05x float32)
        roofline_report.comm_report(report)

    if results.get("serve"):
        report = dict(results["serve"])
        report["mode"] = "fast" if fast else "full"
        with open(args.serve_json, "w") as f:
            json.dump(report, f, indent=2)
        acc = report["acceptance"]
        eng = report["engine"]["coded"]
        print(f"wrote {args.serve_json}: coded engine "
              f"{eng['tokens_per_s']:.1f} tok/s, sim p99 coded "
              f"{acc['coded_p99_ms']:.2f} ms vs uncoded "
              f"{acc['uncoded_p99_ms']:.2f} ms, "
              f"bit_identical_at_p0="
              f"{acc['token_stream_bit_identical_at_p0']}")

    if args.only is not None and "decoding_error" not in wanted:
        # A filtered run of unrelated suites shouldn't pay for (or
        # overwrite) the decoding perf report.
        print(f"\nall benchmarks done in {time.time() - t0:.1f}s")
        return

    print("\n=== decoding perf report ===")
    sys.stdout.flush()
    report = decoding_error.speed_report(fast=fast)
    report["mode"] = "fast" if fast else "full"
    # Reuse the rows the kernels suite just measured rather than timing
    # the same benchmarks twice.
    kernel_rows = [r for r in results.get("kernels") or []
                   if r[0].startswith("batched_alpha")] \
        or kernel_bench.batched_alpha_rows(fast=fast)
    report["kernels"] = [
        {"name": n, "us_per_call": round(us, 1), "derived": derived}
        for n, us, derived in kernel_rows]
    with open(args.bench_json, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.bench_json}: "
          f"scalar {report['scalar']['trials_per_sec']:.1f} trials/s, "
          f"batched {report['batched']['trials_per_sec']:.1f} trials/s "
          f"({report['speedup']:.1f}x)")

    print("\n=== grid-sweep perf report ===")
    sys.stdout.flush()
    sweep = decoding_error.sweep_report()  # paper-scale by contract
    sweep["mode"] = "fast" if fast else "full"
    with open(args.sweep_json, "w") as f:
        json.dump(sweep, f, indent=2)
    grid = sweep["regime2_grid"]
    print(f"wrote {args.sweep_json}: regime-2 grid "
          f"{grid['per_point_seconds']:.1f}s per-point vs "
          f"{grid['sweep_seconds']:.2f}s sweep ({grid['speedup']:.1f}x), "
          f"bit_identical={grid['bit_identical_mean_std']}, "
          f"cov_rel={grid['cov_norm_max_rel_diff']:.2e}")
    camp = sweep["campaign"]
    print(f"campaign {camp['campaign_seconds']:.2f}s vs sequential "
          f"per-scheme loop {camp['sequential_seconds']:.2f}s "
          f"({camp['speedup']:.2f}x), "
          f"bit_identical={camp['bit_identical_mean_std']}, "
          f"cov_rel={camp['cov_norm_max_rel_diff']:.2e}")
    cg = sweep["compression_grid"]
    print(f"compression grid: {len(cg['rows'])} "
          f"error-vs-p-vs-bits rows in {cg['seconds']:.2f}s "
          f"(codecs x p x decoding incl. majority-vote signSGD)")
    zoo = sweep["scheme_zoo"]
    print(f"scheme zoo (m={zoo['m']}, d={zoo['d']}): "
          f"{len(zoo['schemes'])} schemes x {len(zoo['p_grid'])} "
          f"p-points in {zoo['campaign_seconds']:.2f}s campaign "
          f"(oracle loop {zoo['per_point_oracle_seconds']:.2f}s), "
          f"bit_identical={zoo['bit_identical_to_oracle']}")
    ar = sweep["adaptive_regret"]
    print(f"adaptive regret (markov p={ar['true_p']}, "
          f"{ar['steps']} steps): adaptive "
          f"{ar['policies']['adaptive']['regret']:.3e} vs best static "
          f"fixed {ar['best_static_fixed_regret']:.3e} "
          f"(beats={ar['adaptive_beats_best_static_fixed']})")
    print(f"\nall benchmarks done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
