"""Quickstart: build an expander gradient code, decode around
stragglers, and check the error against the paper's theory.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (BernoulliStragglers, adversarial_mask, decode,
                        expander_assignment, monte_carlo_error,
                        normalized_error, theory)


def main():
    m, d, p = 48, 4, 0.2
    # The paper's scheme (Def II.2): machines = edges of a d-regular
    # expander on n = 2m/d data blocks.
    A = expander_assignment(m, d, vertex_transitive=False, seed=0)
    print(f"scheme: {A.name}  n={A.n} blocks, m={A.m} machines, "
          f"lambda={A.graph.spectral_expansion():.2f}")

    # One round: sample stragglers, decode optimally in O(m).
    rng = np.random.default_rng(0)
    alive = BernoulliStragglers(m=m, p=p).sample(rng)
    res = decode(A, alive, method="optimal")
    print(f"straggled {int((~alive).sum())}/{m}; "
          f"decoding error (1/n)|alpha-1|^2 = "
          f"{normalized_error(res.alpha):.4g}")

    # Monte-Carlo vs the paper's bounds.
    mc_opt = monte_carlo_error(A, p, trials=300, method="optimal")
    mc_fix = monte_carlo_error(A, p, trials=300, method="fixed")
    print(f"E[error] optimal {mc_opt['mean_error']:.4g}  "
          f"(any-decoder lower bound "
          f"{theory.lower_bound_any_decoding(p, d):.4g})")
    print(f"E[error] fixed   {mc_fix['mean_error']:.4g}  "
          f"(fixed lower bound "
          f"{theory.lower_bound_fixed_decoding(p, d):.4g})")

    # Adversarial stragglers (Section V).
    adv = decode(A, adversarial_mask(A, p), method="optimal")
    lam = A.graph.spectral_expansion()
    print(f"adversarial error {normalized_error(adv.alpha):.4g} "
          f"<= Cor V.2 bound "
          f"{theory.adversarial_bound_graph(p, d, lam):.4g}")


if __name__ == "__main__":
    main()
