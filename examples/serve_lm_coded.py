"""Coded serving walkthrough: the paper's straggler machinery applied
to TTFT tail latency.

Prefill shards are replicated d=2 across mesh slices via the same
``expander_assignment`` the coded trainer uses; each replica's latency
is drawn from the straggler process (here Bernoulli p=0.2 -- a replica
either answers inside the deadline or straggles for ``--straggle-ms``).
The engine combines whichever replicas arrive first with the optimal
decoder's weights, so:

* p50 stays at the single-replica base latency (no coding tax), and
* p99 is bounded by one deadline plus rare retry rounds (P ~ p^d)
  instead of by the slowest device.

``--check`` additionally pins the token streams against the
sequential-batching reference loop -- coding and continuous-batching
scheduling change *when* tokens are computed, never *which* tokens.

    PYTHONPATH=src python examples/serve_lm_coded.py [--arch ...]

Compare the summary's ttft_p50_ms/ttft_p99_ms against a
``--scheme uncoded`` run (examples/serve_llm.py) of the same seed to
see the tail collapse while the median holds.
"""

import sys

from repro.launch import serve


def main():
    argv = sys.argv[1:] or [
        "--arch", "qwen1.5-4b", "--scheme", "expander",
        "--replication", "2", "--replicas", "8",
        "--straggler-model", "bernoulli", "--straggler-p", "0.2",
        "--requests", "12", "--slots", "4", "--prompt-len", "16",
        "--prompt-spread", "3", "--max-new-tokens", "12",
        "--max-len", "64", "--check",
    ]
    serve.main(argv)


if __name__ == "__main__":
    main()
