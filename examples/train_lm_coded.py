"""End-to-end driver: coded training of a (reduced) assigned
architecture on the virtual-device mesh, with live straggler sampling
and O(m) optimal decoding. Wraps repro.launch.train with its async
pipeline defaults: deduplicated block execution (each unique block
once, weighted by v = A @ w), lookahead-batched decoding, and
metrics buffered on device between log intervals. Pass --no-dedup /
--collective manual to see the replicated-cluster simulation instead.

The default run composes gradient compression with the coded combine
(``--compress int8``): each block's gradient is quantized to a
per-tensor int8 payload + one float32 scale, an error-feedback
residual carries the quantization error into the next step, and the
fused quantized combine dequantizes and applies the decoded weights
in one pass -- the wire payload drops to ~0.25x of the float32 bytes
(audited in the summary's ``comm_bytes_per_step`` fields). Use
``--compress sign`` for the 1-bit signSGD-style codec or
``--compress none`` to recover the float32 combine bit-for-bit.

    PYTHONPATH=src python examples/train_lm_coded.py [--arch ...]
"""

import sys

from repro.launch import train


def main():
    argv = sys.argv[1:] or [
        "--arch", "deepseek-moe-16b", "--steps", "40",
        "--seq-len", "48", "--block-size", "2", "--lr", "1e-3",
        "--straggler-p", "0.2", "--scheme", "expander",
        "--decoding", "optimal", "--replication", "2",
        "--dedup", "--lookahead", "10", "--log-every", "5",
        "--compress", "int8",
    ]
    train.main(argv)


if __name__ == "__main__":
    main()
