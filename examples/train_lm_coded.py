"""End-to-end driver: coded training of a (reduced) assigned
architecture on the virtual-device mesh, with live straggler sampling
and O(m) optimal decoding. Wraps repro.launch.train with its async
pipeline defaults: deduplicated block execution (each unique block
once, weighted by v = A @ w), lookahead-batched decoding, and
metrics buffered on device between log intervals. Pass --no-dedup /
--collective manual to see the replicated-cluster simulation instead.

The default run composes gradient compression with the coded combine
(``--compress int8``): each block's gradient is quantized to a
per-tensor int8 payload + one float32 scale, an error-feedback
residual carries the quantization error into the next step, and the
fused quantized combine dequantizes and applies the decoded weights
in one pass -- the wire payload drops to ~0.25x of the float32 bytes
(audited in the summary's ``comm_bytes_per_step`` fields). Use
``--compress sign`` for the 1-bit signSGD-style codec or
``--compress none`` to recover the float32 combine bit-for-bit.

Chaos mode (``--chaos <spec>``) switches straggler masks from sampled
to *observed*: a seeded injector simulates per-machine completion
timestamps, a heartbeat monitor derives each round's alive mask by
deadline (exponential backoff per consecutive miss), and
``--dead-after`` consecutive misses declare a machine dead -- which
triggers an elastic re-assignment: the code is re-drawn over the
survivors and training continues from the live state. The spec is
semicolon-separated events over the *original* machine ids::

    kill:J@S          machine J dies permanently at step S
    rack:J,K,...@S    correlated failure: all listed machines die at S
    delay:J@S-E[:X]   J's completion time x X (default 10) for [S, E)
    flap:J@S-E[:K]    J alternates K steps dark / K healthy on [S, E)

e.g. ``--chaos "kill:1@3;delay:2@5-8:20"``. The structured failure
log lands in the summary's ``chaos`` object and, with
``--event-log FILE``, as a JSON artifact:

    {"spec": ..., "events": [{"step", "kind": straggle|recover|dead|
     reassign, "machine", "detail"}, ...], "reassignments": [{"step",
     "generation", "dead", "survivors", "m", "scheme", "replication",
     "n_blocks", "rebuild_s"}, ...], "dead_machines": [...],
     "steps_to_detect": {machine: steps}, "degraded_steps": N,
     "m_final": M, "generations": G}

Try: ``PYTHONPATH=src python examples/train_lm_coded.py --steps 20 \
--straggler-p 0 --chaos "kill:1@5" --compress none``

    PYTHONPATH=src python examples/train_lm_coded.py [--arch ...]
"""

import sys

from repro.launch import train


def main():
    argv = sys.argv[1:] or [
        "--arch", "deepseek-moe-16b", "--steps", "40",
        "--seq-len", "48", "--block-size", "2", "--lr", "1e-3",
        "--straggler-p", "0.2", "--scheme", "expander",
        "--decoding", "optimal", "--replication", "2",
        "--dedup", "--lookahead", "10", "--log-every", "5",
        "--compress", "int8",
    ]
    train.main(argv)


if __name__ == "__main__":
    main()
