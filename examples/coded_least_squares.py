"""The paper's Section VIII experiment: coded gradient descent on least
squares under random stragglers -- optimal vs fixed decoding vs
uncoded.

    PYTHONPATH=src python examples/coded_least_squares.py
"""

import numpy as np

from repro.core import (BernoulliStragglers, LeastSquares,
                        expander_assignment, gcod, uncoded_gd)


def main():
    m, d, p, steps = 96, 4, 0.2, 60
    n = 2 * m // d
    prob = LeastSquares.synthetic(N=n * 8, k=64, noise=0.5, n_blocks=n,
                                  seed=0)
    A = expander_assignment(m, d, vertex_transitive=False, seed=0)

    lrs = np.geomspace(3e-4, 3e-2, 6)

    def best(fn):
        traces = [fn(lr) for lr in lrs]
        good = [t for t in traces if np.isfinite(t.errors[-1])]
        return min(good, key=lambda t: t.errors[-1])

    runs = {
        "optimal": best(lambda lr: gcod(
            prob, A, BernoulliStragglers(m=m, p=p), steps=steps, lr=lr,
            method="optimal", p=p)),
        "fixed": best(lambda lr: gcod(
            prob, A, BernoulliStragglers(m=m, p=p), steps=steps, lr=lr,
            method="fixed", p=p)),
        "uncoded(x d iters)": best(lambda lr: uncoded_gd(
            LeastSquares.synthetic(N=n * 8, k=64, noise=0.5,
                                   n_blocks=m, seed=0),
            m, p, steps=d * steps, lr=lr)),
    }
    print(f"m={m} machines, d={d}, p={p}: |theta_t - theta*|^2")
    for name, tr in runs.items():
        print(f"  {name:20s} start {tr.errors[0]:9.3f} -> "
              f"final {tr.errors[-1]:.6f}")
    assert runs["optimal"].errors[-1] <= runs["fixed"].errors[-1] * 1.2


if __name__ == "__main__":
    main()
