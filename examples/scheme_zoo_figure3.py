"""Cross-paper Figure-3 reproduction: the scheme zoo under one draw.

The paper's Figure 3 plots decoding error vs straggler probability p
for its expander code against rivals it only cites. This walkthrough
actually runs that comparison from this repo: the paper's expander
code, the FRC (Table I), the cyclic-MDS / shifted code of Raviv et al.
(1707.03858), the affine-plane BIBD of Kadhe et al. (1904.13373), and
the random perfect-matching d-regular code of Charles et al.
(1711.06771) -- all at the ONE machine count m = q(q+1) = 12 they
share, facing the SAME shared-uniform straggler draw via
``sweep_campaign`` (the common-random-numbers protocol that makes
cross-scheme curves comparable point by point).

It then replays the adversarial side of the story (Kadhe et al.'s
claim: pairwise-balanced designs take less worst-case damage than
cyclic codes once the straggler budget exceeds the replication), and
closes with the adaptive layer: estimating p-hat online from the mask
stream and switching decoders per step, scored as regret against the
omniscient choice.

    PYTHONPATH=src python examples/scheme_zoo_figure3.py
"""

import numpy as np

from repro.core import (AdaptivePolicy, StaticPolicy, adversarial_mask,
                        bibd_assignment, cyclic_mds_assignment, decode,
                        normalized_error, policy_regret_report,
                        scheme_zoo_entries, sweep_campaign)
from repro.core.step_weights import (make_straggler_model,
                                     sample_mask_stream)

P_GRID = (0.05, 0.1, 0.15, 0.2, 0.25, 0.3, 0.35, 0.4)


def main():
    # ---- 1. The Figure-3 grid, all five schemes, one shared draw ----
    entries = scheme_zoo_entries(3, seed=0)   # q=3 -> m=12, d=4
    campaign = sweep_campaign(entries, P_GRID, trials=2000, seed=0,
                              cov=False)
    labels = list(campaign)
    print("decoding error E|alpha-bar - 1|^2 / n  (m=12, d=4, "
          "2000 shared trials)")
    print(f"{'p':>5} " + " ".join(f"{lab:>22}" for lab in labels))
    for i, p in enumerate(P_GRID):
        row = " ".join(f"{campaign[lab][i]['mean_error']:>22.5f}"
                       for lab in labels)
        print(f"{p:>5.2f} {row}")

    # ---- 2. Adversarial stragglers: BIBD vs cyclic (Kadhe et al.) ----
    bibd = bibd_assignment(13, 4)      # PG(2, 3): lambda = 1
    cyclic = cyclic_mds_assignment(13, 4)
    print("\nworst-case |S| <= pm error at m=13, d=4 "
          "(portfolio / greedy attacks, brute-force-exact at this m):")
    print(f"{'p':>5} {'budget':>7} {'cyclic_mds':>11} {'bibd':>11}")
    for p in (0.16, 0.24, 0.31, 0.39, 0.47):
        errs = []
        for A in (cyclic, bibd):
            mask = adversarial_mask(A, p)
            errs.append(normalized_error(
                decode(A, mask, method="optimal").alpha))
        budget = int(np.floor(p * 13))
        marker = "  <- design wins" if errs[1] < errs[0] else ""
        print(f"{p:>5.2f} {budget:>7} {errs[0]:>11.5f} "
              f"{errs[1]:>11.5f}{marker}")

    # ---- 3. Adaptive decoding: online p-hat, per-step policy --------
    A = entries[0].assignment          # the expander, m=12
    model = make_straggler_model(A, "markov", 0.15, persistence=8.0)
    _, stream = sample_mask_stream(A, model, steps=400, shuffle=False,
                                   rng=np.random.default_rng(42))
    policies = {"adaptive": AdaptivePolicy()}
    for p_f in (0.05, 0.15, 0.3):
        policies[f"static fixed(p={p_f})"] = StaticPolicy(
            method="fixed", p=p_f)
    report = policy_regret_report(A, stream, policies, burn_in=50)
    print("\nregret vs omniscient (markov stream, true p=0.15, "
          "400 steps, burn-in 50):")
    for name, row in report.items():
        print(f"  {name:>22}: mean error {row['mean_error']:.5f}, "
              f"regret {row['regret']:.5f}")
    assert report["adaptive"]["regret"] < min(
        v["regret"] for k, v in report.items() if "fixed" in k)
    print("adaptive beats every static fixed policy.")


if __name__ == "__main__":
    main()
