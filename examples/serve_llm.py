"""Batched serving example: prefill + autoregressive decode with the
per-architecture cache (KV cache / SSM state / xLSTM state). Wraps
repro.launch.serve.

    PYTHONPATH=src python examples/serve_llm.py [--arch ...]
"""

import sys

from repro.launch import serve


def main():
    argv = sys.argv[1:] or [
        "--arch", "xlstm-1.3b", "--batch", "4", "--prompt-len", "16",
        "--new-tokens", "12", "--max-len", "64",
    ]
    serve.main(argv)


if __name__ == "__main__":
    main()
