"""Batched serving example: continuous-batching engine with the
per-architecture cache (KV cache / SSM state / xLSTM state), uncoded
single-replica prefill. Wraps repro.launch.serve.

    PYTHONPATH=src python examples/serve_llm.py [--arch ...]

See examples/serve_lm_coded.py for the d-replicated coded prefill
variant with bounded TTFT tails.
"""

import sys

from repro.launch import serve


def main():
    argv = sys.argv[1:] or [
        "--arch", "xlstm-1.3b", "--scheme", "uncoded", "--requests", "8",
        "--slots", "4", "--prompt-len", "16", "--max-new-tokens", "12",
        "--max-len", "64",
    ]
    serve.main(argv)


if __name__ == "__main__":
    main()
