"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=102400; 2 shared + 64 routed experts top-6, fine-grained.
[arXiv:2401.06066]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    arch_type="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    expert_d_ff=1408,
    source="arXiv:2401.06066",
)
