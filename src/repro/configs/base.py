"""Model / run configuration dataclasses shared by the whole framework."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture configuration.

    ``arch_type`` in {dense, moe, hybrid, ssm, vlm, audio}. Hybrid =
    Mamba2 backbone with shared attention blocks (Zamba2); ssm = xLSTM;
    audio = encoder-decoder with a stubbed modality frontend; vlm =
    decoder with stubbed patch-embedding prefix.
    """

    name: str
    arch_type: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    # attention
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None  # None = full causal attention
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0          # per-expert hidden dim (fine-grained MoE)
    # SSM / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    attn_positions: Tuple[int, ...] = ()   # hybrid: shared-attn insertions
    # xLSTM
    slstm_ratio: int = 0          # mLSTM blocks per sLSTM block (0 = n/a)
    # enc-dec / multimodal
    n_encoder_layers: int = 0
    prefix_len: int = 0           # vlm patch / audio frame positions
    # numerics
    dtype: str = "bfloat16"       # activation/compute dtype
    param_dtype: str = "float32"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # citation
    source: str = ""

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def padded_vocab(self, multiple: int = 256) -> int:
        """Vocab padded for clean sharding (standard practice; loss
        masks the padding ids)."""
        v = self.vocab_size
        return ((v + multiple - 1) // multiple) * multiple

    def with_overrides(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def smoke_variant(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests:
        2 layers, d_model <= 512, <= 4 experts."""
        kw = dict(
            n_layers=2,
            d_model=256,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) or 4,
            d_ff=512,
            vocab_size=512,
            dtype="float32",
            sliding_window=(64 if self.sliding_window else None),
        )
        if self.n_experts:
            kw.update(n_experts=4, n_shared_experts=min(self.n_shared_experts, 1),
                      top_k=min(self.top_k, 2), expert_d_ff=128)
        if self.ssm_state:
            kw.update(ssm_state=16)
        if self.attn_positions:
            kw.update(attn_positions=(1,))
        if self.n_encoder_layers:
            kw.update(n_encoder_layers=2)
        if self.prefix_len:
            kw.update(prefix_len=8)
        return self.with_overrides(**kw)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One of the four assigned input shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeSpec("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524_288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


@dataclasses.dataclass(frozen=True)
class CodingConfig:
    """Gradient-coding runtime configuration (the paper's technique)."""

    # expander | frc | uncoded | cyclic_mds | bibd | random_regular
    scheme: str = "expander"
    replication: int = 4          # d
    decoding: str = "optimal"     # optimal | fixed
    straggler_model: str = "bernoulli"  # bernoulli | markov | adversarial
    straggler_p: float = 0.1
    shuffle_blocks: bool = True
    seed: int = 0
