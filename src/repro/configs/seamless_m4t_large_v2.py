"""seamless-m4t-large-v2 [audio]: 24L decoder d_model=1024 16H (kv=16)
d_ff=8192 vocab=256206; encoder-decoder, multimodal frontend stubbed
(precomputed frame embeddings). [arXiv:2308.11596]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    arch_type="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    n_encoder_layers=24,
    prefix_len=1024,  # stub frame-embedding length (source sequence)
    source="arXiv:2308.11596",
)
