"""pixtral-12b [vlm]: 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072; pixtral-ViT frontend stubbed (patch embeddings), decoder
is mistral-nemo. [hf:mistralai/Pixtral-12B-2409]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    arch_type="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=131072,
    prefix_len=1024,  # stub patch-embedding prefix
    source="hf:mistralai/Pixtral-12B-2409",
)
