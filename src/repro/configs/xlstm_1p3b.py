"""xlstm-1.3b [ssm]: 48L d_model=2048 4H d_ff=0 vocab=50304; sLSTM +
mLSTM blocks at 7:1 ratio. [arXiv:2405.04517]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    arch_type="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    slstm_ratio=7,  # 7 mLSTM : 1 sLSTM per super-block (48 = 6 x 8)
    source="arXiv:2405.04517",
)
