"""Architecture registry: one module per assigned architecture."""

from __future__ import annotations

import importlib
from typing import Dict

from .base import (ModelConfig, ShapeSpec, CodingConfig, TRAIN_4K,
                   PREFILL_32K, DECODE_32K, LONG_500K, ALL_SHAPES)

ARCH_IDS = (
    "qwen1.5-4b",
    "zamba2-1.2b",
    "deepseek-coder-33b",
    "yi-34b",
    "deepseek-moe-16b",
    "llama4-scout-17b-a16e",
    "granite-3-8b",
    "seamless-m4t-large-v2",
    "pixtral-12b",
    "xlstm-1.3b",
)

_MODULES = {
    "qwen1.5-4b": "qwen15_4b",
    "zamba2-1.2b": "zamba2_1p2b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "yi-34b": "yi_34b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "granite-3-8b": "granite_3_8b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "pixtral-12b": "pixtral_12b",
    "xlstm-1.3b": "xlstm_1p3b",
}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


__all__ = ["ModelConfig", "ShapeSpec", "CodingConfig", "TRAIN_4K",
           "PREFILL_32K", "DECODE_32K", "LONG_500K", "ALL_SHAPES",
           "ARCH_IDS", "get_config", "all_configs"]
