"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=32000, ssm_state=64; Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    arch_type="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_conv=4,
    attn_positions=(13, 26),  # two shared-attn insertions
    source="arXiv:2411.15242",
)
