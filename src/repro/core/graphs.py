"""Expander-graph constructions for graph assignment schemes (Def II.2).

Data blocks are vertices; machines are edges. The key graph quantity is
the *spectral expansion* lambda = d - lambda_2(Adj(G)) (the gap between
the largest and second-largest adjacency eigenvalues); the paper's
bounds (Thm IV.1, Cor V.2) improve with lambda.

All constructions return a ``Graph`` with an explicit edge list so the
assignment matrix and the O(m) decoder can index edges consistently.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

Edge = Tuple[int, int]


@dataclasses.dataclass(frozen=True)
class Graph:
    """An undirected multigraph with a fixed edge ordering.

    ``circulant_offsets`` is derived metadata (the canonical half
    connection set of a circulant/Cayley graph of Z_n) that unlocks the
    exact FFT eigenvalue path in ``core.spectral``; it is excluded from
    eq/hash so graphs with identical edge lists share cache entries
    regardless of how they were constructed.
    """

    n: int
    edges: Tuple[Edge, ...]
    circulant_offsets: Optional[Tuple[int, ...]] = dataclasses.field(
        default=None, compare=False)

    @property
    def m(self) -> int:
        return len(self.edges)

    @property
    def replication_factor(self) -> float:
        """d = 2m/n (average vertex degree)."""
        return 2.0 * self.m / self.n

    def degrees(self) -> np.ndarray:
        deg = np.zeros(self.n, dtype=np.int64)
        for u, v in self.edges:
            deg[u] += 1
            deg[v] += 1
        return deg

    def adjacency(self) -> np.ndarray:
        adj = np.zeros((self.n, self.n), dtype=np.float64)
        for u, v in self.edges:
            adj[u, v] += 1.0
            adj[v, u] += 1.0
        return adj

    def spectral_expansion(self, method: str = "auto") -> float:
        """lambda = d - lambda_2 for a d-regular graph.

        For irregular graphs, returns max-degree minus the second
        adjacency eigenvalue, which is what the expander mixing lemma
        uses up to regularity slack.

        ``method`` dispatches the lambda_2 computation ('auto' |
        'dense' | 'fft' | 'lanczos'): exact FFT for circulant graphs,
        dense eigvalsh for small n, matrix-free Lanczos for large
        regular graphs. See ``core.spectral.graph_lambda2``.
        """
        from .spectral import spectral_expansion as _spectral_expansion

        return _spectral_expansion(self, method=method)

    def is_regular(self) -> bool:
        deg = self.degrees()
        return bool(np.all(deg == deg[0]))

    def is_connected(self) -> bool:
        return _num_components(self.n, self.edges) == 1

    def incident_edges(self) -> List[List[int]]:
        """vertex -> list of edge indices (for BFS decoding)."""
        inc: List[List[int]] = [[] for _ in range(self.n)]
        for j, (u, v) in enumerate(self.edges):
            inc[u].append(j)
            inc[v].append(j)
        return inc


def _num_components(n: int, edges: Sequence[Edge]) -> int:
    parent = list(range(n))

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    comps = n
    for u, v in edges:
        ru, rv = find(u), find(v)
        if ru != rv:
            parent[ru] = rv
            comps -= 1
    return comps


# ---------------------------------------------------------------------------
# Constructions
# ---------------------------------------------------------------------------


def _canonical_offsets(n: int, offsets: Sequence[int]) -> Tuple[int, ...]:
    """Canonical half connection set {min(o, n-o)} of a Z_n Cayley graph,
    deduplicated exactly as ``circulant_graph`` dedups edges."""
    half = set()
    for o in offsets:
        o = o % n
        if o:
            half.add(min(o, n - o))
    return tuple(sorted(half))


def cycle_graph(n: int) -> Graph:
    """2-regular cycle: the weakest vertex-transitive expander (d=2)."""
    if n < 3:
        raise ValueError("cycle needs n >= 3")
    return Graph(n, tuple((i, (i + 1) % n) for i in range(n)),
                 circulant_offsets=(1,))


def complete_graph(n: int) -> Graph:
    """K_n: the best expander (lambda = n), replication factor n-1."""
    return Graph(n, tuple((i, j) for i in range(n) for j in range(i + 1, n)))


def random_regular_graph(n: int, d: int, seed: int = 0,
                         max_tries: int = 200) -> Graph:
    """Uniform-ish random d-regular simple graph via the pairing model.

    Random d-regular graphs are near-Ramanujan with high probability
    (Friedman's theorem: lambda_2 <= 2*sqrt(d-1) + eps), which is what
    the paper uses for its m=24 experiments (Section VIII, matrix A_1).
    """
    if n * d % 2 != 0:
        raise ValueError("n*d must be even")
    if d >= n:
        raise ValueError("need d < n for a simple graph")
    rng = np.random.default_rng(seed)
    for _ in range(max_tries):
        # Pairing/configuration model: d half-edges ("stubs") per vertex.
        # Pure rejection fails with probability ~1 - e^{-d^2/4}, so
        # repair collisions (self-loops / multi-edges) by random edge
        # swaps instead of rejecting the whole pairing.
        stubs = np.repeat(np.arange(n), d)
        rng.shuffle(stubs)
        pairs = [(int(a), int(b)) for a, b in stubs.reshape(-1, 2)]
        seen = set()
        good = []
        bad = []
        for u, v in pairs:
            key = (min(u, v), max(u, v))
            if u == v or key in seen:
                bad.append((u, v))
            else:
                seen.add(key)
                good.append(key)
        ok = True
        for u, v in bad:
            if not good:
                ok = False
                break
            fixed = False
            for _try in range(200):
                j = int(rng.integers(len(good)))
                x, y = good[j]
                # rewire (u,v),(x,y) -> (u,x),(v,y)
                k1 = (min(u, x), max(u, x))
                k2 = (min(v, y), max(v, y))
                if u == x or v == y or k1 in seen or k2 in seen:
                    continue
                seen.discard((x, y))
                seen.add(k1)
                seen.add(k2)
                good[j] = k1
                good.append(k2)
                fixed = True
                break
            if not fixed:
                ok = False
                break
        if ok:
            g = Graph(n, tuple(good))
            if g.is_regular() and g.is_connected():
                return g
    raise RuntimeError(f"failed to sample a simple connected {d}-regular "
                       f"graph on {n} vertices in {max_tries} tries")


def random_matching_regular_graph(n: int, d: int, seed: int = 0,
                                  max_tries: int = 200) -> Graph:
    """Random d-regular graph as a union of d random perfect matchings.

    The sparse-random-graph construction of Charles et al. (1711.06771):
    each of the d rounds draws a uniform perfect matching on the n
    vertices (n even), and the union is d-regular by construction. The
    matching model is contiguous with the pairing model
    (``random_regular_graph``) but keeps per-round regularity exact --
    the generation style of expander-per-round schemes -- and is
    near-Ramanujan whp like the pairing model. Matchings that collide
    with an already-placed edge are redrawn so the union stays simple;
    a final connectivity check rejects the rare disconnected draw.
    """
    if n % 2 != 0:
        raise ValueError(
            f"random perfect matchings need an even vertex count, got "
            f"n={n} (a perfect matching pairs all vertices)")
    if not 1 <= d < n:
        raise ValueError(f"need 1 <= d < n for a simple d-regular "
                         f"graph, got d={d}, n={n}")
    rng = np.random.default_rng(seed)
    for _ in range(max_tries):
        seen: set = set()
        edges: List[Edge] = []
        ok = True
        for _round in range(d):
            for _try in range(max_tries):
                perm = rng.permutation(n)
                matching = [(int(min(a, b)), int(max(a, b)))
                            for a, b in perm.reshape(-1, 2)]
                if all(e not in seen for e in matching):
                    seen.update(matching)
                    edges.extend(matching)
                    break
            else:
                ok = False
                break
        if ok:
            g = Graph(n, tuple(edges))
            if g.is_connected():
                assert g.is_regular()
                return g
    raise RuntimeError(f"failed to build a connected {d}-regular union "
                       f"of perfect matchings on {n} vertices in "
                       f"{max_tries} tries")


def circulant_graph(n: int, offsets: Sequence[int]) -> Graph:
    """Cayley graph of Z_n with connection set {±o : o in offsets}.

    Circulant graphs are vertex-transitive, so Theorem IV.1's
    unbiasedness requirement (E[alpha*] = c*1) holds exactly. With
    well-spread offsets they are good (though not Ramanujan) expanders.
    """
    edges = []
    seen = set()
    for i in range(n):
        for o in offsets:
            o = o % n
            j = (i + o) % n
            key = (min(i, j), max(i, j))
            if i == j or key in seen:
                continue
            seen.add(key)
            edges.append(key)
    return Graph(n, tuple(edges),
                 circulant_offsets=_canonical_offsets(n, offsets))


def hypercube_graph(k: int) -> Graph:
    """k-dimensional hypercube: vertex-transitive, d=k, lambda = 2.

    Included as a vertex-transitive *non*-expander family for ablations.
    """
    n = 1 << k
    edges = []
    for i in range(n):
        for b in range(k):
            j = i ^ (1 << b)
            if i < j:
                edges.append((i, j))
    return Graph(n, tuple(edges))


def _is_prime(x: int) -> bool:
    if x < 2:
        return False
    if x % 2 == 0:
        return x == 2
    f = 3
    while f * f <= x:
        if x % f == 0:
            return False
        f += 2
    return True


def paley_graph(q: int) -> Graph:
    """Paley graph on q vertices (q prime, q = 1 mod 4).

    Vertex-transitive Cayley graph with lambda_2 = (sqrt(q)-1)/2, i.e.
    an excellent explicit expander with d = (q-1)/2. Serves the same
    role as the paper's LPS Ramanujan graphs: an explicit
    vertex-transitive expander, but self-contained to construct.
    """
    if not _is_prime(q) or q % 4 != 1:
        raise ValueError("Paley graph needs prime q = 1 mod 4")
    squares = {(x * x) % q for x in range(1, q)}
    edges = []
    for i in range(q):
        for j in range(i + 1, q):
            if (j - i) % q in squares:
                edges.append((i, j))
    # q = 1 mod 4 makes -1 a square, so the connection set is symmetric
    # and the Paley graph is the circulant with the square offsets.
    return Graph(q, tuple(edges),
                 circulant_offsets=_canonical_offsets(q, sorted(squares)))


def lps_like_cayley_expander(n: int, d: int, seed: int = 0) -> Graph:
    """Vertex-transitive d-regular expander: random circulant of Z_n.

    The paper uses the degree-6 LPS Ramanujan graph on 2184 vertices.
    LPS requires PGL(2, q) machinery; per the hardware-adaptation rule
    we substitute the closest self-contained construction with the same
    two properties the proofs need: (a) vertex transitivity (for
    unbiasedness), (b) large spectral expansion. Random circulants on
    Z_n achieve lambda_2 = O(sqrt(d log n)) whp; we draw several offset
    sets and keep the best expander.
    """
    if d % 2 != 0 and n % 2 != 0:
        raise ValueError("circulant d-regular needs even d or even n")
    from .spectral import circulant_spectrum

    rng = np.random.default_rng(seed)
    k = d // 2
    best_offs: Optional[List[int]] = None
    best_lam = -np.inf
    for _ in range(20):
        offs = rng.choice(np.arange(1, n // 2), size=k, replace=False)
        offs = list(int(o) for o in offs)
        if d % 2 == 1:
            offs.append(n // 2)
        # Degree d is automatic (distinct offsets < n/2, plus n/2 once);
        # the circulant is connected iff the offsets generate Z_n, and
        # its full spectrum is one FFT -- no graph build, no eigvalsh.
        if functools.reduce(math.gcd, offs, n) != 1:
            continue
        lam = d - float(np.sort(circulant_spectrum(n, offs))[-2])
        if lam > best_lam:
            best_offs, best_lam = offs, lam
    if best_offs is None:
        raise RuntimeError("no valid circulant found")
    return circulant_graph(n, best_offs)


def _sqrt_mod(a: int, q: int) -> Optional[int]:
    a %= q
    for x in range(q):
        if (x * x) % q == a:
            return x
    return None


def lps_graph(p: int, q: int) -> Graph:
    """The Lubotzky-Phillips-Sarnak Ramanujan graph X^{p,q} [19].

    p, q distinct primes = 1 mod 4. Degree p+1; vertex set PSL(2,q) if p
    is a quadratic residue mod q (n = q(q^2-1)/2), else PGL(2,q)
    (n = q(q^2-1)). Vertex-transitive with lambda_2 <= 2*sqrt(p), i.e.
    spectral expansion lambda >= d - 2*sqrt(d-1). The paper's m=6552
    experiment uses X^{5,13}: degree 6 on the 2184 elements of PGL(2,13).

    Generators: for each of the 8(p+1) integer solutions of
    a0^2+a1^2+a2^2+a3^2 = p there is a canonical subset with a0 > 0 odd
    and a1,a2,a3 even, of size p+1, mapped to matrices
    [[a0 + i*a1, a2 + i*a3], [-a2 + i*a3, a0 - i*a1]] mod q, i^2 = -1.
    """
    if not (_is_prime(p) and _is_prime(q)) or p % 4 != 1 or q % 4 != 1:
        raise ValueError("LPS needs distinct primes p, q = 1 mod 4")
    i = _sqrt_mod(q - 1, q)
    assert i is not None
    # Enumerate the p+1 canonical solutions of the four-square equation.
    gens = []
    bound = int(np.sqrt(p)) + 1
    for a0 in range(1, bound + 1, 2):  # a0 odd, positive
        for a1 in range(-bound, bound + 1):
            for a2 in range(-bound, bound + 1):
                for a3 in range(-bound, bound + 1):
                    if a0 * a0 + a1 * a1 + a2 * a2 + a3 * a3 != p:
                        continue
                    if a1 % 2 or a2 % 2 or a3 % 2:
                        continue
                    g = ((a0 + i * a1) % q, (a2 + i * a3) % q,
                         (-a2 + i * a3) % q, (a0 - i * a1) % q)
                    gens.append(g)
    if len(gens) != p + 1:
        raise RuntimeError(f"found {len(gens)} generators, wanted {p+1}")

    legendre_p_q = pow(p, (q - 1) // 2, q)
    use_psl = legendre_p_q == 1

    def canon(mat: Tuple[int, int, int, int]) -> Tuple[int, int, int, int]:
        """Canonical representative modulo the centre (scalars)."""
        a, b, c, d_ = mat
        if use_psl:
            # PSL: mats have det in (F_q^*)^2 after scaling; quotient by
            # all scalars AND by sign -- canonical: first nonzero entry
            # is the smallest of {e, q-e} choices... we scale so the
            # first nonzero entry is 1, then fix sign ambiguity is
            # absorbed since -1 is a scalar.
            pass
        for e in (a, b, c, d_):
            if e % q:
                inv = pow(e, q - 2, q)
                return (a * inv % q, b * inv % q, c * inv % q, d_ * inv % q)
        raise ValueError("zero matrix")

    def mul(x, y):
        a, b, c, d_ = x
        e, f, g, h = y
        return ((a * e + b * g) % q, (a * f + b * h) % q,
                (c * e + d_ * g) % q, (c * f + d_ * h) % q)

    # BFS over the Cayley graph from the identity.
    start = canon((1, 0, 0, 1))
    index = {start: 0}
    frontier = [start]
    edge_set = set()
    while frontier:
        nxt = []
        for v in frontier:
            for g in gens:
                u = canon(mul(v, g))
                if u not in index:
                    index[u] = len(index)
                    nxt.append(u)
                a, b = index[v], index[u]
                if a != b:
                    edge_set.add((min(a, b), max(a, b)))
        frontier = nxt
    n = len(index)
    expected = q * (q * q - 1) // (2 if use_psl else 1)
    if n != expected:
        raise RuntimeError(f"LPS component has {n} vertices, "
                           f"expected {expected}")
    return Graph(n, tuple(sorted(edge_set)))


@functools.lru_cache(maxsize=32)  # process-level: LPS BFS etc. run once
def make_expander(n: int, d: int, *, vertex_transitive: bool = True,
                  seed: int = 0) -> Graph:
    """Main entry point: a d-regular expander on n vertices.

    Vertex-transitive requests are served by (in order of preference):
    the exact LPS Ramanujan graph when (n, d) matches one, the
    hypercube, or a best-of-20 random circulant (adequate for the small
    n used by the distributed runtime; NOT a good expander for large n
    at constant d -- use LPS sizes there, as the paper does).

    Cached per process (graphs are immutable), so every benchmark
    module sharing e.g. the m=6552 LPS scheme pays construction once.
    """
    if d >= n - 1:
        return complete_graph(n)
    if d == 2:
        return cycle_graph(n)
    if vertex_transitive:
        if (n, d) == (2184, 6):
            return lps_graph(5, 13)
        if n == (1 << (n.bit_length() - 1)) and d == n.bit_length() - 1:
            return hypercube_graph(d)
        return lps_like_cayley_expander(n, d, seed=seed)
    return random_regular_graph(n, d, seed=seed)
