"""Batched optimal decoding: alpha* for a whole (trials, m) batch of masks.

The scalar decoder (``decoding.optimal_alpha_graph``) runs one Python BFS
two-coloring per straggler mask. Every Monte-Carlo harness in the paper
(Figure 3, the m=6552 Section VIII-B simulations, the adversarial
sweeps) samples thousands of masks over the *same* graph, so this module
replaces the per-mask BFS with an array-level fixed-point iteration that
decodes the entire batch at once.

Formulation: pointer jumping on the bipartite double cover
----------------------------------------------------------

Everything the Section III characterisation needs -- connected
components of the surviving subgraph, bipartiteness of each component,
and the two side sizes |L|, |R| -- is recovered from connected
components of the *bipartite double cover* of G. The cover has two nodes
v0 = v and v1 = v + n per vertex v, and each surviving edge (u, v)
becomes the two cover edges (u0, v1) and (u1, v0). Standard facts:

* a component of G is bipartite  <=>  its cover splits into two
  components, one per side (v0's component collects the vertices at
  even distance from v, v1's the odd ones);
* a component is non-bipartite   <=>  v0 and v1 are merged (an odd walk
  exists), so the whole component lifts to a single cover component;
* an isolated vertex keeps v0 and v1 as two singleton components.

Components are labeled by min-label propagation with pointer jumping
(Shiloach-Vishkin style): labels start as node identity; each round
every node takes the minimum label over its surviving cover neighbours,
then shortcuts ``label <- label[label]``. Labels decrease monotonically
and the unique fixed point assigns every cover node the minimum node
index of its component, in O(log n) rounds. Each round is a
whole-(trials, 2n)-array operation: a gather of neighbour labels
through a degree-padded dense incidence (cover nodes inherit the vertex
degrees, so d-regular graphs pad to exactly d slots), a masked
min-reduce over the degree axis, and take-along-axis jumps. Backends:
NumPy for small batches, and a jitted JAX ``lax.while_loop`` (usable
under ``jit`` end to end, and the path TPU execution takes) for large
ones.

Equivalence with the BFS decoder: let L[x] be the fixed-point label of
cover node x and r = min(L[v0], L[v1]) the component root. Then
``nonbipartite(v) = (L[v0] == L[v1])``, and for bipartite components
``color(v) = (L[v1] < L[v0])`` puts v on the root's side iff
L[v0] = r < L[v1] (the root's own cover component always carries the
smaller label, because the opposite side's minimum node index is
strictly larger). Side sizes s0, s1 are then integer bincounts per
(trial, root, color), and alpha follows the Section III table with the
*same float expressions* as the scalar decoder -- ``1 -/+ |s0-s1|/(s0+s1)``
on bipartite components (the ``1 - delta`` branch taken by the weakly
larger side, which also yields the isolated-vertex 0 via s=1/0), and 1
on non-bipartite components -- so batched and scalar alphas agree
bit-for-bit, not just to rounding.
"""

from __future__ import annotations

import functools

import numpy as np

from .assignment import Assignment
from .graphs import Graph

try:  # jax is the repo's accelerator substrate, but keep numpy-only use viable
    import jax
    import jax.numpy as jnp

    _HAS_JAX = True
except Exception:  # pragma: no cover
    _HAS_JAX = False

# Below this many mask entries the jit/compile overhead of the JAX path
# outweighs its fused execution; "auto" uses NumPy there.
_JAX_MIN_WORK = 200_000


# ---------------------------------------------------------------------------
# Double-cover incidence (fixed per graph, cached)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=64)  # bounded: tables are O(n*d) each
def _cover_dense(graph: Graph):
    """Degree-padded incidence of the double cover.

    Cover node u0 = u neighbours {v1 : (u,v) surviving}, u1 = u + n
    neighbours {v0}; both inherit vertex u's degree, so the incidence
    packs into dense (2n, deg_max) tables -- gather + min-reduce over
    the last axis then replaces a ragged segment reduction, which is
    what makes the batched sweep SIMD/XLA-friendly. Padding slots point
    at the node itself via the sentinel edge m (always dead).

    Returns (pad_nbr, pad_edge), both (2n, deg_max) int32.
    """
    n, m = graph.n, graph.m
    # Cover nodes u0/u1 both inherit vertex u's degree.
    deg_max = max(int(graph.degrees().max(initial=0)), 1)
    pad_nbr = np.tile(np.arange(2 * n, dtype=np.int32)[:, None],
                      (1, deg_max))
    pad_edge = np.full((2 * n, deg_max), m, dtype=np.int32)
    fill = np.zeros(2 * n, dtype=np.int64)

    def put(x, y, j):
        pad_nbr[x, fill[x]] = y
        pad_edge[x, fill[x]] = j
        fill[x] += 1

    for j, (u, v) in enumerate(graph.edges):
        put(u, v + n, j)
        put(v + n, u, j)
        put(u + n, v, j)
        put(v, u + n, j)
    return pad_nbr, pad_edge


# ---------------------------------------------------------------------------
# Label-propagation backends: alive (T, m) -> cover labels (T, 2n)
# ---------------------------------------------------------------------------


def _label_dtype(n: int):
    """int16 labels when every node id -- and the jax backend's 2n
    sentinel -- fits (2n is even, so 2n < 32768 iff 2n <= 32766 fits
    int16); halves the gather traffic of the memory-bound relax step.
    Shared by both backends so warm-start labels round-trip losslessly.
    """
    return np.int16 if 2 * n < 32768 else np.int32


def _check_labels0(labels0, trials: int, n: int) -> np.ndarray:
    """Validate warm-start labels (see ``batched_optimal_alpha_graph``:
    only sound when the masks are supersets of the labels' masks)."""
    labels0 = np.asarray(labels0)
    if labels0.shape != (trials, 2 * n):
        raise ValueError(f"labels0 must be ({trials}, {2 * n}), "
                         f"got {labels0.shape}")
    return labels0.astype(_label_dtype(n), copy=False)


def _propagate_numpy(graph: Graph, alive: np.ndarray,
                     labels0: np.ndarray | None = None) -> np.ndarray:
    n = graph.n
    trials = alive.shape[0]
    pad_nbr, pad_edge = _cover_dense(graph)
    deg_max = pad_nbr.shape[1]
    # Column m is the always-dead sentinel edge; dead slots retarget to
    # the node itself, which is neutral under min.
    alive_ext = np.concatenate(
        [alive, np.zeros((trials, 1), dtype=bool)], axis=1)
    self_idx = np.arange(2 * n, dtype=np.int32)[:, None]
    nbr_eff = np.where(alive_ext[:, pad_edge], pad_nbr[None],
                       self_idx[None]).reshape(trials, 2 * n * deg_max)
    ldt = _label_dtype(n)
    if labels0 is None:
        labels = np.tile(np.arange(2 * n, dtype=ldt), (trials, 1))
    else:
        labels = _check_labels0(labels0, trials, n)
    while True:
        vals = np.take_along_axis(labels, nbr_eff, axis=1)
        new = np.minimum(labels,
                         vals.reshape(trials, 2 * n, deg_max).min(axis=2))
        while True:  # full path compression
            nxt = np.take_along_axis(new, new, axis=1)
            if np.array_equal(nxt, new):
                break
            new = nxt
        if np.array_equal(new, labels):
            return labels
        labels = new


@functools.lru_cache(maxsize=64)  # bounded: jitted fns hold XLA executables
def _jax_propagator(graph: Graph):
    """Jitted propagators for one graph: (run_cold, run_warm).

    ``run_cold(alive)`` seeds labels with node identity on device;
    ``run_warm(alive, labels0)`` takes a (T, 2n) warm-start seed. Both
    use a *static* shared gather index (each trial's label row fits in
    cache, and XLA folds index computation away) plus a precomputed
    liveness mask, which benches ~4x faster than per-trial effective
    neighbour indices on CPU. The fixed point -- per-component label
    minima -- is independent of the seed, so warm and cold starts agree
    bit-for-bit and one compile per entry serves a whole p-sweep.
    """
    n = graph.n
    pad_nbr_np, pad_edge_np = _cover_dense(graph)
    deg_max = pad_nbr_np.shape[1]
    nbr_flat = jnp.asarray(pad_nbr_np.ravel())    # (2n*deg,) static
    edge_flat = jnp.asarray(pad_edge_np.ravel())
    ldt = jnp.dtype(_label_dtype(n))
    big = jnp.asarray(2 * n, ldt)

    def propagate(alive, labels0):
        trials = alive.shape[0]
        alive_ext = jnp.concatenate(
            [alive, jnp.zeros((trials, 1), dtype=bool)], axis=1)
        pad_alive = alive_ext[:, edge_flat]       # (T, 2n*deg)

        def cond(carry):
            return carry[1]

        def body(carry):
            labels, _ = carry
            vals = jnp.where(pad_alive, labels[:, nbr_flat], big)
            new = jnp.minimum(
                labels, vals.reshape(trials, 2 * n, deg_max).min(axis=2))
            for _ in range(3):  # pointer jumping (cheap vs the relax)
                new = jnp.take_along_axis(new, new, axis=1)
            return new, jnp.any(new != labels)

        labels, _ = jax.lax.while_loop(
            cond, body, (labels0.astype(ldt), jnp.bool_(True)))
        return labels

    @jax.jit
    def run_cold(alive):
        # Identity seed built on device: the common (non-sweep) case
        # ships no labels array from the host.
        labels0 = jnp.tile(jnp.arange(2 * n, dtype=ldt),
                           (alive.shape[0], 1))
        return propagate(alive, labels0)

    run_warm = jax.jit(propagate)
    return run_cold, run_warm


def _alpha_from_labels(labels: np.ndarray, n: int) -> np.ndarray:
    """Cover labels (T, 2n) -> alpha (T, n) float64, bit-identical to the
    scalar Section III decoder (see module docstring)."""
    trials = labels.shape[0]
    idt = np.int32 if 2 * trials * n < 2 ** 31 else np.int64
    l0 = labels[:, :n]
    l1 = labels[:, n:]
    nonbip_v = l0 == l1
    root = np.minimum(l0, l1).astype(idt)  # min vertex of the G-component
    color = l1 < l0  # False = root's side
    base = root + (np.arange(trials, dtype=idt) * n)[:, None]
    ids2 = (base << 1) | color
    cnt = np.bincount(ids2.ravel(), minlength=2 * trials * n)
    own_side = cnt[ids2]
    other_side = cnt[ids2 ^ 1]
    total = own_side + other_side
    nb_cnt = np.bincount(base[nonbip_v], minlength=trials * n)
    nb_comp = nb_cnt[base] > 0
    # Same float expressions as optimal_alpha_graph: delta, then 1 -/+.
    delta = np.abs(own_side - other_side) / total
    alpha = np.where(own_side >= other_side, 1.0 - delta, 1.0 + delta)
    return np.where(nb_comp, 1.0, alpha)


# ---------------------------------------------------------------------------
# Public batched decoders
# ---------------------------------------------------------------------------


def is_graph_scheme(assignment: Assignment) -> bool:
    """True for Def II.2 schemes (machines = edges of the carried
    graph): the schemes the O(m) component decoders serve. Single
    dispatch predicate shared by the scalar, batched and sweep paths.
    Keyed on the explicit ``machines`` marker, not the A shape --
    adjacency assignments also carry a graph, and for 2-regular graphs
    their n x n shape is indistinguishable from (n, m); they must fall
    through to the pseudoinverse."""
    return assignment.graph is not None and assignment.machines == "edges"


def _check_masks(alive, m: int) -> np.ndarray:
    alive = np.asarray(alive, dtype=bool)
    if alive.ndim != 2:
        raise ValueError(f"alive must be (trials, m), got {alive.shape}")
    if alive.shape[1] != m:
        raise ValueError(f"alive has {alive.shape[1]} machines, wanted {m}")
    return alive


def batched_optimal_alpha_graph(graph: Graph, alive, *,
                                backend: str = "auto", labels0=None,
                                return_labels: bool = False):
    """alpha* (trials, n) for a (trials, m) batch of masks over one graph.

    backend: 'numpy' | 'jax' | 'auto' (jax for large batches when
    available; the first jax call per (graph, trials) shape pays a jit
    compile).

    ``labels0`` warm-starts the label propagation with the (trials, 2n)
    cover labels of a *previous* decode whose masks were subsets of
    ``alive`` (per trial) -- the sweep engine's nested-in-p protocol.
    Any seed satisfying that containment leaves the fixed point (and
    hence alpha) bit-identical to a cold start; it only cuts rounds.
    ``return_labels=True`` additionally returns the fixed-point labels
    so the caller can seed the next grid point.
    """
    alive = _check_masks(alive, graph.m)
    trials = alive.shape[0]
    n = graph.n
    if trials == 0:
        out = np.zeros((0, n), dtype=np.float64)
        if return_labels:
            return out, np.zeros((0, 2 * n), dtype=_label_dtype(n))
        return out
    if backend == "auto":
        backend = ("jax" if _HAS_JAX and alive.size >= _JAX_MIN_WORK
                   else "numpy")
    if backend == "jax" and not _HAS_JAX:
        raise RuntimeError("jax backend requested but jax is missing")
    if backend not in ("jax", "numpy"):
        raise ValueError(f"unknown backend {backend!r}")
    if labels0 is not None:
        labels0 = _check_labels0(labels0, trials, n)
    # Chunk the batch so the (T, 2n, deg_max) gather stays in-cache-ish
    # and bounded in memory (~200 MB of int32 per intermediate).
    deg_max = _cover_dense(graph)[0].shape[1]
    chunk = max(1, int(5e7) // max(2 * n * deg_max, 1))
    ldt = _label_dtype(n)
    out = np.empty((trials, n), dtype=np.float64)
    out_labels = (np.empty((trials, 2 * n), dtype=ldt)
                  if return_labels else None)
    for lo in range(0, trials, chunk):
        part = alive[lo:lo + chunk]
        part_l0 = None if labels0 is None else labels0[lo:lo + chunk]
        if backend == "jax":
            run_cold, run_warm = _jax_propagator(graph)
            if part_l0 is None:
                labels = np.asarray(run_cold(jnp.asarray(part)))
            else:
                labels = np.asarray(run_warm(jnp.asarray(part),
                                             jnp.asarray(part_l0)))
        else:
            labels = _propagate_numpy(graph, part, part_l0)
        out[lo:lo + chunk] = _alpha_from_labels(labels, n)
        if out_labels is not None:
            out_labels[lo:lo + chunk] = labels
    if return_labels:
        return out, out_labels
    return out


def fixed_scale(d: float, p: float) -> float:
    """The Section VIII fixed-decoding coefficient 1/(d (1-p)).

    The single definition (validation included) shared by every fixed
    decoder -- scalar, batched, and the stacked grid -- whose
    bit-identity contract depends on this expression being evaluated
    identically everywhere."""
    if p >= 1.0:
        raise ValueError(f"fixed decoding requires p < 1, got p={p}")
    return 1.0 / (d * (1.0 - p))


def fixed_w(alive, d: float, p: float) -> np.ndarray:
    """Section VIII fixed weights: 1/(d (1-p)) on survivors, 0 on
    stragglers. ``alive`` may be a single (m,) mask or a (trials, m)
    batch; shared by the scalar and batched fixed decoders."""
    return np.where(alive, fixed_scale(d, p), 0.0)


def counts_are_exact(assignment: Assignment) -> bool:
    """True when every entry of A is a small nonnegative integer, so
    ``alive @ A.T`` runs entirely in exactly-representable integers:
    the sum is then independent of summation order / BLAS blocking, and
    a stacked (P*trials, m) grid matmul is bit-identical to per-point
    (or per-mask) matmuls. Every shipped scheme (graph / FRC /
    adjacency / Bernoulli / uncoded) satisfies this; the guard keeps a
    hypothetical weighted assignment on the order-sensitive path.
    The O(n*m) scan is cached on the assignment
    (``Assignment.integer_matrix``)."""
    return assignment.integer_matrix


def batched_fixed_alpha(assignment: Assignment, alive,
                        p: float) -> np.ndarray:
    """Section VIII fixed decoding for a batch: alpha = A w with
    w = 1/(d (1-p)) on survivors -- evaluated count-first
    (``(alive @ A.T) * c``, exact integer counts) for integer A so the
    result is batching-invariant; see ``decoding.fixed_decode``."""
    alive = _check_masks(alive, assignment.m)
    if not counts_are_exact(assignment):
        w = fixed_w(alive, assignment.replication_factor, p)
        return w @ assignment.A.T
    c = fixed_scale(assignment.replication_factor, p)
    return (alive.astype(np.float64) @ assignment.A.T) * c


def fixed_alpha_grid(assignment: Assignment, masks,
                     p_grid) -> np.ndarray:
    """Fixed decoding for a whole (P, trials, m) mask grid in ONE
    stacked counts matmul: alpha[i] = (masks[i] @ A.T) / (d (1-p_i)).

    Bit-identical to ``batched_fixed_alpha(A, masks[i], p_grid[i])``
    per point because the counts matmul is exact integer arithmetic
    (order-independent); the stacked (P*trials, m) GEMM is what makes
    the campaign's fixed path ~P times cheaper than the per-point loop
    (one well-blocked BLAS call instead of P skinny ones).
    """
    masks = np.asarray(masks, dtype=bool)
    if masks.ndim != 3 or masks.shape[2] != assignment.m:
        raise ValueError(f"masks must be (P, trials, {assignment.m}), "
                         f"got {masks.shape}")
    P, trials, m = masks.shape
    if len(p_grid) != P:
        raise ValueError(f"p_grid has {len(p_grid)} entries for {P} "
                         "mask batches")
    if not counts_are_exact(assignment):
        return np.stack([batched_fixed_alpha(assignment, masks[i],
                                             float(p_grid[i]))
                         for i in range(P)])
    d = assignment.replication_factor
    scales = np.asarray([fixed_scale(d, float(p)) for p in p_grid])
    counts = (masks.reshape(P * trials, m).astype(np.float64)
              @ assignment.A.T).reshape(P, trials, assignment.n)
    return counts * scales[:, None, None]


def batched_frc_alpha(assignment: Assignment, alive) -> np.ndarray:
    """FRC closed-form optimum for a batch: block survives (alpha = 1)
    iff any machine in its group survives."""
    alive = _check_masks(alive, assignment.m)
    counts = alive.astype(np.float64) @ (assignment.A > 0).T
    return (counts > 0).astype(np.float64)


def frc_alpha_grid(assignment: Assignment, masks) -> np.ndarray:
    """FRC closed form for a (P, trials, m) grid in one stacked counts
    matmul; bit-identical to per-point ``batched_frc_alpha`` (exact
    integer counts, thresholded)."""
    masks = np.asarray(masks, dtype=bool)
    if masks.ndim != 3 or masks.shape[2] != assignment.m:
        raise ValueError(f"masks must be (P, trials, {assignment.m}), "
                         f"got {masks.shape}")
    P, trials, m = masks.shape
    counts = (masks.reshape(P * trials, m).astype(np.float64)
              @ (assignment.A > 0).T)
    return (counts > 0).astype(np.float64).reshape(P, trials,
                                                   assignment.n)


def batched_alpha(assignment: Assignment, alive, *,
                  method: str = "optimal", p: float = 0.0,
                  backend: str = "auto", labels0=None,
                  return_labels: bool = False) -> np.ndarray:
    """Batched mirror of ``decoding.decode`` returning alphas (trials, n).

    Dispatch matches the scalar path exactly: Def II.2 graph schemes use
    the batched component decoder, FRCs their closed form, everything
    else falls back to a per-trial pseudoinverse.

    ``labels0`` / ``return_labels`` expose the graph decoder's
    warm-start label protocol (see ``batched_optimal_alpha_graph``)
    through the dispatching entry point, so multi-scheme pipelines (the
    sweep campaign) can chain labels per scheme without special-casing
    graph schemes at every call site. Non-graph schemes have no label
    state: ``labels0`` must be None there, and ``return_labels=True``
    returns ``(alphas, None)``.
    """
    alive = _check_masks(alive, assignment.m)
    graph = method == "optimal" and is_graph_scheme(assignment)
    if not graph and labels0 is not None:
        raise ValueError("labels0 is only meaningful for optimal "
                         "decoding of graph schemes (no label state "
                         f"for {assignment.name!r}/{method!r})")
    if graph:
        return batched_optimal_alpha_graph(
            assignment.graph, alive, backend=backend, labels0=labels0,
            return_labels=return_labels)
    if method == "fixed":
        out = batched_fixed_alpha(assignment, alive, p)
    elif method != "optimal":
        raise ValueError(f"unknown method {method!r}")
    elif assignment.name.startswith("frc"):
        out = batched_frc_alpha(assignment, alive)
    else:
        from .decoding import optimal_decode_pinv  # lazy: import cycle

        if alive.shape[0] == 0:
            out = np.zeros((0, assignment.n), dtype=np.float64)
        else:
            out = np.stack(
                [optimal_decode_pinv(assignment, a).alpha for a in alive])
    return (out, None) if return_labels else out
