"""Proposition B.1: black-box debiasing of any assignment scheme.

Given any (A, w) scheme with (1/N) E|alpha - 1|^2 <= eps, construct
(A-hat, w) with E[alpha-hat] = 1 at the cost of at most doubling the
computational load: keep the rows with E[alpha_i] >= delta = 1 -
sqrt(2 eps), rescale each row i by 1/E[alpha_i], and re-fill the dropped
rows by duplicating the first t retained rows.
"""

from __future__ import annotations

from typing import Callable

import numpy as np

from .assignment import Assignment


def estimate_mean_alpha(assignment: Assignment,
                        decode_fn: Callable[[np.ndarray], np.ndarray],
                        p: float, trials: int = 200,
                        seed: int = 0) -> np.ndarray:
    """Monte-Carlo E[alpha] under Bernoulli(p) stragglers; decode_fn maps
    an alive mask to alpha."""
    rng = np.random.default_rng(seed)
    acc = np.zeros(assignment.n, dtype=np.float64)
    for _ in range(trials):
        alive = rng.random(assignment.m) >= p
        acc += decode_fn(alive)
    return acc / trials


def debias_assignment(assignment: Assignment, mean_alpha: np.ndarray,
                      eps: float) -> Assignment:
    """Prop B.1 construction. ``mean_alpha`` is E[alpha] (exact or
    estimated); ``eps`` the normalized decoding error bound."""
    if eps >= 0.5:
        raise ValueError("Prop B.1 needs eps < 1/2")
    delta = 1.0 - np.sqrt(2.0 * eps)
    keep = np.nonzero(mean_alpha >= delta)[0]
    n = assignment.n
    if keep.size < (n + 1) // 2:
        raise ValueError(
            f"only {keep.size}/{n} rows have E[alpha] >= {delta:.3f}; "
            "eps bound violated")
    D = 1.0 / mean_alpha[keep]
    A_s = assignment.A[keep] * D[:, None]
    t = n - keep.size
    A_hat = np.vstack([A_s, A_s[:t]])
    return Assignment(A=A_hat, name=assignment.name + "+debiased",
                      graph=None)
