"""Algorithms 2 & 3: coded gradient descent (logical view).

``GCOD`` simulates Algorithm 2 exactly: at each round a straggler mask is
sampled, the parameter server decodes w*, and the update uses
sum_j w*_j g_j. ``sgd_alg`` is Algorithm 3, the stochastically equivalent
form parameterised by the distribution of alpha, used for the m=6552
simulations in Section VIII-B.

This module is the *single-host* reference; the multi-pod shard_map
runtime in ``repro.dist.coded_train`` implements the same update on a
device mesh and is tested against this.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import numpy as np

from .assignment import Assignment
from .batched_decoding import batched_alpha
from .step_weights import sample_mask_stream as _sample_mask_stream
from .stragglers import StragglerModel, BernoulliStragglers


@dataclasses.dataclass
class LeastSquares:
    """min_theta |X theta - Y|_2^2 partitioned into n blocks (Section
    VIII data model). f_i = sum over block i of (x^T theta - y)^2."""

    X: np.ndarray
    Y: np.ndarray
    n_blocks: int

    def __post_init__(self):
        N = self.X.shape[0]
        if N % self.n_blocks:
            raise ValueError("n_blocks must divide N")
        self.block_size = N // self.n_blocks

    @classmethod
    def synthetic(cls, N: int, k: int, noise: float, n_blocks: int,
                  seed: int = 0) -> "LeastSquares":
        rng = np.random.default_rng(seed)
        X = rng.normal(size=(N, k)) / np.sqrt(k)
        theta = rng.normal(size=k)
        Y = X @ theta + noise * rng.normal(size=N)
        return cls(X=X, Y=Y, n_blocks=n_blocks)

    def minimizer(self) -> np.ndarray:
        return np.linalg.lstsq(self.X, self.Y, rcond=None)[0]

    def block_gradients(self, theta: np.ndarray) -> np.ndarray:
        """(n_blocks, k) matrix of per-block gradients of f_i."""
        resid = self.X @ theta - self.Y  # (N,)
        per_point = 2.0 * self.X * resid[:, None]  # (N, k)
        return per_point.reshape(self.n_blocks, self.block_size, -1).sum(1)

    def loss(self, theta: np.ndarray) -> float:
        return float(np.sum((self.X @ theta - self.Y) ** 2))


@dataclasses.dataclass
class GDTrace:
    thetas: List[np.ndarray]
    errors: List[float]  # |theta_t - theta*|^2
    alphas: List[np.ndarray]


def precompute_alphas(assignment: Assignment,
                      straggler_model: StragglerModel, *, steps: int,
                      method: str = "optimal", p: float = 0.0,
                      shuffle: bool = True, seed: int = 0,
                      backend: str = "auto") -> np.ndarray:
    """Sample the exact mask stream ``gcod(..., shuffle=shuffle,
    seed=seed)`` would consume and decode it in one batched call.

    Feeding the result back via ``gcod(..., alphas=...)`` reproduces the
    sampling-in-the-loop run bit-for-bit while skipping per-step
    decoding -- useful when the same (assignment, model, seed) trace is
    re-run across a step-size grid, as the Figure 4/5 harness does.
    """
    rng = np.random.default_rng(seed)
    _, masks = _sample_mask_stream(assignment, straggler_model,
                                   steps=steps, shuffle=shuffle, rng=rng)
    return batched_alpha(assignment, masks, method=method, p=p,
                         backend=backend)


def gcod(problem: LeastSquares, assignment: Assignment,
         straggler_model: StragglerModel, *, steps: int, lr: float,
         method: str = "optimal", p: float = 0.0,
         shuffle: bool = True, seed: int = 0,
         theta0: Optional[np.ndarray] = None,
         lr_schedule: Optional[Callable[[int], float]] = None,
         alphas: Optional[np.ndarray] = None,
         backend: str = "auto") -> GDTrace:
    """Algorithm 2 (GCOD). ``method`` selects optimal vs fixed decoding;
    ``shuffle`` applies the random block permutation rho.

    All straggler masks are sampled up front and decoded by the batched
    engine (the straggler model only touches the RNG while sampling, so
    this reorders nothing). ``alphas`` (steps, n) bypasses sampling and
    decoding entirely -- see ``precompute_alphas``.
    """
    rng = np.random.default_rng(seed)
    n = assignment.n
    if problem.n_blocks != n:
        raise ValueError("problem blocks must match assignment rows")
    # With precomputed alphas no masks are drawn (steps=0), leaving the
    # rho draw -- and hence the trajectory -- identical either way.
    rho, masks = _sample_mask_stream(
        assignment, straggler_model, shuffle=shuffle, rng=rng,
        steps=steps if alphas is None else 0)
    if alphas is None:
        alphas = batched_alpha(assignment, masks, method=method, p=p,
                               backend=backend)
    else:
        alphas = np.asarray(alphas, dtype=np.float64)
        if alphas.shape != (steps, n):
            raise ValueError(
                f"alphas must be ({steps}, {n}), got {alphas.shape}")
    theta_star = problem.minimizer()
    theta = np.zeros(problem.X.shape[1]) if theta0 is None else theta0.copy()
    trace = GDTrace(thetas=[theta.copy()],
                    errors=[float(np.sum((theta - theta_star) ** 2))],
                    alphas=[])
    for t in range(steps):
        alpha = alphas[t]
        # alpha acts on shuffled blocks: block rho(i) receives alpha_i.
        block_grads = problem.block_gradients(theta)  # (n, k)
        g = (alpha[:, None] * block_grads[rho]).sum(axis=0)
        step = lr if lr_schedule is None else lr_schedule(t)
        theta = theta - step * g
        trace.thetas.append(theta.copy())
        trace.errors.append(float(np.sum((theta - theta_star) ** 2)))
        trace.alphas.append(alpha.copy())
    return trace


def uncoded_gd(problem: LeastSquares, m: int, p: float, *, steps: int,
               lr: float, seed: int = 0,
               lr_schedule: Optional[Callable[[int], float]] = None,
               alphas: Optional[np.ndarray] = None) -> GDTrace:
    """Ignore-stragglers baseline: m machines, one block each, surviving
    gradients summed with weight 1/(1-p) (unbiased)."""
    from .assignment import uncoded_assignment

    assignment = uncoded_assignment(m)
    model = BernoulliStragglers(m=m, p=p)
    return gcod(problem, assignment, model, steps=steps, lr=lr,
                method="fixed", p=p, seed=seed, lr_schedule=lr_schedule,
                alphas=alphas)


def sgd_alg(problem: LeastSquares,
            sample_beta: Optional[
                Callable[[np.random.Generator], np.ndarray]] = None, *,
            steps: int, lr: float, shuffle: bool = True, seed: int = 0,
            lr_schedule: Optional[Callable[[int], float]] = None,
            betas: Optional[np.ndarray] = None) -> GDTrace:
    """Algorithm 3 (SGD-ALG): update with externally supplied beta
    draws. Stochastically equivalent to GCOD when beta ~ P_{alpha*}.

    Betas come either from ``sample_beta`` (one draw per step, as
    before) or as a precomputed ``betas`` (steps, n) batch, e.g. from
    ``precompute_alphas`` / ``batched_alpha``.
    """
    if (sample_beta is None) == (betas is None):
        raise ValueError("provide exactly one of sample_beta / betas")
    if betas is not None:
        betas = np.asarray(betas, dtype=np.float64)
        if betas.shape != (steps, problem.n_blocks):
            raise ValueError(
                f"betas must be ({steps}, {problem.n_blocks}), "
                f"got {betas.shape}")
    rng = np.random.default_rng(seed)
    n = problem.n_blocks
    rho = rng.permutation(n) if shuffle else np.arange(n)
    theta_star = problem.minimizer()
    theta = np.zeros(problem.X.shape[1])
    trace = GDTrace(thetas=[theta.copy()],
                    errors=[float(np.sum((theta - theta_star) ** 2))],
                    alphas=[])
    for t in range(steps):
        beta = betas[t] if betas is not None else sample_beta(rng)
        block_grads = problem.block_gradients(theta)
        g = (beta[:, None] * block_grads[rho]).sum(axis=0)
        step = lr if lr_schedule is None else lr_schedule(t)
        theta = theta - step * g
        trace.thetas.append(theta.copy())
        trace.errors.append(float(np.sum((theta - theta_star) ** 2)))
        trace.alphas.append(beta)
    return trace
