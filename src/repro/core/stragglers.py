"""Straggler process models.

The paper analyses two models (Defs I.2 / I.3) and empirically observes a
third (Section VIII: "which machines are straggling tends to stay
stagnant throughout a run"):

- ``BernoulliStragglers``  : each machine straggles i.i.d. w.p. p.
- ``AdversarialStragglers``: worst-case |S| <= pm, instantiated with the
  attacks that achieve the known worst cases per scheme.
- ``MarkovStragglers``     : stagnant/bursty process matching the
  cluster observation; used to show why expander codes beat the FRC on
  real clusters even though the FRC is optimal for i.i.d. stragglers.

All models emit an ``alive`` boolean mask of shape (m,): True = machine
responded in time.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .assignment import Assignment


class StragglerModel:
    def sample(self, rng: np.random.Generator) -> np.ndarray:
        raise NotImplementedError


@dataclasses.dataclass
class BernoulliStragglers(StragglerModel):
    m: int
    p: float

    def sample(self, rng: np.random.Generator) -> np.ndarray:
        return rng.random(self.m) >= self.p


@dataclasses.dataclass
class FixedCountStragglers(StragglerModel):
    """Exactly floor(pm) uniformly random stragglers (the |S| <= pm
    budget of Def I.3 with a random, non-adversarial S)."""

    m: int
    p: float

    def sample(self, rng: np.random.Generator) -> np.ndarray:
        # Clamp: p >= 1 (every machine straggling) must yield the
        # all-dead mask, not an over-sized choice() draw.
        s = min(int(np.floor(self.p * self.m)), self.m)
        alive = np.ones(self.m, dtype=bool)
        alive[rng.choice(self.m, size=s, replace=False)] = False
        return alive


@dataclasses.dataclass
class MarkovStragglers(StragglerModel):
    """Two-state Markov chain per machine with stationary straggle
    probability p and mean sojourn ``persistence`` steps: stagnant
    stragglers, matching the paper's cluster observation."""

    m: int
    p: float
    persistence: float = 10.0
    _state: Optional[np.ndarray] = None

    def sample(self, rng: np.random.Generator) -> np.ndarray:
        # Transition rates chosen so the stationary distribution is
        # (1-p, p) and the straggling state persists ~``persistence``.
        leave_straggle = 1.0 / self.persistence
        enter_straggle = leave_straggle * self.p / max(1.0 - self.p, 1e-9)
        if self._state is None:
            self._state = rng.random(self.m) < self.p  # True = straggling
        u = rng.random(self.m)
        nxt = np.where(self._state, u >= leave_straggle,
                       u < enter_straggle)
        self._state = nxt
        return ~nxt


@dataclasses.dataclass
class AdversarialStragglers(StragglerModel):
    """Def I.3 as a *process*: every step replays the worst-case
    |S| <= pm attack for the carried assignment (the adversary knows the
    scheme and has no reason to move). Wraps ``adversarial_mask`` so the
    attack plugs into the same ``sample(rng)`` protocol the stochastic
    models use; the RNG is accepted and ignored."""

    assignment: Assignment
    p: float
    _mask: Optional[np.ndarray] = None

    def sample(self, rng: np.random.Generator) -> np.ndarray:
        if self._mask is None:
            self._mask = adversarial_mask(self.assignment, self.p)
        return self._mask.copy()


# ---------------------------------------------------------------------------
# Adversarial attacks (Def I.3 instantiations)
# ---------------------------------------------------------------------------


def adversarial_mask_graph(assignment: Assignment, p: float) -> np.ndarray:
    """Worst-case-style attack on a graph scheme (Remark V.4): isolate
    floor(pm / d) vertices by straggling every edge incident to them,
    choosing greedily to respect the budget."""
    g = assignment.graph
    if g is None:
        raise ValueError("graph attack needs a graph assignment")
    budget = int(np.floor(p * g.m))
    inc = g.incident_edges()
    dead = np.zeros(g.m, dtype=bool)
    spent = 0
    # Greedy: repeatedly kill the vertex whose remaining live edges are
    # fewest (cheapest full isolation next).
    order = np.argsort([len(e) for e in inc])
    for v in order:
        cost = sum(1 for j in inc[v] if not dead[j])
        if spent + cost > budget:
            continue
        for j in inc[v]:
            dead[j] = True
        spent += cost
    # Spend any remainder arbitrarily (extra stragglers never help A).
    for j in range(g.m):
        if spent >= budget:
            break
        if not dead[j]:
            dead[j] = True
            spent += 1
    return ~dead


def adversarial_mask_frc(assignment: Assignment, p: float) -> np.ndarray:
    """Worst case for the FRC: straggle whole groups of d machines, each
    erasing one block entirely -- error pm/d blocks out of n = m/d,
    i.e. normalized error p (Table I)."""
    A = assignment.A
    n, m = A.shape
    budget = int(np.floor(p * m))
    alive = np.ones(m, dtype=bool)
    spent = 0
    for i in range(n):
        js = np.nonzero(A[i])[0]
        if spent + js.size > budget:
            break
        alive[js] = False
        spent += js.size
    return alive


def _mask_error(assignment: Assignment, alive: np.ndarray) -> float:
    """Normalized optimal-decoding error of one mask -- the objective
    the search attacks below maximise. Local import: ``decoding``
    imports this module's consumers."""
    from .decoding import decode, normalized_error

    return normalized_error(
        decode(assignment, alive, method="optimal").alpha)


def adversarial_mask_cyclic(assignment: Assignment, p: float) -> np.ndarray:
    """Attack portfolio for cyclic/shifted schemes (Raviv et al.):
    the worst straggler set is either a *consecutive window* (which
    fully erases window-minus-d+1 blocks once the budget exceeds the
    shift width -- the attack that breaks MDS-style cyclic codes) or
    an *arithmetic progression* (spread kills maximise per-block
    damage at small budgets). Both families are enumerated -- O(m)
    candidate masks, one decode each -- and the worst is returned;
    exact against the C(m, pm) brute-force oracle on every small-m
    case pinned in tests/test_adversarial_oracle.py."""
    m = assignment.m
    budget = int(np.floor(p * m))
    if budget == 0:
        return np.ones(m, dtype=bool)
    candidates = [[j % m for j in range(budget)]]  # consecutive window
    for stride in range(2, m // budget + 1):
        dead = [(j * stride) % m for j in range(budget)]
        if len(set(dead)) == budget:
            candidates.append(dead)
    best_mask, best_err = None, -1.0
    for dead in candidates:
        alive = np.ones(m, dtype=bool)
        alive[dead] = False
        e = _mask_error(assignment, alive)
        if e > best_err:
            best_mask, best_err = alive, e
    return best_mask


def adversarial_mask_bibd(assignment: Assignment, p: float) -> np.ndarray:
    """Marginal-error greedy attack for block-design schemes (Kadhe et
    al.): grow the straggler set one machine at a time, each round
    killing the machine whose removal maximises the realized decoding
    error. O(budget * m) decodes; exact against the brute-force
    oracle on every small design pinned in
    tests/test_adversarial_oracle.py (the pairwise balance that makes
    BIBDs adversarially strong also flattens the search landscape)."""
    m = assignment.m
    budget = int(np.floor(p * m))
    alive = np.ones(m, dtype=bool)
    for _ in range(budget):
        best_j, best_err = None, -1.0
        for j in np.nonzero(alive)[0]:
            alive[j] = False
            e = _mask_error(assignment, alive)
            alive[j] = True
            if e > best_err:
                best_j, best_err = j, e
        alive[best_j] = False
    return alive


def adversarial_mask(assignment: Assignment, p: float) -> np.ndarray:
    if assignment.graph is not None:
        return adversarial_mask_graph(assignment, p)
    if assignment.name.startswith("frc"):
        return adversarial_mask_frc(assignment, p)
    if assignment.name.startswith("cyclic_mds"):
        return adversarial_mask_cyclic(assignment, p)
    if assignment.name.startswith("bibd"):
        return adversarial_mask_bibd(assignment, p)
    # Generic greedy: kill machines covering the rarest blocks first.
    A = assignment.A
    m = A.shape[1]
    budget = int(np.floor(p * m))
    replication = A.sum(axis=1)
    machine_score = (A / np.maximum(replication[:, None], 1)).sum(axis=0)
    order = np.argsort(-machine_score)
    alive = np.ones(m, dtype=bool)
    alive[order[:budget]] = False
    return alive
