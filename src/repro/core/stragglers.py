"""Straggler process models.

The paper analyses two models (Defs I.2 / I.3) and empirically observes a
third (Section VIII: "which machines are straggling tends to stay
stagnant throughout a run"):

- ``BernoulliStragglers``  : each machine straggles i.i.d. w.p. p.
- ``AdversarialStragglers``: worst-case |S| <= pm, instantiated with the
  attacks that achieve the known worst cases per scheme.
- ``MarkovStragglers``     : stagnant/bursty process matching the
  cluster observation; used to show why expander codes beat the FRC on
  real clusters even though the FRC is optimal for i.i.d. stragglers.

All models emit an ``alive`` boolean mask of shape (m,): True = machine
responded in time.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .assignment import Assignment


class StragglerModel:
    def sample(self, rng: np.random.Generator) -> np.ndarray:
        raise NotImplementedError


@dataclasses.dataclass
class BernoulliStragglers(StragglerModel):
    m: int
    p: float

    def sample(self, rng: np.random.Generator) -> np.ndarray:
        return rng.random(self.m) >= self.p


@dataclasses.dataclass
class FixedCountStragglers(StragglerModel):
    """Exactly floor(pm) uniformly random stragglers (the |S| <= pm
    budget of Def I.3 with a random, non-adversarial S)."""

    m: int
    p: float

    def sample(self, rng: np.random.Generator) -> np.ndarray:
        # Clamp: p >= 1 (every machine straggling) must yield the
        # all-dead mask, not an over-sized choice() draw.
        s = min(int(np.floor(self.p * self.m)), self.m)
        alive = np.ones(self.m, dtype=bool)
        alive[rng.choice(self.m, size=s, replace=False)] = False
        return alive


@dataclasses.dataclass
class MarkovStragglers(StragglerModel):
    """Two-state Markov chain per machine with stationary straggle
    probability p and mean sojourn ``persistence`` steps: stagnant
    stragglers, matching the paper's cluster observation."""

    m: int
    p: float
    persistence: float = 10.0
    _state: Optional[np.ndarray] = None

    def sample(self, rng: np.random.Generator) -> np.ndarray:
        # Transition rates chosen so the stationary distribution is
        # (1-p, p) and the straggling state persists ~``persistence``.
        leave_straggle = 1.0 / self.persistence
        enter_straggle = leave_straggle * self.p / max(1.0 - self.p, 1e-9)
        if self._state is None:
            self._state = rng.random(self.m) < self.p  # True = straggling
        u = rng.random(self.m)
        nxt = np.where(self._state, u >= leave_straggle,
                       u < enter_straggle)
        self._state = nxt
        return ~nxt


@dataclasses.dataclass
class AdversarialStragglers(StragglerModel):
    """Def I.3 as a *process*: every step replays the worst-case
    |S| <= pm attack for the carried assignment (the adversary knows the
    scheme and has no reason to move). Wraps ``adversarial_mask`` so the
    attack plugs into the same ``sample(rng)`` protocol the stochastic
    models use; the RNG is accepted and ignored."""

    assignment: Assignment
    p: float
    _mask: Optional[np.ndarray] = None

    def sample(self, rng: np.random.Generator) -> np.ndarray:
        if self._mask is None:
            self._mask = adversarial_mask(self.assignment, self.p)
        return self._mask.copy()


# ---------------------------------------------------------------------------
# Adversarial attacks (Def I.3 instantiations)
# ---------------------------------------------------------------------------


def adversarial_mask_graph(assignment: Assignment, p: float) -> np.ndarray:
    """Worst-case-style attack on a graph scheme (Remark V.4): isolate
    floor(pm / d) vertices by straggling every edge incident to them,
    choosing greedily to respect the budget."""
    g = assignment.graph
    if g is None:
        raise ValueError("graph attack needs a graph assignment")
    budget = int(np.floor(p * g.m))
    inc = g.incident_edges()
    dead = np.zeros(g.m, dtype=bool)
    spent = 0
    # Greedy: repeatedly kill the vertex whose remaining live edges are
    # fewest (cheapest full isolation next).
    order = np.argsort([len(e) for e in inc])
    for v in order:
        cost = sum(1 for j in inc[v] if not dead[j])
        if spent + cost > budget:
            continue
        for j in inc[v]:
            dead[j] = True
        spent += cost
    # Spend any remainder arbitrarily (extra stragglers never help A).
    for j in range(g.m):
        if spent >= budget:
            break
        if not dead[j]:
            dead[j] = True
            spent += 1
    return ~dead


def adversarial_mask_frc(assignment: Assignment, p: float) -> np.ndarray:
    """Worst case for the FRC: straggle whole groups of d machines, each
    erasing one block entirely -- error pm/d blocks out of n = m/d,
    i.e. normalized error p (Table I)."""
    A = assignment.A
    n, m = A.shape
    budget = int(np.floor(p * m))
    alive = np.ones(m, dtype=bool)
    spent = 0
    for i in range(n):
        js = np.nonzero(A[i])[0]
        if spent + js.size > budget:
            break
        alive[js] = False
        spent += js.size
    return alive


def adversarial_mask(assignment: Assignment, p: float) -> np.ndarray:
    if assignment.graph is not None:
        return adversarial_mask_graph(assignment, p)
    if assignment.name.startswith("frc"):
        return adversarial_mask_frc(assignment, p)
    # Generic greedy: kill machines covering the rarest blocks first.
    A = assignment.A
    m = A.shape[1]
    budget = int(np.floor(p * m))
    replication = A.sum(axis=1)
    machine_score = (A / np.maximum(replication[:, None], 1)).sum(axis=0)
    order = np.argsort(-machine_score)
    alive = np.ones(m, dtype=bool)
    alive[order[:budget]] = False
    return alive
