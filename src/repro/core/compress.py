"""Gradient compression codecs composed with the coded combine.

ROADMAP open item: compose the paper's straggler code with gradient
compression and study the error interaction. The machines' messages
``g_j`` are quantized before the decode-weighted combine
``sum_j w_j g_j``; the combine itself then runs directly on the
quantized payload (``kernels.coded_combine.quantized_combine``
dequantizes, w-weights and reduces in one pass), so the d-fold comms
tax of replication shrinks by the codec's wire ratio.

Codecs (per-tensor symmetric, one scale per machine per leaf):

* ``none``  -- float32 passthrough (scale 1): the oracle the quantized
  path is differential-tested against, and the float32 comm baseline.
* ``int8``  -- symmetric round-to-nearest-even onto [-127, 127] with
  scale = amax * (1/127) (amax = 0 rows keep scale 1 so q = 0 exactly).
* ``sign``  -- signSGD with the L1 scale of Bernstein et al.
  (arXiv:1802.04434): payload sign(g), scale = mean|g|. 1 bit of
  information per component; the wire container here is int8 (the
  smallest TPU-native dtype -- bit-packing is a transport-layer detail
  the ``bits``/``wire_bits`` split keeps honest).
* ``sign_packed`` -- the same sign/mean-|g| semantics with the
  transport-layer detail actually paid for: 8 signs per uint8 byte
  (little-endian bit order, bit=1 <-> +1, so ``np.unpackbits(...,
  bitorder="little")`` is an independent unpacker), taking the wire
  ratio from sign's 0.25x float32 to ~0.031x. The packed payload's
  trailing byte is zero-padded, so ``decompress`` needs the true
  component count ``d``; it differs from ``sign`` only at exact zeros
  (packed maps 0 -> +1 where sign ships 0), which error feedback
  absorbs like any other quantization residual.

Every codec is written once over a generic array namespace ``xp`` and
exposed for both jnp (on-device, inside the jitted train step) and
numpy (the host-side round-trip reference the property tests pin
against): the int8 round/clip/cast chain is elementwise IEEE and
matches bitwise across the two; the sign codec's mean reduction is
summation-order sensitive, so only it carries a tolerance.

Error feedback
--------------
``init_state`` allocates the per-machine residual pytree that rides
alongside ``opt_state`` (and is checkpointed with it): each step
compresses ``g_t + e_t`` and keeps ``e_{t+1} = g_t + e_t - dequant``.
The telescoping identity ``sum_t dequant_t = sum_t g_t + e_0 - e_T``
(pinned in tests/test_compress.py) is what turns the biased sign codec
into a convergent method, and carrying ``e`` in the checkpoint is what
keeps resumed runs bit-identical.

``compression_campaign`` is the error-vs-p-vs-bits grid the source
paper does not have: the decoding-error floor of each straggler
probability composed with each codec's quantization noise, plus
majority-vote signSGD (fixed all-alive voting, no decoding weights) as
the degenerate fixed-decoding entry.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import step_weights as sw
from .assignment import Assignment
from .sweep import bernoulli_uniforms


# ---------------------------------------------------------------------------
# Codecs (xp-generic: xp is jnp on device, np for the host reference)
# ---------------------------------------------------------------------------


def _none_compress(g, xp):
    g = g.astype(xp.float32)
    return g, xp.ones(g.shape[:-1], xp.float32)


def _none_decompress(q, scale, xp):
    return q.astype(xp.float32) * scale[..., None]


def _int8_compress(g, xp):
    g = g.astype(xp.float32)
    amax = xp.max(xp.abs(g), axis=-1)
    # amax * (1/127), NOT amax / 127: XLA strength-reduces division by
    # a compile-time constant into a reciprocal multiply that is
    # occasionally 1 ulp off the IEEE quotient, which would break the
    # np/jnp bitwise contract this codec carries. A multiply is
    # exactly rounded on both sides; the division by the *runtime*
    # scale below stays a true fdiv.
    scale = xp.where(amax > 0, amax * xp.float32(1.0 / 127.0),
                     xp.ones_like(amax)).astype(xp.float32)
    q = xp.clip(xp.round(g / scale[..., None]), -127, 127).astype(xp.int8)
    return q, scale


def _sign_compress(g, xp):
    g = g.astype(xp.float32)
    scale = xp.mean(xp.abs(g), axis=-1).astype(xp.float32)
    q = xp.sign(g).astype(xp.int8)
    return q, scale


def _q_decompress(q, scale, xp):
    return q.astype(xp.float32) * scale[..., None]


def packed_width(d: int) -> int:
    """Bytes needed to carry ``d`` sign bits (8 per byte, ceil)."""
    return (int(d) + 7) // 8


def pack_signs(bits, xp):
    """(..., D) {0,1} -> (..., ceil(D/8)) uint8, little-endian bits.

    Bit k of byte j carries component 8j + k; the trailing byte is
    zero-padded. Pure integer shift/mask arithmetic, so np and jnp
    agree bitwise (and ``np.unpackbits(..., bitorder="little")`` is an
    independent decoder the tests cross-check against).
    """
    d = bits.shape[-1]
    pad = (-d) % 8
    bits = bits.astype(xp.uint8)
    if pad:
        bits = xp.concatenate(
            [bits, xp.zeros(bits.shape[:-1] + (pad,), xp.uint8)], axis=-1)
    grouped = bits.reshape(bits.shape[:-1] + (packed_width(d), 8))
    weights = (xp.uint8(1) << xp.arange(8, dtype=xp.uint8))
    return (grouped * weights).sum(axis=-1).astype(xp.uint8)


def unpack_signs(q, xp, d: Optional[int] = None):
    """(..., B) uint8 -> (..., d) {0,1} uint8 (inverse of pack_signs)."""
    shifts = xp.arange(8, dtype=xp.uint8)
    bits = (q[..., :, None] >> shifts) & xp.uint8(1)
    bits = bits.reshape(q.shape[:-1] + (q.shape[-1] * 8,))
    return bits if d is None else bits[..., :d]


def _sign_packed_compress(g, xp):
    g = g.astype(xp.float32)
    scale = xp.mean(xp.abs(g), axis=-1).astype(xp.float32)
    q = pack_signs(g >= 0, xp)
    return q, scale


def _sign_packed_decompress(q, scale, xp, d=None):
    bits = unpack_signs(q, xp, d)
    signs = 2.0 * bits.astype(xp.float32) - 1.0
    return signs * scale[..., None]


@dataclasses.dataclass(frozen=True)
class Codec:
    """One compression scheme: rows-of-components -> (payload, scale).

    ``compress(g)`` takes (..., D) float and returns a payload ((..., D)
    int8 for the quantized codecs, float32 for 'none', (..., ceil(D/8))
    uint8 for the packed codec) plus a (...,) float32 per-row scale;
    ``decompress`` is the exact float32 round-trip ``payload * scale``.
    ``bits`` is the information content per component (the campaign's
    bits axis: 32 / 8 / 1); ``wire_bits`` is the container actually
    shipped (sign rides an int8 container on TPU; sign_packed pays the
    true 1 bit), which is what ``comm_bytes_per_step`` measures.
    ``packed`` codecs carry fewer payload elements than components, so
    their ``decompress`` takes the true component count ``d`` (the
    trailing byte is zero-padded).
    """

    name: str
    bits: int
    wire_bits: int
    _compress: Callable = dataclasses.field(repr=False, default=None)
    _decompress: Callable = dataclasses.field(repr=False, default=None)
    packed: bool = False

    def compress(self, g, xp=jnp):
        return self._compress(g, xp)

    def decompress(self, q, scale, xp=jnp, d=None):
        if self.packed:
            return self._decompress(q, scale, xp, d)
        return self._decompress(q, scale, xp)


CODECS: Dict[str, Codec] = {
    "none": Codec("none", bits=32, wire_bits=32,
                  _compress=_none_compress, _decompress=_none_decompress),
    "int8": Codec("int8", bits=8, wire_bits=8,
                  _compress=_int8_compress, _decompress=_q_decompress),
    "sign": Codec("sign", bits=1, wire_bits=8,
                  _compress=_sign_compress, _decompress=_q_decompress),
    "sign_packed": Codec("sign_packed", bits=1, wire_bits=1,
                         _compress=_sign_packed_compress,
                         _decompress=_sign_packed_decompress, packed=True),
}


def get_codec(name) -> Codec:
    if isinstance(name, Codec):
        return name
    try:
        return CODECS[name]
    except KeyError:
        raise ValueError(f"unknown codec {name!r} "
                         f"(one of {sorted(CODECS)})") from None


# ---------------------------------------------------------------------------
# Error-feedback residual state
# ---------------------------------------------------------------------------


def init_state(params, rows: int):
    """The error-feedback pytree that rides alongside opt_state.

    One float32 residual per (machine/block, parameter): leaves are
    (rows,) + param.shape, zero-initialised (e_0 = 0, so the first
    step compresses the raw gradient). ``rows`` is m on the
    replicated/manual paths and n on the dedup path.
    """
    if rows < 1:
        raise ValueError("rows must be >= 1")
    return {"residual": jax.tree.map(
        lambda p: jnp.zeros((rows,) + tuple(p.shape), jnp.float32),
        params)}


def comm_bytes_per_step(codec: Optional[Codec], rows: int, params) -> int:
    """Bytes the machines ship per step under ``codec``.

    ``None`` is the uncompressed baseline (full float32 gradients, no
    scale sideband); a codec pays ``wire_bits`` per component plus one
    float32 scale per (row, leaf), rounded up to whole bytes *per leaf*
    (each leaf is flattened and packed independently, so a sub-byte
    codec pads its trailing byte per leaf). A measured quantity in the
    sense that it counts the actual payload arrays the combine
    consumes -- not a model of a hypothetical transport.
    """
    leaves = jax.tree.leaves(params)
    if codec is None:
        total = sum(int(np.prod(leaf.shape)) for leaf in leaves)
        return rows * total * 4
    payload = sum(-(-int(np.prod(leaf.shape)) * codec.wire_bits // 8)
                  for leaf in leaves)
    return rows * (payload + len(leaves) * 4)


# ---------------------------------------------------------------------------
# Error-vs-p-vs-bits campaign
# ---------------------------------------------------------------------------


def compression_campaign(assignment: Assignment,
                         p_grid: Sequence[float], *,
                         codecs: Sequence[str] = ("none", "sign", "int8"),
                         trials: int = 200, dim: int = 512,
                         seed: int = 0, method: str = "optimal",
                         debias: bool = True,
                         majority_vote: bool = True) -> List[Dict]:
    """The error-vs-p-vs-bits grid: decoding error composed with
    quantization noise, on one shared straggler draw.

    Protocol: one ``bernoulli_uniforms`` draw shared across the whole
    grid (the sweep engine's common-random-numbers contract), one fixed
    synthetic gradient tableau G (n, dim) with target ``sum_i G_i``,
    machine messages ``g = A^T G``, and per codec the *precomputed*
    float round-trip ``dequant(quant(g))`` -- so every (codec, p) cell
    differs only in the decode weights and the codec, never the draw.

    Rows: {codec, bits, p, decoding, mean_error, std_error} with
    relative error ``|W_t qhat - target|^2 / |target|^2`` per trial.
    ``majority_vote=True`` appends the degenerate fixed-decoding entry
    per p: majority-vote signSGD (Bernstein et al. Alg. 2 with unit
    server weights over the alive machines, L1 target scale) -- the
    scheme the coded sign rows beat by replacing the vote with the
    paper's optimal decode.
    """
    p_list = [float(p) for p in p_grid]
    u = bernoulli_uniforms(assignment.m, trials, seed)
    rng = np.random.default_rng(seed + 1)
    G = rng.normal(size=(assignment.n, dim)) / np.sqrt(dim)
    target = G.sum(axis=0)
    tnorm = float((target ** 2).sum())
    g = (assignment.A.T @ G).astype(np.float32)        # (m, dim)

    deq = {}
    for cname in codecs:
        codec = get_codec(cname)
        q, s = codec.compress(g, xp=np)
        deq[cname] = np.asarray(
            codec.decompress(q, s, xp=np, d=g.shape[-1]), np.float64)
    mv_scale = float(np.abs(target).sum()) / dim
    sgn = np.sign(g).astype(np.float64)

    rows: List[Dict] = []
    for p in p_list:
        alive = u >= p
        scale = 1.0
        if debias and method == "optimal":
            scale = sw.debias_scale_mc(assignment, p=p, trials=trials,
                                       seed=seed + 0x5EED)
        W, _ = sw.batched_step_weights(assignment, alive, method=method,
                                       p=p, scale=scale)
        for cname in codecs:
            est = W @ deq[cname]                       # (trials, dim)
            errs = ((est - target) ** 2).sum(axis=1) / tnorm
            rows.append({"codec": cname, "bits": get_codec(cname).bits,
                         "p": p, "decoding": method,
                         "mean_error": float(errs.mean()),
                         "std_error": float(errs.std())})
        if majority_vote:
            est = mv_scale * np.sign(alive.astype(np.float64) @ sgn)
            errs = ((est - target) ** 2).sum(axis=1) / tnorm
            rows.append({"codec": "sign", "bits": 1, "p": p,
                         "decoding": "majority_vote",
                         "mean_error": float(errs.mean()),
                         "std_error": float(errs.std())})
    return rows
