"""Decoders: map a straggler mask to decoding coefficients w and alpha = A w.

The paper's central algorithmic contribution (Section III): for graph
assignment schemes, the *optimal* decoding coefficients

    w* = argmin_{w : w_j = 0 for stragglers} |A w - 1|_2

are computable in O(m) by analysing the connected components of the
sparsified graph G(p) (surviving machines = surviving edges):

  * non-bipartite component  -> alpha*_v = 1 everywhere;
  * bipartite component L|R (|L| >= |R|)
                             -> alpha*_v = 1 -/+ (|L|-|R|)/(|L|+|R|);
  * isolated vertex          -> alpha*_v = 0.

``w*`` itself is recovered by a spanning-tree back-substitution with one
symbolic unknown on an odd cycle (non-bipartite components only).

We also implement the general pseudoinverse decoder (Eq. 9) for
arbitrary assignment matrices, the fixed-coefficient decoder of
Section VIII, and the FRC closed-form optimal decoder.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from ..kernels.batched_alpha import ops as _ba_ops
from .assignment import Assignment
from .batched_decoding import (batched_alpha, counts_are_exact,
                               fixed_scale, fixed_w, is_graph_scheme)
from .graphs import Graph


@dataclasses.dataclass(frozen=True)
class DecodeResult:
    """w: (m,) decoding coefficients; alpha: (n,) effective block weights."""

    w: np.ndarray
    alpha: np.ndarray

    def error(self) -> float:
        """|alpha - 1|_2^2 (unnormalized decoding error)."""
        return float(np.sum((self.alpha - 1.0) ** 2))


# ---------------------------------------------------------------------------
# O(m) optimal decoder for graph schemes (Section III)
# ---------------------------------------------------------------------------


def _components_two_coloring(
    graph: Graph, alive: np.ndarray
) -> Tuple[np.ndarray, np.ndarray, List[bool], List[List[int]],
           List[Optional[int]]]:
    """BFS over surviving edges.

    Returns (comp_id, color, comp_bipartite, comp_vertices, odd_edge):
      comp_id[v]        component index of vertex v
      color[v]          BFS 2-coloring in {0, 1}
      comp_bipartite[c] True if component c is bipartite
      comp_vertices[c]  vertices of component c
      odd_edge[c]       index of one same-color ("odd") surviving edge
                        in component c, or None if bipartite
    """
    n = graph.n
    inc = graph.incident_edges()
    edges = graph.edges
    comp_id = np.full(n, -1, dtype=np.int64)
    color = np.zeros(n, dtype=np.int64)
    comp_bipartite: List[bool] = []
    comp_vertices: List[List[int]] = []
    odd_edge: List[Optional[int]] = []

    for s in range(n):
        if comp_id[s] != -1:
            continue
        c = len(comp_bipartite)
        comp_id[s] = c
        color[s] = 0
        verts = [s]
        bip = True
        odd: Optional[int] = None
        queue = [s]
        while queue:
            u = queue.pop()
            for j in inc[u]:
                if not alive[j]:
                    continue
                a, b = edges[j]
                v = b if a == u else a
                if comp_id[v] == -1:
                    comp_id[v] = c
                    color[v] = 1 - color[u]
                    verts.append(v)
                    queue.append(v)
                elif color[v] == color[u]:
                    bip = False
                    if odd is None:
                        odd = j
        comp_bipartite.append(bip)
        comp_vertices.append(verts)
        odd_edge.append(odd)
    return comp_id, color, comp_bipartite, comp_vertices, odd_edge


def optimal_alpha_graph(graph: Graph, alive: np.ndarray) -> np.ndarray:
    """alpha* in O(n + m), straight from the Section III characterisation."""
    alive = np.asarray(alive, dtype=bool)
    comp_id, color, bip, verts, _ = _components_two_coloring(graph, alive)
    alpha = np.ones(graph.n, dtype=np.float64)
    for c, vs in enumerate(verts):
        if not bip[c]:
            continue  # alpha = 1 on non-bipartite components
        side0 = sum(1 for v in vs if color[v] == 0)
        side1 = len(vs) - side0
        if side0 + side1 == 1:
            alpha[vs[0]] = 0.0  # isolated vertex: no surviving machine
            continue
        # Larger side gets 1 - delta, smaller side gets 1 + delta.
        delta = abs(side0 - side1) / (side0 + side1)
        big_color = 0 if side0 >= side1 else 1
        for v in vs:
            alpha[v] = 1.0 - delta if color[v] == big_color else 1.0 + delta
    return alpha


def optimal_decode_graph(graph: Graph, alive: np.ndarray) -> DecodeResult:
    """Full O(m) decoder: alpha* plus an explicit w* with A w* = alpha*.

    Spanning-tree back-substitution. Tree edge weights are affine
    functions ``const + coeff * x`` of one unknown x placed on an odd
    cycle edge (non-bipartite components); x is fixed by the root
    equation. Bipartite components are consistent with x-free weights by
    construction of alpha*.
    """
    alive = np.asarray(alive, dtype=bool)
    n, edges = graph.n, graph.edges
    inc = graph.incident_edges()
    alpha = optimal_alpha_graph(graph, alive)
    comp_id, color, bip, verts, odd_edge = _components_two_coloring(
        graph, alive)

    w_const = np.zeros(graph.m, dtype=np.float64)
    w_coeff = np.zeros(graph.m, dtype=np.float64)

    for c, vs in enumerate(verts):
        if len(vs) == 1:
            continue
        root = vs[0]
        # BFS spanning tree of the surviving subgraph of this component.
        parent_edge: dict[int, int] = {}
        parity = {root: 0}
        order = [root]
        qi = 0
        while qi < len(order):
            u = order[qi]
            qi += 1
            for j in inc[u]:
                if not alive[j]:
                    continue
                a, b = edges[j]
                v = b if a == u else a
                if v not in parity:
                    parity[v] = parity[u] ^ 1
                    parent_edge[v] = j
                    order.append(v)
        # The symbolic unknown lives on an edge that is odd *with respect
        # to this tree's parity* (exists iff the component is
        # non-bipartite); being a non-tree edge, it closes an odd cycle.
        oe: Optional[int] = None
        if not bip[c]:
            tree_edges = set(parent_edge.values())
            for u in vs:
                for j in inc[u]:
                    if alive[j] and j not in tree_edges:
                        a, b = edges[j]
                        if parity[a] == parity[b]:
                            oe = j
                            break
                if oe is not None:
                    break
            if oe is None:
                raise RuntimeError("non-bipartite component lacks odd edge")
        if oe is not None:
            w_coeff[oe] = 1.0  # symbolic unknown x on the odd edge
        # Back-substitute leaves-first: each vertex's parent edge weight
        # absorbs the residual of its alpha equation.
        resid_const = {v: alpha[v] for v in vs}
        resid_coeff = {v: 0.0 for v in vs}
        if oe is not None:
            ea, eb = edges[oe]
            resid_coeff[ea] -= 1.0
            resid_coeff[eb] -= 1.0
        for v in reversed(order[1:]):
            j = parent_edge[v]
            w_const[j] = resid_const[v]
            w_coeff[j] += resid_coeff[v]
            a, b = edges[j]
            u = b if a == v else a
            resid_const[u] -= w_const[j]
            resid_coeff[u] -= w_coeff[j]
        # Root equation: resid tracked alpha - (assigned weights), so we
        # need resid_const[root] + resid_coeff[root] * x == 0.
        if oe is not None:
            rc, rk = resid_const[root], resid_coeff[root]
            if abs(rk) < 1e-12:
                raise RuntimeError("odd-cycle sensitivity vanished")
            x = -rc / rk
            w_const += w_coeff * x
            w_coeff[:] = 0.0  # coeffs are per-component; reset for the next
        else:
            if abs(resid_const[root]) > 1e-6 * max(len(vs), 1):
                raise RuntimeError(
                    f"bipartite component root residual {resid_const[root]}")
    w = w_const
    w[~alive] = 0.0
    return DecodeResult(w=w, alpha=alpha)


# ---------------------------------------------------------------------------
# General decoders
# ---------------------------------------------------------------------------


def optimal_decode_pinv(assignment: Assignment,
                        alive: np.ndarray) -> DecodeResult:
    """Eq. (9): alpha* = A(p) (A(p)^T A(p))^+ A(p)^T 1, any assignment."""
    alive = np.asarray(alive, dtype=bool)
    A = assignment.A
    m = A.shape[1]
    w = np.zeros(m, dtype=np.float64)
    if alive.any():
        As = A[:, alive]
        ws, *_ = np.linalg.lstsq(As, np.ones(A.shape[0]), rcond=None)
        w[alive] = ws
    return DecodeResult(w=w, alpha=A @ w)


def fixed_decode(assignment: Assignment, alive: np.ndarray,
                 p: float) -> DecodeResult:
    """Section VIII fixed decoding: w_j = 1/(d (1-p)) on survivors, which
    makes E[A w] = 1 for d-regular assignments.

    alpha is computed as ``(A @ alive) * c`` rather than ``A @ w``: for
    the 0/1 assignment matrices every partial sum of ``A @ alive`` is an
    exact small integer, so the result is independent of summation order
    and BLAS blocking -- which is what lets the sweep-campaign engine
    decode a whole (P * trials) grid through one stacked matmul while
    staying bit-identical to this per-mask oracle (the c-first order
    ``A @ w`` rounds once per addition and is *not* batching-stable).
    Non-integer assignment matrices keep the historical ``A @ w`` path.
    """
    alive = np.asarray(alive, dtype=bool)
    w = fixed_w(alive, assignment.replication_factor, p)
    if not counts_are_exact(assignment):
        return DecodeResult(w=w, alpha=assignment.A @ w)
    c = fixed_scale(assignment.replication_factor, p)
    counts = assignment.A @ alive.astype(np.float64)
    return DecodeResult(w=w, alpha=counts * c)


def optimal_decode_frc(assignment: Assignment,
                       alive: np.ndarray) -> DecodeResult:
    """Closed-form optimal decoding for the FRC: within each group of d
    machines holding the same block, give weight 1/(#survivors)."""
    alive = np.asarray(alive, dtype=bool)
    A = assignment.A
    n, m = A.shape
    w = np.zeros(m, dtype=np.float64)
    for i in range(n):
        js = np.nonzero(A[i])[0]
        live = js[alive[js]]
        if live.size:
            w[live] = 1.0 / live.size
    return DecodeResult(w=w, alpha=A @ w)


def decode(assignment: Assignment, alive: np.ndarray, *,
           method: str = "optimal", p: float = 0.0) -> DecodeResult:
    """Dispatch: 'optimal' uses the O(m) graph decoder when the assignment
    carries a graph, the FRC closed form for FRCs, else the pseudoinverse.
    'fixed' uses Section VIII's fixed coefficients."""
    if method == "fixed":
        return fixed_decode(assignment, alive, p)
    if method != "optimal":
        raise ValueError(f"unknown method {method!r}")
    if is_graph_scheme(assignment):
        # Def II.2 scheme (machines = edges): O(m) component decoder.
        return optimal_decode_graph(assignment.graph, alive)
    if assignment.name.startswith("frc"):
        return optimal_decode_frc(assignment, alive)
    return optimal_decode_pinv(assignment, alive)


# ---------------------------------------------------------------------------
# Error metrics (Definitions I.2 / I.3)
# ---------------------------------------------------------------------------


def normalized_error(alpha: np.ndarray) -> float:
    """(1/n) |alpha - 1|_2^2."""
    return float(np.mean((alpha - 1.0) ** 2))


def debias_alpha(alphas: np.ndarray) -> np.ndarray:
    """Normalize a batch of alpha draws by |1|_2 / |E[alpha]|_2
    (the paper's alpha-bar)."""
    return alphas * _ba_ops.debias_scale(alphas)


def monte_carlo_error(assignment: Assignment, p: float, *, trials: int,
                      method: str = "optimal", seed: int = 0,
                      debias: bool = True, backend: str = "auto",
                      cov: bool = True,
                      cov_method: str = "dense") -> dict:
    """Estimate E[(1/n)|alpha-bar - 1|^2] and |Cov(alpha-bar)|_2 under
    Bernoulli(p) stragglers (Figure 3 harness).

    A single-point view of the grid engine: delegates to
    ``sweep.sweep_error`` with a one-element grid, which keeps this
    bit-identical to the historical per-trial loop (same RNG stream,
    same batched decode, same fused error kernel) *and* to multi-point
    sweeps under the shared-uniform protocol. ``cov=False`` skips the
    covariance/spectral-norm step for throughput benchmarks;
    ``cov_method`` defaults to the historical dense SVD -- pass
    'lanczos' (or 'auto') for the matrix-free O(trials * n * iters)
    path at large n (see ``core.spectral``).
    """
    from .sweep import sweep_error  # local: decoding is imported early

    row = sweep_error(assignment, (p,), trials=trials, method=method,
                      seed=seed, debias=debias, backend=backend, cov=cov,
                      cov_method=cov_method)[0]
    del row["p"]
    return row
