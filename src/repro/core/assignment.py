"""Assignment matrices: data blocks -> machines.

The paper's scheme (Def II.2) derives A from a graph; we also implement
every baseline the paper compares against (Table I / Section VIII):

- ``GraphAssignment``   : blocks = vertices, machines = edges (ours).
- ``FRCAssignment``     : fractional repetition code of [4]/[10].
- ``AdjacencyAssignment``: expander code of [6] (A = adjacency matrix,
  machines = vertices holding their d neighbours' blocks).
- ``BernoulliAssignment``: rBGC-style random sparse assignment of [8].
- ``UncodedAssignment`` : identity (ignore-stragglers baseline).

All assignments are over *blocks* (the N x m point-level matrix is the
block-level matrix with each row repeated block_size times, which leaves
every normalized error metric unchanged -- see paper Section II).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import numpy as np

from .graphs import Graph, make_expander


@dataclasses.dataclass(frozen=True)
class Assignment:
    """A block-level assignment matrix with scheme metadata.

    ``machines`` records what a carried graph's machines *are*:
    'edges' for Def II.2 schemes (the O(m) component decoders apply),
    'vertices' for adjacency schemes (pseudoinverse decoding). An
    explicit marker rather than a shape heuristic -- for 2-regular
    graphs m == n and the shapes are indistinguishable.
    """

    A: np.ndarray  # (n_blocks, m_machines)
    name: str
    graph: Optional[Graph] = None
    machines: Optional[str] = None  # 'edges' | 'vertices' | None

    @property
    def n(self) -> int:
        return self.A.shape[0]

    @property
    def m(self) -> int:
        return self.A.shape[1]

    @property
    def replication_factor(self) -> float:
        return float(np.count_nonzero(self.A)) / self.n

    @functools.cached_property
    def integer_matrix(self) -> bool:
        """True when every entry of A is a small nonnegative integer,
        so count sums like ``alive @ A.T`` run entirely in
        exactly-representable floats -- summation-order / BLAS-blocking
        invariant, which is what lets the grid/campaign engines stack
        fixed/FRC decodes into one GEMM bit-identically to per-point
        calls (see ``batched_decoding.counts_are_exact``). The O(n*m)
        scan runs once per assignment (cached_property writes the
        instance __dict__ directly, bypassing the frozen guard)."""
        return bool(np.all(self.A >= 0.0)
                    and np.all(self.A == np.rint(self.A))
                    and float(self.A.sum()) < 2.0 ** 52)

    @property
    def load(self) -> int:
        """Computational load: max blocks per machine."""
        return int(np.count_nonzero(self.A, axis=0).max())

    def blocks_of_machine(self, j: int) -> np.ndarray:
        return np.nonzero(self.A[:, j])[0]

    def machines_of_block(self, i: int) -> np.ndarray:
        return np.nonzero(self.A[i, :])[0]


def graph_assignment(graph: Graph, name: str = "graph") -> Assignment:
    """Definition II.2: A_ij = 1 iff edge j has vertex i as an endpoint."""
    A = np.zeros((graph.n, graph.m), dtype=np.float64)
    for j, (u, v) in enumerate(graph.edges):
        A[u, j] = 1.0
        A[v, j] = 1.0
    return Assignment(A=A, name=name, graph=graph, machines="edges")


@functools.lru_cache(maxsize=8)  # the m=6552 A is ~114 MB; keep few
def expander_assignment(m: int, d: int, *, vertex_transitive: bool = True,
                        seed: int = 0) -> Assignment:
    """The paper's scheme: d-regular expander on n = 2m/d vertices.

    Cached per process, so benchmark modules sharing the paper-scale
    scheme pay graph construction and the O(n*m) matrix build once per
    run. The cached A is frozen read-only: an in-place mutation by one
    caller would otherwise silently corrupt every later one.
    """
    if (2 * m) % d != 0:
        raise ValueError("need d | 2m")
    n = 2 * m // d
    g = make_expander(n, d, vertex_transitive=vertex_transitive, seed=seed)
    if g.m != m:
        raise RuntimeError(f"graph has {g.m} edges, wanted {m}")
    assignment = graph_assignment(g, name=f"expander(d={d})")
    assignment.A.setflags(write=False)
    return assignment


def frc_assignment(m: int, d: int) -> Assignment:
    """FRC of [4]: machines partitioned into n = m/d groups of d; every
    machine in group i holds (only) block i. Optimal for random
    stragglers (error p^d), worst-possible adversarially (error p)."""
    if m % d != 0:
        raise ValueError("need d | m")
    n = m // d
    A = np.zeros((n, m), dtype=np.float64)
    for j in range(m):
        A[j // d, j] = 1.0
    return Assignment(A=A, name=f"frc(d={d})")


def adjacency_assignment(graph: Graph, name: str = "adjacency") -> Assignment:
    """Expander code of [6]: n blocks = n machines = vertices of G;
    machine j holds the blocks of its neighbours (A = Adj(G))."""
    return Assignment(A=graph.adjacency().astype(np.float64), name=name,
                      graph=graph, machines="vertices")


def bernoulli_assignment(n: int, m: int, d: int, seed: int = 0) -> Assignment:
    """rBGC-flavoured random assignment [8]: each (block, machine) entry
    is 1 independently with probability d/m, regularized so every block
    appears at least once."""
    rng = np.random.default_rng(seed)
    A = (rng.random((n, m)) < d / m).astype(np.float64)
    for i in range(n):  # regularization: no empty rows
        if not A[i].any():
            A[i, rng.integers(m)] = 1.0
    return Assignment(A=A, name=f"bernoulli(d={d})")


def uncoded_assignment(m: int) -> Assignment:
    """No replication: block i on machine i only (ignore stragglers)."""
    return Assignment(A=np.eye(m, dtype=np.float64), name="uncoded")
