"""Assignment matrices: data blocks -> machines.

The paper's scheme (Def II.2) derives A from a graph; we also implement
every baseline the paper compares against (Table I / Section VIII) plus
the rival constructions of the related work (the "scheme zoo"):

- ``GraphAssignment``   : blocks = vertices, machines = edges (ours).
- ``FRCAssignment``     : fractional repetition code of [4]/[10].
- ``AdjacencyAssignment``: expander code of [6] (A = adjacency matrix,
  machines = vertices holding their d neighbours' blocks).
- ``BernoulliAssignment``: rBGC-style random sparse assignment of [8].
- ``UncodedAssignment`` : identity (ignore-stragglers baseline).
- ``cyclic_mds_assignment``: the cyclic / shifted construction of
  Raviv et al. (1707.03858) -- machine j holds the d cyclically
  consecutive blocks starting at j.
- ``bibd_assignment``   : balanced-incomplete-block-design codes of
  Kadhe et al. (1904.13373) for adversarial stragglers -- symmetric
  designs developed from cyclic difference sets, or the lines of the
  affine plane AG(2, q).
- ``random_matching_assignment``: Def II.2 over the random
  union-of-perfect-matchings d-regular graphs of Charles et al.
  (1711.06771), vs our deterministic LPS/Cayley expanders.

All assignments are over *blocks* (the N x m point-level matrix is the
block-level matrix with each row repeated block_size times, which leaves
every normalized error metric unchanged -- see paper Section II).
"""

from __future__ import annotations

import dataclasses
import functools
import itertools
from typing import Optional, Sequence, Tuple

import numpy as np

from .graphs import Graph, make_expander, random_matching_regular_graph


@dataclasses.dataclass(frozen=True)
class Assignment:
    """A block-level assignment matrix with scheme metadata.

    ``machines`` records what a carried graph's machines *are*:
    'edges' for Def II.2 schemes (the O(m) component decoders apply),
    'vertices' for adjacency schemes (pseudoinverse decoding). An
    explicit marker rather than a shape heuristic -- for 2-regular
    graphs m == n and the shapes are indistinguishable.
    """

    A: np.ndarray  # (n_blocks, m_machines)
    name: str
    graph: Optional[Graph] = None
    machines: Optional[str] = None  # 'edges' | 'vertices' | None

    @property
    def n(self) -> int:
        return self.A.shape[0]

    @property
    def m(self) -> int:
        return self.A.shape[1]

    @property
    def replication_factor(self) -> float:
        return float(np.count_nonzero(self.A)) / self.n

    @functools.cached_property
    def integer_matrix(self) -> bool:
        """True when every entry of A is a small nonnegative integer,
        so count sums like ``alive @ A.T`` run entirely in
        exactly-representable floats -- summation-order / BLAS-blocking
        invariant, which is what lets the grid/campaign engines stack
        fixed/FRC decodes into one GEMM bit-identically to per-point
        calls (see ``batched_decoding.counts_are_exact``). The O(n*m)
        scan runs once per assignment (cached_property writes the
        instance __dict__ directly, bypassing the frozen guard)."""
        return bool(np.all(self.A >= 0.0)
                    and np.all(self.A == np.rint(self.A))
                    and float(self.A.sum()) < 2.0 ** 52)

    @property
    def load(self) -> int:
        """Computational load: max blocks per machine."""
        return int(np.count_nonzero(self.A, axis=0).max())

    def blocks_of_machine(self, j: int) -> np.ndarray:
        return np.nonzero(self.A[:, j])[0]

    def machines_of_block(self, i: int) -> np.ndarray:
        return np.nonzero(self.A[i, :])[0]


def graph_assignment(graph: Graph, name: str = "graph") -> Assignment:
    """Definition II.2: A_ij = 1 iff edge j has vertex i as an endpoint."""
    A = np.zeros((graph.n, graph.m), dtype=np.float64)
    for j, (u, v) in enumerate(graph.edges):
        A[u, j] = 1.0
        A[v, j] = 1.0
    return Assignment(A=A, name=name, graph=graph, machines="edges")


@functools.lru_cache(maxsize=8)  # the m=6552 A is ~114 MB; keep few
def expander_assignment(m: int, d: int, *, vertex_transitive: bool = True,
                        seed: int = 0) -> Assignment:
    """The paper's scheme: d-regular expander on n = 2m/d vertices.

    Cached per process, so benchmark modules sharing the paper-scale
    scheme pay graph construction and the O(n*m) matrix build once per
    run. The cached A is frozen read-only: an in-place mutation by one
    caller would otherwise silently corrupt every later one.
    """
    if (2 * m) % d != 0:
        raise ValueError("need d | 2m")
    n = 2 * m // d
    g = make_expander(n, d, vertex_transitive=vertex_transitive, seed=seed)
    if g.m != m:
        raise RuntimeError(f"graph has {g.m} edges, wanted {m}")
    assignment = graph_assignment(g, name=f"expander(d={d})")
    assignment.A.setflags(write=False)
    return assignment


def frc_assignment(m: int, d: int) -> Assignment:
    """FRC of [4]: machines partitioned into n = m/d groups of d; every
    machine in group i holds (only) block i. Optimal for random
    stragglers (error p^d), worst-possible adversarially (error p)."""
    if m % d != 0:
        raise ValueError("need d | m")
    n = m // d
    A = np.zeros((n, m), dtype=np.float64)
    for j in range(m):
        A[j // d, j] = 1.0
    return Assignment(A=A, name=f"frc(d={d})")


def adjacency_assignment(graph: Graph, name: str = "adjacency") -> Assignment:
    """Expander code of [6]: n blocks = n machines = vertices of G;
    machine j holds the blocks of its neighbours (A = Adj(G))."""
    return Assignment(A=graph.adjacency().astype(np.float64), name=name,
                      graph=graph, machines="vertices")


def bernoulli_assignment(n: int, m: int, d: int, seed: int = 0) -> Assignment:
    """rBGC-flavoured random assignment [8]: each (block, machine) entry
    is 1 independently with probability d/m, regularized so every block
    appears at least once."""
    rng = np.random.default_rng(seed)
    A = (rng.random((n, m)) < d / m).astype(np.float64)
    for i in range(n):  # regularization: no empty rows
        if not A[i].any():
            A[i, rng.integers(m)] = 1.0
    return Assignment(A=A, name=f"bernoulli(d={d})")


def uncoded_assignment(m: int) -> Assignment:
    """No replication: block i on machine i only (ignore stragglers)."""
    return Assignment(A=np.eye(m, dtype=np.float64), name="uncoded")


# ---------------------------------------------------------------------------
# Scheme zoo: the related-work constructions the paper benchmarks against
# ---------------------------------------------------------------------------


def cyclic_mds_assignment(m: int, d: int) -> Assignment:
    """Cyclic / shifted construction of Raviv et al. (1707.03858):
    n = m blocks, machine j holds the d cyclically consecutive blocks
    {j, j+1, ..., j+d-1 mod m}.

    The assignment matrix is circulant, so the scheme is transitive
    under the cyclic shift (unbiased under symmetric straggler
    processes) like the MDS-based cyclic repetition codes that paper
    analyses. Decoding goes through the least-squares pseudoinverse
    (Eq. 9) -- there is no graph, and no closed form survives partial
    window erasures.
    """
    if d < 1:
        raise ValueError(f"cyclic MDS replication must be >= 1, got "
                         f"d={d}")
    if d > m:
        raise ValueError(
            f"cyclic MDS scheme needs d <= m: machine j holds d "
            f"consecutive blocks of only m={m} distinct blocks, so "
            f"d={d} would assign duplicates")
    A = np.zeros((m, m), dtype=np.float64)
    for j in range(m):
        for k in range(d):
            A[(j + k) % m, j] = 1.0
    return Assignment(A=A, name=f"cyclic_mds(d={d})")


def _quadratic_residue_difference_set(v: int) -> Optional[Tuple[int, ...]]:
    """The Paley difference set {x^2 mod v} for prime v = 3 mod 4:
    a (v, (v-1)/2, (v-3)/4) cyclic difference set."""
    if v < 7 or v % 4 != 3:
        return None
    if any(v % f == 0 for f in range(2, int(v ** 0.5) + 1)):
        return None
    return tuple(sorted({(x * x) % v for x in range(1, v)}))


def _search_difference_set(v: int, k: int,
                           lam: int) -> Optional[Tuple[int, ...]]:
    """Smallest-lexicographic (v, k, lam) cyclic difference set by
    exhaustive search over base blocks containing 0. Bounded: meant
    for the small-v designs the zoo and the brute-force adversarial
    oracle use (Fano, biplanes, small projective planes)."""
    budget = 5_000_000  # ~seconds; v in the tens stays far below it
    cost_per = k * (k - 1)
    seen = 0
    for rest in itertools.combinations(range(1, v), k - 1):
        seen += cost_per
        if seen > budget:
            return None
        block = (0,) + rest
        diffs = np.zeros(v, dtype=np.int64)
        for a, b in itertools.permutations(block, 2):
            diffs[(a - b) % v] += 1
        if np.all(diffs[1:] == lam):
            return block
    return None


def _affine_plane_blocks(q: int) -> Sequence[Sequence[int]]:
    """The q^2 + q lines of AG(2, q), q prime: point (x, y) has index
    x*q + y; lines are {y = a x + b} for a, b in F_q plus the q
    verticals {x = c}."""
    lines = []
    for a in range(q):
        for b in range(q):
            lines.append([x * q + (a * x + b) % q for x in range(q)])
    for c in range(q):
        lines.append([c * q + y for y in range(q)])
    return lines


def bibd_assignment(v: int, k: int, *, design: str = "auto") -> Assignment:
    """Block-design codes of Kadhe et al. (1904.13373): machines are
    the blocks of a (v, k, lambda) BIBD over the v data blocks, so
    every *pair* of data blocks is covered by exactly lambda machines
    -- the pairwise balance that caps how much damage an adversarial
    straggler set can concentrate (see tests/test_adversarial_oracle).

    Two constructible families:

    * ``design='symmetric'``: a symmetric (v, k, lambda) design
      developed cyclically from a difference set (m = v machines,
      replication r = k, lambda = k(k-1)/(v-1)); served by the Paley
      quadratic-residue set for prime v = 3 mod 4 with k = (v-1)/2,
      else by bounded exhaustive search (Fano plane, biplanes, small
      projective planes).
    * ``design='affine'``: the q^2 + q lines of the affine plane
      AG(2, q) with q = k prime (v = k^2 data blocks, m = k^2 + k
      machines, replication r = k + 1, lambda = 1) -- the resolvable
      family, whose machine count composes with the d | m schemes in
      one campaign (symmetric designs never have k | v).

    ``design='auto'`` picks affine when v == k^2, else symmetric.
    Parameter validation happens here, at construction: the lambda
    divisibility condition and design existence are checked up front
    with actionable errors rather than failing downstream.
    """
    if design == "auto":
        design = "affine" if v == k * k else "symmetric"
    if not 2 <= k < v:
        raise ValueError(f"BIBD needs 2 <= k < v, got (v={v}, k={k})")
    if design == "affine":
        if v != k * k:
            raise ValueError(
                f"affine-plane BIBD needs v = k^2 points, got v={v} "
                f"for k={k} (AG(2, q) has q^2 points on lines of q)")
        if any(k % f == 0 for f in range(2, k)):
            raise ValueError(
                f"affine-plane BIBD needs prime q = k, got k={k} "
                "(prime-power planes need field arithmetic we don't "
                "carry)")
        blocks = _affine_plane_blocks(k)
        name = f"bibd_affine(q={k})"
    elif design == "symmetric":
        if (k * (k - 1)) % (v - 1) != 0:
            raise ValueError(
                f"no symmetric (v={v}, k={k}) BIBD: lambda = "
                f"k(k-1)/(v-1) = {k * (k - 1)}/{v - 1} is not an "
                "integer (pick v, k with (v-1) | k(k-1), e.g. the "
                "Fano plane (7, 3) or a quadratic-residue design "
                "(prime v = 3 mod 4, k = (v-1)/2))")
        lam = k * (k - 1) // (v - 1)
        ds = None
        if k == (v - 1) // 2:
            ds = _quadratic_residue_difference_set(v)
        if ds is None:
            ds = _search_difference_set(v, k, lam)
        if ds is None:
            raise ValueError(
                f"no (v={v}, k={k}, lambda={lam}) cyclic difference "
                "set found (the design may not exist -- cf. the "
                "Bruck-Ryser-Chowla condition -- or lies beyond the "
                "bounded search)")
        blocks = [[(x + j) % v for x in ds] for j in range(v)]
        name = f"bibd({v},{k},{lam})"
    else:
        raise ValueError(f"unknown BIBD design {design!r} "
                         "(auto | symmetric | affine)")
    A = np.zeros((v, len(blocks)), dtype=np.float64)
    for j, block in enumerate(blocks):
        A[list(block), j] = 1.0
    return Assignment(A=A, name=name)


def random_matching_assignment(m: int, d: int, seed: int = 0) -> Assignment:
    """Def II.2 over the random union-of-perfect-matchings d-regular
    graph of Charles et al. (1711.06771): the sparse random rival of
    our deterministic LPS / Cayley expanders, decodable by the same
    O(m) component decoder (machines = edges)."""
    if d < 1:
        raise ValueError(f"replication must be >= 1, got d={d}")
    if d > m:
        raise ValueError(f"graph schemes need d <= m: d={d} edges per "
                         f"vertex cannot exceed m={m} machines")
    if (2 * m) % d != 0:
        raise ValueError(f"need d | 2m for a d-regular graph with "
                         f"m edges, got (m={m}, d={d})")
    g = random_matching_regular_graph(2 * m // d, d, seed=seed)
    return graph_assignment(g, name=f"random_matching(d={d})")
