"""Straggler-sample -> decode -> debiased step-weights: one pipeline.

Every consumer of the paper's update ``sum_j w*_j g_j`` needs the same
three host-side stages each round: sample an alive mask from a straggler
process, decode it into weights, and (optionally) rescale by the
alpha-bar debias factor. Before this module, ``core/coded_gd.GCOD``,
``core/sweep.py`` and the mesh runtime (``repro.dist.coded_train``)
each grew their own copy of parts of that pipeline; this is the single
``core`` entry point they all share now:

- model construction from config strings (``make_straggler_model``),
- the GCOD RNG-consumption protocol (``sample_mask_stream``, moved here
  from ``coded_gd`` so the mesh runtime can reuse it),
- per-mask machine weights w* (``step_weights``) and the batched form
  (``batched_step_weights``) -- there is deliberately no third decoder
  implementation here, only dispatch onto the existing ones,
- the per-block combine weights v = A @ w (``block_weights``, scalar
  and batched) -- the dedup train path's view of the same decode,
- the Monte-Carlo debias scale (``debias_scale_mc``), computed by one
  ``batched_alpha`` call over a shared-uniform Bernoulli batch (the
  sweep engine's sampling protocol),
- the mask-*source* abstraction (``MaskSource`` and its three
  implementations): where each round's alive mask comes from is
  orthogonal to how it is decoded. ``SampledMaskSource`` draws from a
  ``core.stragglers`` process (the simulation path every consumer used
  until PR 9), ``ObservedMaskSource`` is fed masks derived from real
  per-machine heartbeats (``repro.dist.failures``), and
  ``ReplayedMaskSource`` replays a recorded (T, m) stream -- e.g. the
  mask column of a failure-event log -- so an observed run can be
  re-executed deterministically.
"""

from __future__ import annotations

import collections
from typing import Tuple

import numpy as np

from ..kernels.batched_alpha import ops as _ba_ops
from .assignment import Assignment
from .batched_decoding import batched_alpha, batched_fixed_alpha, fixed_w
from .decoding import decode
from .stragglers import (AdversarialStragglers, BernoulliStragglers,
                         FixedCountStragglers, MarkovStragglers,
                         StragglerModel)
from .sweep import bernoulli_uniforms

STRAGGLER_MODELS = ("bernoulli", "markov", "adversarial", "fixed_count")


def make_straggler_model(assignment: Assignment, name: str, p: float, *,
                         persistence: float = 10.0) -> StragglerModel:
    """Build one of the ``core.stragglers`` processes from its config
    string. All models emit (m,) alive masks via ``sample(rng)``."""
    m = assignment.m
    if name == "bernoulli":
        return BernoulliStragglers(m=m, p=p)
    if name == "markov":
        return MarkovStragglers(m=m, p=p, persistence=persistence)
    if name == "adversarial":
        return AdversarialStragglers(assignment=assignment, p=p)
    if name == "fixed_count":
        return FixedCountStragglers(m=m, p=p)
    raise ValueError(f"unknown straggler model {name!r}; "
                     f"known: {STRAGGLER_MODELS}")


class MaskSource:
    """Where a round's (m,) alive mask comes from.

    The decode pipeline below is source-agnostic: a mask is a mask
    whether it was *sampled* from a synthetic straggler process,
    *observed* from real machine heartbeats, or *replayed* from a
    recorded stream. ``next_mask()`` yields one round's mask;
    ``skip(rounds)`` fast-forwards the stream for checkpoint resume
    (consuming exactly the state a per-round loop would).
    """

    m: int

    def next_mask(self) -> np.ndarray:
        raise NotImplementedError

    def skip(self, rounds: int) -> None:
        if rounds < 0:
            raise ValueError("rounds must be >= 0")
        for _ in range(rounds):
            self.next_mask()


class SampledMaskSource(MaskSource):
    """Masks drawn from a ``core.stragglers`` process -- the synthetic
    simulation path. Holds (not copies) the model and RNG, so a runtime
    that wraps its own ``(model, rng)`` pair consumes the exact RNG
    stream the pre-abstraction code did (bit-identity pinned in
    tests/test_coding_runtime.py)."""

    def __init__(self, model: StragglerModel,
                 rng: np.random.Generator, m: int):
        self.model = model
        self.rng = rng
        self.m = m

    def next_mask(self) -> np.ndarray:
        return self.model.sample(self.rng)


class ReplayedMaskSource(MaskSource):
    """Replays a recorded (T, m) mask stream round for round -- the
    deterministic re-execution path for observed failure traces (e.g.
    the per-step masks a failure-event log recorded). Raises when the
    recording is exhausted rather than silently resampling."""

    def __init__(self, masks):
        masks = np.asarray(masks, dtype=bool)
        if masks.ndim != 2:
            raise ValueError(f"masks must be (T, m), got {masks.shape}")
        self.masks = masks
        self.m = masks.shape[1]
        self.cursor = 0

    def next_mask(self) -> np.ndarray:
        if self.cursor >= self.masks.shape[0]:
            raise RuntimeError(
                f"replayed mask stream exhausted after "
                f"{self.masks.shape[0]} rounds")
        row = self.masks[self.cursor]
        self.cursor += 1
        return row.copy()

    def skip(self, rounds: int) -> None:
        if rounds < 0:
            raise ValueError("rounds must be >= 0")
        if self.cursor + rounds > self.masks.shape[0]:
            raise RuntimeError("cannot skip past the recorded stream")
        self.cursor += rounds


class ObservedMaskSource(MaskSource):
    """Push-based source for masks derived from real heartbeats.

    The failure detector (``repro.dist.failures.HeartbeatMonitor``)
    owns *deriving* the mask from per-machine completion timestamps;
    the driver pushes each round's derived mask here before asking the
    runtime for weights, keeping the runtime's sample -> decode
    protocol (and its memo cache / bookkeeping) identical across
    sampled and observed execution. Pulling without a pushed mask is a
    driver bug, not a resampling opportunity, and raises; ``skip`` is
    rejected because an observed stream cannot be fast-forwarded --
    resume re-observes instead.
    """

    def __init__(self, m: int):
        self.m = m
        self._queue: collections.deque = collections.deque()

    def push(self, alive: np.ndarray) -> None:
        alive = np.asarray(alive, dtype=bool)
        if alive.shape != (self.m,):
            raise ValueError(f"mask must be ({self.m},), "
                             f"got {alive.shape}")
        self._queue.append(alive.copy())

    def next_mask(self) -> np.ndarray:
        if not self._queue:
            raise RuntimeError(
                "no observed mask pushed for this round (push() the "
                "heartbeat-derived mask before requesting weights)")
        return self._queue.popleft()

    def skip(self, rounds: int) -> None:
        raise RuntimeError(
            "observed mask streams cannot be fast-forwarded; resume "
            "re-observes the cluster instead of replaying RNG")


def sample_mask_stream(assignment: Assignment,
                       straggler_model: StragglerModel, *, steps: int,
                       shuffle: bool, rng: np.random.Generator
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """GCOD's RNG consumption protocol -- the rho permutation draw
    (when shuffling), then one straggler mask per step. The single
    source of truth shared by ``gcod``, ``precompute_alphas`` and the
    mesh runtime, so precomputed alpha batches cannot desync from the
    in-loop stream.

    Returns (rho, masks) with masks of shape (steps, m).
    """
    n = assignment.n
    rho = rng.permutation(n) if shuffle else np.arange(n)
    if steps:
        masks = np.stack(
            [straggler_model.sample(rng) for _ in range(steps)])
    else:
        masks = np.zeros((0, assignment.m), dtype=bool)
    return rho, masks


def step_weights(assignment: Assignment, alive: np.ndarray, *,
                 method: str = "optimal", p: float = 0.0,
                 scale: float = 1.0) -> Tuple[np.ndarray, np.ndarray]:
    """One mask -> (w (m,), alpha (n,)), both scaled by ``scale``.

    Thin dispatch onto ``decoding.decode`` (the O(m) graph decoder /
    FRC closed form / pseudoinverse / Section VIII fixed weights);
    stragglers keep w = 0 under any scale.
    """
    res = decode(assignment, alive, method=method, p=p)
    return res.w * scale, res.alpha * scale


def block_weights(assignment: Assignment, w: np.ndarray) -> np.ndarray:
    """Per-block combine weights v = A @ w from machine weights w.

    The paper combine ``sum_j w_j g_j`` over the m machines is
    algebraically the per-block form ``sum_i (A w)_i grad L_i`` over
    the n *unique* blocks (machine j's gradient is the sum of its
    assigned blocks' gradients), so v is everything the deduplicated
    train path (``repro.dist.coded_train.coded_loss_fn_dedup``) needs:
    it never recomputes a replicated block. For decoder outputs v is
    exactly the decoder's alpha -- exposed here as a first-class output
    rather than an ad-hoc ``assignment.A @ w`` at every call site.

    Accepts a scalar (m,) weight vector -> (n,), or a batched (T, m)
    stack -> (T, n).
    """
    w = np.asarray(w)
    if w.ndim == 1:
        if w.shape[0] != assignment.m:
            raise ValueError(f"w must be ({assignment.m},), got {w.shape}")
        return assignment.A @ w
    if w.ndim == 2:
        if w.shape[1] != assignment.m:
            raise ValueError(f"W must be (T, {assignment.m}), "
                             f"got {w.shape}")
        return w @ assignment.A.T
    raise ValueError(f"w must be (m,) or (T, m), got ndim={w.ndim}")


def served_blocks(assignment: Assignment, w: np.ndarray,
                  eps: float = 1e-3) -> np.ndarray:
    """Which blocks the decoded weights can actually reconstruct:
    alpha_i = (A w)_i > eps.

    Training tolerates alpha_i ~ 0 (that block's gradient is simply
    missing from the unbiased combine this round); serving cannot -- a
    prefill shard with no usable combine weight has no output to emit,
    so the engine retries it next round. This is the serving-side view
    of the same decode: w_j = 0 on stragglers implies alpha_i > 0 only
    when some arrived replica covers block i.

    Accepts (m,) -> (n,) bool, or batched (T, m) -> (T, n) bool.
    """
    return block_weights(assignment, w) > eps


def batched_step_weights(assignment: Assignment, masks, *,
                         method: str = "optimal", p: float = 0.0,
                         scale: float = 1.0
                         ) -> Tuple[np.ndarray, np.ndarray]:
    """A (T, m) mask batch -> (W (T, m), alphas (T, n)).

    Fixed decoding is fully vectorised. Optimal decoding loops the
    scalar ``decoding.decode`` dispatch once per mask -- w* needs the
    spanning-tree back-substitution and each decode yields w and alpha
    together, so this is the cheapest correct route to *machine*
    weights. Alpha-only Monte-Carlo consumers (``gcod``, the sweep
    engine, ``debias_scale_mc``) go through the ``batched_alpha``
    engine instead, whose alphas are bit-identical for graph schemes
    (property-tested in tests/test_batched_decoding.py).
    """
    masks = np.asarray(masks, dtype=bool)
    if masks.ndim != 2 or masks.shape[1] != assignment.m:
        raise ValueError(f"masks must be (T, {assignment.m}), "
                         f"got {masks.shape}")
    if method == "fixed":
        W = fixed_w(masks, assignment.replication_factor, p)
        # Count-first alphas (exact integer counts): bitwise the scalar
        # ``fixed_decode`` alphas, row for row, on integer A.
        alphas = batched_fixed_alpha(assignment, masks, p)
    elif method != "optimal":
        raise ValueError(f"unknown method {method!r}")
    else:
        results = [decode(assignment, a, method="optimal")
                   for a in masks]
        W = np.stack([r.w for r in results]) if results else \
            np.zeros((0, assignment.m))
        alphas = np.stack([r.alpha for r in results]) if results else \
            np.zeros((0, assignment.n))
    return W * scale, alphas * scale


def debias_scale_mc(assignment: Assignment, *, p: float,
                    method: str = "optimal", trials: int = 256,
                    seed: int = 0, backend: str = "auto") -> float:
    """Monte-Carlo alpha-bar debias factor |1|_2 / |E[alpha]|_2 under
    Bernoulli(p) stragglers.

    One ``batched_alpha`` call over the sweep engine's shared-uniform
    draw -- the runtime analogue of ``sweep_error``'s per-point scale,
    and what Prop B.1-style unbiasing costs at runtime: a single
    pre-training decode batch instead of per-step estimation.
    """
    if trials <= 0:
        raise ValueError("trials must be positive")
    masks = bernoulli_uniforms(assignment.m, trials, seed) >= p
    alphas = batched_alpha(assignment, masks, method=method, p=p,
                           backend=backend)
    return _ba_ops.debias_scale(alphas)
