"""Gradient coding with optimal decoding (Glasgow & Wootters 2020).

Public surface of the paper's core contribution:

- graphs:      expander constructions (incl. the exact LPS X^{5,13})
- assignment:  graph / FRC / adjacency / Bernoulli / uncoded schemes
- decoding:    O(m) optimal graph decoder, pseudoinverse, fixed
- batched_decoding: the (trials, m)-at-once alpha* engine (pointer
               jumping on the double cover; numpy + jittable jax paths)
- sweep:       the (p_grid x trials) grid engine (shared uniforms,
               warm-started labels, one decode pipeline per scheme)
- spectral:    matrix-free spectra (Lanczos covariance norm, FFT
               circulant eigenvalues, sparse-matvec graph lambda_2)
- stragglers:  Bernoulli / fixed-count / Markov / adversarial attacks
- adaptive:    online p-hat / transition estimation from the observed
               mask stream + per-step decoding policies (the regret
               harness behind the BENCH_sweep.json adaptive row)
- step_weights: the shared straggler-sample -> decode -> debiased
               step-weights pipeline (single-host GCOD and the
               repro.dist mesh runtime both sit on it)
- compress:    gradient compression codecs (int8 / signSGD) composed
               with the coded combine, error-feedback state, and the
               error-vs-p-vs-bits campaign grid
- theory:      the paper's closed-form bounds
- debias:      Prop B.1 black-box debiasing
- coded_gd:    Algorithms 2 & 3 (single-host logical view)
"""

from .graphs import (Graph, cycle_graph, complete_graph, hypercube_graph,
                     paley_graph, circulant_graph, random_regular_graph,
                     random_matching_regular_graph, lps_graph,
                     make_expander)
from .assignment import (Assignment, graph_assignment, expander_assignment,
                         frc_assignment, adjacency_assignment,
                         bernoulli_assignment, uncoded_assignment,
                         cyclic_mds_assignment, bibd_assignment,
                         random_matching_assignment)
from .decoding import (DecodeResult, decode, optimal_alpha_graph,
                       optimal_decode_graph, optimal_decode_pinv,
                       optimal_decode_frc, fixed_decode, normalized_error,
                       monte_carlo_error, debias_alpha)
from .batched_decoding import (batched_alpha, batched_fixed_alpha,
                               batched_frc_alpha,
                               batched_optimal_alpha_graph,
                               counts_are_exact, fixed_alpha_grid,
                               frc_alpha_grid)
from .sweep import (CampaignEntry, bernoulli_uniforms, decode_grid,
                    scheme_zoo_entries, sweep_campaign, sweep_error)
from . import spectral
from .spectral import (circulant_spectrum, covariance_spectral_norm,
                       covariance_spectral_norm_batch, covariance_topk,
                       graph_lambda2, lanczos_lambda_max,
                       lanczos_lambda_max_batch)
from .stragglers import (StragglerModel, BernoulliStragglers,
                         FixedCountStragglers, MarkovStragglers,
                         AdversarialStragglers,
                         adversarial_mask, adversarial_mask_graph,
                         adversarial_mask_frc, adversarial_mask_cyclic,
                         adversarial_mask_bibd)
from . import adaptive
from .adaptive import (OnlineStragglerEstimator, StragglerEstimate,
                       PolicyDecision, DecodingPolicy, StaticPolicy,
                       AdaptivePolicy, make_policy, replay_policy,
                       policy_regret_report)
from .step_weights import (make_straggler_model, sample_mask_stream,
                           batched_step_weights, debias_scale_mc)
from . import step_weights  # the module: step_weights.step_weights etc.
from . import compress
from .compress import (Codec, get_codec, compression_campaign)
from . import theory
from .debias import debias_assignment, estimate_mean_alpha
from .coded_gd import (LeastSquares, GDTrace, gcod, precompute_alphas,
                       sgd_alg, uncoded_gd)

__all__ = [
    "Graph", "cycle_graph", "complete_graph", "hypercube_graph",
    "paley_graph", "circulant_graph", "random_regular_graph",
    "random_matching_regular_graph", "lps_graph", "make_expander",
    "Assignment", "graph_assignment", "expander_assignment",
    "frc_assignment", "adjacency_assignment", "bernoulli_assignment",
    "uncoded_assignment", "cyclic_mds_assignment", "bibd_assignment",
    "random_matching_assignment",
    "DecodeResult", "decode", "optimal_alpha_graph", "optimal_decode_graph",
    "optimal_decode_pinv", "optimal_decode_frc", "fixed_decode",
    "normalized_error", "monte_carlo_error", "debias_alpha",
    "batched_alpha", "batched_fixed_alpha", "batched_frc_alpha",
    "batched_optimal_alpha_graph", "counts_are_exact",
    "fixed_alpha_grid", "frc_alpha_grid",
    "CampaignEntry", "bernoulli_uniforms", "decode_grid",
    "scheme_zoo_entries", "sweep_campaign", "sweep_error",
    "spectral", "circulant_spectrum", "covariance_spectral_norm",
    "covariance_spectral_norm_batch", "covariance_topk",
    "graph_lambda2", "lanczos_lambda_max", "lanczos_lambda_max_batch",
    "StragglerModel", "BernoulliStragglers", "FixedCountStragglers",
    "MarkovStragglers", "AdversarialStragglers", "adversarial_mask",
    "adversarial_mask_graph", "adversarial_mask_frc",
    "adversarial_mask_cyclic", "adversarial_mask_bibd",
    "adaptive", "OnlineStragglerEstimator", "StragglerEstimate",
    "PolicyDecision", "DecodingPolicy", "StaticPolicy", "AdaptivePolicy",
    "make_policy", "replay_policy", "policy_regret_report",
    "step_weights", "make_straggler_model", "sample_mask_stream",
    "batched_step_weights", "debias_scale_mc",
    "compress", "Codec", "get_codec", "compression_campaign",
    "theory", "debias_assignment", "estimate_mean_alpha",
    "LeastSquares", "GDTrace", "gcod", "precompute_alphas", "sgd_alg",
    "uncoded_gd",
]
