"""Matrix-free spectral toolkit for the sweep engine and graph schemes.

Two spectral quantities gate the paper's harnesses at scale:

* ``|Cov(alpha-bar)|_2`` in the Figure 3 / Section VIII-B Monte-Carlo
  pipeline. The historical path formed the dense n x n covariance and
  ran a full SVD -- O(n^3) per p-point, ~3.5 s at the LPS n=2184 scale.
  ``covariance_spectral_norm`` instead runs Lanczos iteration directly
  on the centered (trials, n) batch: the covariance top eigenvalue is
  sigma_max(C)^2 / trials, reachable through Gram matvecs
  v -> X^T (X v) with X the tall-skinny orientation of C, i.e.
  O(trials * n * iters) and no n x n matrix ever formed. The matvec is
  the ``kernels.spectral_matvec`` package (Pallas on TPU, float64
  NumPy oracle on CPU). When the Krylov dimension min(trials, n) is
  small (the paper's trials=30 regime) Lanczos exhausts the space and
  the result is exact to rounding.

* ``lambda_2(Adj(G))`` behind ``Graph.spectral_expansion`` -- the
  quantity Thm IV.1 / Cor V.2 and the related expander schemes (Raviv
  et al., Charles et al.) all scale with. ``graph_lambda2`` dispatches:
  circulant graphs (cycles, Paley, the ``lps_like_cayley_expander``
  candidates) get their *exact* spectrum from one FFT of the offset
  indicator; large regular graphs get sparse-matvec Lanczos with the
  known top eigenvector (the all-ones direction) deflated; small or
  irregular graphs keep the dense eigvalsh.
"""

from __future__ import annotations

import functools
from typing import Callable, Sequence, TYPE_CHECKING

import numpy as np

from ..kernels.spectral_matvec import ops as _sm_ops

if TYPE_CHECKING:  # avoid a runtime cycle with .graphs
    from .graphs import Graph

# Below these sizes the dense path is both exact and cheap; Lanczos
# only pays off once the O(n^3) eigendecomposition dominates.
_DENSE_N_MAX = 512
_DENSE_COV_MAX = 512


# ---------------------------------------------------------------------------
# Lanczos extreme eigenvalue (full reorthogonalization)
# ---------------------------------------------------------------------------


def lanczos_lambda_max(matvec: Callable[[np.ndarray], np.ndarray],
                       dim: int, *, maxiter: int | None = None,
                       tol: float = 1e-12, seed: int = 0) -> float:
    """Largest eigenvalue of a symmetric operator given only matvecs.

    Full reorthogonalization (the Krylov bases here are tiny relative
    to the matvec cost), with restart on breakdown so invariant
    subspaces are enumerated rather than silently truncated: when
    ``maxiter`` covers the whole space the result is therefore exact to
    rounding, which is what the covariance-norm acceptance (1e-6
    relative of the dense SVD) and the closed-form graph tests rely on.
    Stops early once the top Ritz value is stable to ``tol`` (relative)
    for two consecutive iterations.
    """
    if dim <= 0:
        return 0.0
    rng = np.random.default_rng(seed)
    kmax = dim if maxiter is None else max(1, min(maxiter, dim))
    # Grow the basis geometrically: convergence usually takes a few
    # dozen iterations, so never preallocate the O(dim^2) worst case.
    Q = np.empty((min(kmax, 32), dim), dtype=np.float64)

    def ensure_row(i: int) -> None:
        nonlocal Q
        if i >= Q.shape[0]:
            Q = np.concatenate(
                [Q, np.empty((min(kmax, 2 * Q.shape[0]) - Q.shape[0],
                              dim))], axis=0)

    diag: list[float] = []
    off: list[float] = []
    q = rng.standard_normal(dim)
    q /= np.linalg.norm(q)
    Q[0] = q
    theta_prev = None
    stable = 0
    k = 0
    while True:
        w = np.asarray(matvec(Q[k]), dtype=np.float64)
        diag.append(float(Q[k] @ w))
        # Classical Gram-Schmidt against the whole basis, twice (the
        # standard "twice is enough" full reorthogonalization).
        w -= Q[:k + 1].T @ (Q[:k + 1] @ w)
        w -= Q[:k + 1].T @ (Q[:k + 1] @ w)
        b = float(np.linalg.norm(w))
        k += 1
        T = np.diag(diag)
        if off:
            idx = np.arange(len(off))
            T[idx, idx + 1] = off
            T[idx + 1, idx] = off
        theta = float(np.linalg.eigvalsh(T)[-1])
        if theta_prev is not None and \
                abs(theta - theta_prev) <= tol * max(1.0, abs(theta)):
            stable += 1
            if stable >= 2:
                return theta
        else:
            stable = 0
        theta_prev = theta
        if k == kmax:
            return theta
        ensure_row(k)
        if b <= 1e-13 * max(1.0, abs(diag[-1])):
            # Invariant subspace found: restart in its orthogonal
            # complement (off-diagonal 0 keeps T block-tridiagonal).
            q = rng.standard_normal(dim)
            q -= Q[:k].T @ (Q[:k] @ q)
            nq = float(np.linalg.norm(q))
            if nq < 1e-10:  # basis exhausted: theta is exact
                return theta
            off.append(0.0)
            Q[k] = q / nq
        else:
            off.append(b)
            Q[k] = w / b


# ---------------------------------------------------------------------------
# Covariance spectral norm (matrix-free)
# ---------------------------------------------------------------------------


def covariance_spectral_norm(batch: np.ndarray, *, method: str = "auto",
                             maxiter: int | None = None,
                             tol: float = 1e-12, seed: int = 0) -> float:
    """|Cov(rows of batch)|_2 for a (trials, n) batch.

    method 'dense' reproduces the historical expression bit-for-bit
    (center, form C^T C / trials, dense 2-norm); 'lanczos' never forms
    the n x n matrix: it runs ``lanczos_lambda_max`` on the Gram
    operator of the tall-skinny orientation of the centered batch
    (dimension min(trials, n)), dividing by trials. 'auto' picks
    lanczos once n outgrows the dense crossover.
    """
    a = np.asarray(batch, dtype=np.float64)
    if a.ndim != 2:
        raise ValueError(f"batch must be (trials, n), got {a.shape}")
    trials, n = a.shape
    if trials == 0:
        return 0.0
    if method == "auto":
        method = "lanczos" if n > _DENSE_COV_MAX else "dense"
    centered = a - a.mean(axis=0, keepdims=True)
    if method == "dense":
        cov = centered.T @ centered / trials
        return float(np.linalg.norm(cov, 2))
    if method != "lanczos":
        raise ValueError(f"unknown cov method {method!r}")
    # Operate on the small side: X^T X is (k, k) with k = min(trials, n)
    # and shares its nonzero spectrum with the covariance * trials.
    # Stage the tall operand once (a device upload on the TPU path)
    # rather than per Lanczos matvec.
    X = _sm_ops.prepare_operand(centered if trials >= n else centered.T)
    k = X.shape[1]

    def mv(v: np.ndarray) -> np.ndarray:
        return _sm_ops.gram_matvec(X, v) / trials

    lam = lanczos_lambda_max(mv, k, maxiter=maxiter, tol=tol, seed=seed)
    return float(max(lam, 0.0))  # Gram operator is PSD; clip rounding


# ---------------------------------------------------------------------------
# Graph spectra
# ---------------------------------------------------------------------------


def circulant_spectrum(n: int, offsets: Sequence[int]) -> np.ndarray:
    """Exact adjacency spectrum of the circulant graph of Z_n with
    connection set {+-o : o in offsets} \\ {0} (deduplicated like
    ``graphs.circulant_graph``): lambda_k = sum_{s in S} e^{2 pi i ks/n}
    -- i.e. one FFT of the connection-set indicator. Returns the n
    eigenvalues in frequency order (index 0 is the degree)."""
    from .graphs import _canonical_offsets  # single dedup convention

    ind = np.zeros(n, dtype=np.float64)
    for o in _canonical_offsets(n, offsets):
        ind[o] = 1.0
        ind[n - o] = 1.0  # same slot when o = n/2: counted once
    # The connection set is symmetric, so the transform is real up to
    # rounding.
    return np.fft.fft(ind).real


def adjacency_matvec(graph: "Graph") -> Callable[[np.ndarray], np.ndarray]:
    """x -> Adj(G) x as a sparse bincount gather: O(m) per call, no
    dense n x n adjacency."""
    n = graph.n
    if not graph.edges:
        return lambda x: np.zeros(n, dtype=np.float64)
    e = np.asarray(graph.edges, dtype=np.int64)
    src = np.concatenate([e[:, 0], e[:, 1]])
    dst = np.concatenate([e[:, 1], e[:, 0]])

    def mv(x: np.ndarray) -> np.ndarray:
        return np.bincount(src, weights=np.asarray(x, np.float64)[dst],
                           minlength=n)

    return mv


@functools.lru_cache(maxsize=256)  # graphs are immutable; lambda_2 isn't
def graph_lambda2(graph: "Graph", method: str = "auto") -> float:
    """Second-largest adjacency eigenvalue of ``graph``.

    Matches ``sort(eigvalsh(Adj))[-2]`` (the historical definition,
    multiplicity included). Dispatch: 'fft' (exact, circulant metadata
    required), 'dense' (exact, O(n^3)), 'lanczos' (matrix-free; regular
    graphs only -- the top eigenvector is then the all-ones direction,
    which gets deflated so lambda_2 = lambda_max on 1-perp even when
    lambda_2 = d has multiplicity, e.g. disconnected graphs).
    """
    if method == "auto":
        if graph.circulant_offsets is not None:
            method = "fft"
        elif graph.n <= _DENSE_N_MAX or not graph.is_regular():
            method = "dense"
        else:
            method = "lanczos"
    if method == "fft":
        if graph.circulant_offsets is None:
            raise ValueError("fft lambda_2 needs circulant metadata")
        eigs = np.sort(circulant_spectrum(graph.n, graph.circulant_offsets))
        return float(eigs[-2])
    if method == "dense":
        eigs = np.sort(np.linalg.eigvalsh(graph.adjacency()))
        return float(eigs[-2])
    if method != "lanczos":
        raise ValueError(f"unknown lambda_2 method {method!r}")
    if not graph.is_regular():
        raise ValueError("lanczos lambda_2 needs a regular graph "
                         "(unknown Perron vector otherwise); use 'dense'")
    mv = adjacency_matvec(graph)
    d = float(graph.degrees()[0]) if graph.edges else 0.0

    def deflated(v: np.ndarray) -> np.ndarray:
        # P A P - (d+1) * 11^T/n: the all-ones direction is shifted to
        # -(d+1) < -d <= lambda_min, so lambda_max of this operator is
        # exactly lambda_2 (even when lambda_2 < 0, e.g. K_n).
        mean_in = v.mean()
        y = mv(v - mean_in)
        return y - y.mean() - (d + 1.0) * mean_in

    return float(lanczos_lambda_max(deflated, graph.n, seed=0))


def spectral_expansion(graph: "Graph", method: str = "auto") -> float:
    """lambda = max-degree - lambda_2; the ``Graph.spectral_expansion``
    implementation (see its docstring for semantics)."""
    d = float(np.max(graph.degrees())) if graph.edges else 0.0
    return d - graph_lambda2(graph, method)
