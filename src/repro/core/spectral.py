"""Matrix-free spectral toolkit for the sweep engine and graph schemes.

Two spectral quantities gate the paper's harnesses at scale:

* ``|Cov(alpha-bar)|_2`` in the Figure 3 / Section VIII-B Monte-Carlo
  pipeline. The historical path formed the dense n x n covariance and
  ran a full SVD -- O(n^3) per p-point, ~3.5 s at the LPS n=2184 scale.
  ``covariance_spectral_norm`` instead runs Lanczos iteration directly
  on the centered (trials, n) batch: the covariance top eigenvalue is
  sigma_max(C)^2 / trials, reachable through Gram matvecs
  v -> X^T (X v) with X the tall-skinny orientation of C, i.e.
  O(trials * n * iters) and no n x n matrix ever formed. The matvec is
  the ``kernels.spectral_matvec`` package (Pallas on TPU, float64
  NumPy oracle on CPU). When the Krylov dimension min(trials, n) is
  small (the paper's trials=30 regime) Lanczos exhausts the space and
  the result is exact to rounding.

* ``lambda_2(Adj(G))`` behind ``Graph.spectral_expansion`` -- the
  quantity Thm IV.1 / Cor V.2 and the related expander schemes (Raviv
  et al., Charles et al.) all scale with. ``graph_lambda2`` dispatches:
  circulant graphs (cycles, Paley, the ``lps_like_cayley_expander``
  candidates) get their *exact* spectrum from one FFT of the offset
  indicator; large regular graphs get sparse-matvec Lanczos with the
  known top eigenvector (the all-ones direction) deflated; small or
  irregular graphs keep the dense eigvalsh.
"""

from __future__ import annotations

import functools
from typing import Callable, Sequence, TYPE_CHECKING

import numpy as np

from ..kernels.spectral_matvec import ops as _sm_ops

if TYPE_CHECKING:  # avoid a runtime cycle with .graphs
    from .graphs import Graph

# Below these sizes the dense path is both exact and cheap; Lanczos
# only pays off once the O(n^3) eigendecomposition dominates.
_DENSE_N_MAX = 512
_DENSE_COV_MAX = 512


# ---------------------------------------------------------------------------
# Lanczos extreme eigenvalue (full reorthogonalization)
# ---------------------------------------------------------------------------


def lanczos_lambda_max(matvec: Callable[[np.ndarray], np.ndarray],
                       dim: int, *, maxiter: int | None = None,
                       tol: float = 1e-12, seed: int = 0) -> float:
    """Largest eigenvalue of a symmetric operator given only matvecs.

    Full reorthogonalization (the Krylov bases here are tiny relative
    to the matvec cost), with restart on breakdown so invariant
    subspaces are enumerated rather than silently truncated: when
    ``maxiter`` covers the whole space the result is therefore exact to
    rounding, which is what the covariance-norm acceptance (1e-6
    relative of the dense SVD) and the closed-form graph tests rely on.
    Stops early once the top Ritz value is stable to ``tol`` (relative)
    for two consecutive iterations.
    """
    if dim <= 0:
        return 0.0
    rng = np.random.default_rng(seed)
    kmax = dim if maxiter is None else max(1, min(maxiter, dim))
    # Grow the basis geometrically: convergence usually takes a few
    # dozen iterations, so never preallocate the O(dim^2) worst case.
    Q = np.empty((min(kmax, 32), dim), dtype=np.float64)

    def ensure_row(i: int) -> None:
        nonlocal Q
        if i >= Q.shape[0]:
            Q = np.concatenate(
                [Q, np.empty((min(kmax, 2 * Q.shape[0]) - Q.shape[0],
                              dim))], axis=0)

    diag: list[float] = []
    off: list[float] = []
    q = rng.standard_normal(dim)
    q /= np.linalg.norm(q)
    Q[0] = q
    theta_prev = None
    stable = 0
    k = 0
    while True:
        w = np.asarray(matvec(Q[k]), dtype=np.float64)
        diag.append(float(Q[k] @ w))
        # Classical Gram-Schmidt against the whole basis, twice (the
        # standard "twice is enough" full reorthogonalization).
        w -= Q[:k + 1].T @ (Q[:k + 1] @ w)
        w -= Q[:k + 1].T @ (Q[:k + 1] @ w)
        b = float(np.linalg.norm(w))
        k += 1
        T = np.diag(diag)
        if off:
            idx = np.arange(len(off))
            T[idx, idx + 1] = off
            T[idx + 1, idx] = off
        theta = float(np.linalg.eigvalsh(T)[-1])
        if theta_prev is not None and \
                abs(theta - theta_prev) <= tol * max(1.0, abs(theta)):
            stable += 1
            if stable >= 2:
                return theta
        else:
            stable = 0
        theta_prev = theta
        if k == kmax:
            return theta
        ensure_row(k)
        if b <= 1e-13 * max(1.0, abs(diag[-1])):
            # Invariant subspace found: restart in its orthogonal
            # complement (off-diagonal 0 keeps T block-tridiagonal).
            q = rng.standard_normal(dim)
            q -= Q[:k].T @ (Q[:k] @ q)
            nq = float(np.linalg.norm(q))
            if nq < 1e-10:  # basis exhausted: theta is exact
                return theta
            off.append(0.0)
            Q[k] = q / nq
        else:
            off.append(b)
            Q[k] = w / b


def lanczos_lambda_max_batch(matvec: Callable[..., np.ndarray],
                             dim: int, nbatch: int, *,
                             maxiter: int | None = None,
                             tol: float = 1e-12,
                             seed: int = 0) -> np.ndarray:
    """Largest eigenvalues of ``nbatch`` symmetric operators of equal
    ``dim``, driven in lockstep through one *batched* matvec per
    iteration: ``matvec(V, idx)`` with V (B_active, dim) and ``idx``
    the int array of original slice indices V's rows correspond to.

    Per-slice state mirrors ``lanczos_lambda_max`` exactly: full
    reorthogonalization (batched einsums over the shared basis tensor),
    per-slice convergence counters, per-slice breakdown restarts, and
    exactness once a slice's Krylov space is exhausted. Converged
    slices are COMPACTED out of the active set (their result frozen at
    their own stopping iteration, like a sequential early-stop), so the
    lockstep's total matvec/reorth/eigen work tracks the *sum* of
    per-slice iteration counts, not B times the slowest slice -- that,
    plus one kernel launch sequence per iteration instead of B python
    Lanczos loops, is what the batch form buys.
    """
    B = int(nbatch)
    if B == 0:
        return np.zeros(0, dtype=np.float64)
    if dim <= 0:
        return np.zeros(B, dtype=np.float64)
    rng = np.random.default_rng(seed)
    kmax = dim if maxiter is None else max(1, min(maxiter, dim))
    result = np.zeros(B, dtype=np.float64)
    idx = np.arange(B)                     # active slice -> original
    Q = np.empty((B, min(kmax, 32), dim), dtype=np.float64)
    q = rng.standard_normal((B, dim))
    Q[:, 0] = q / np.linalg.norm(q, axis=1, keepdims=True)
    diag = np.empty((B, kmax))
    off = np.empty((B, kmax))
    theta_prev = np.full(B, np.nan)
    stable = np.zeros(B, dtype=np.int64)
    k = 0
    while True:
        w = np.asarray(matvec(Q[:, k], idx), dtype=np.float64)
        diag[:, k] = np.einsum("bd,bd->b", Q[:, k], w)
        for _ in range(2):  # "twice is enough" full reorthogonalization
            coeff = np.einsum("bkd,bd->bk", Q[:, :k + 1], w)
            w -= np.einsum("bkd,bk->bd", Q[:, :k + 1], coeff)
        beta = np.linalg.norm(w, axis=1)
        k += 1
        T = np.zeros((len(idx), k, k))
        di = np.arange(k)
        T[:, di, di] = diag[:, :k]
        if k > 1:
            j = np.arange(k - 1)
            T[:, j, j + 1] = off[:, :k - 1]
            T[:, j + 1, j] = off[:, :k - 1]
        theta = np.linalg.eigvalsh(T)[:, -1]  # batched tridiag eigen
        conv = np.abs(theta - theta_prev) <= \
            tol * np.maximum(1.0, np.abs(theta))
        stable = np.where(conv, stable + 1, 0)
        theta_prev = theta
        if k == kmax:
            result[idx] = theta
            return result
        if k >= Q.shape[1]:  # grow the shared basis geometrically
            extra = min(kmax, 2 * Q.shape[1]) - Q.shape[1]
            Q = np.concatenate(
                [Q, np.empty((len(idx), extra, dim))], axis=1)
        exhausted = np.zeros(len(idx), dtype=bool)
        small = beta <= 1e-13 * np.maximum(1.0, np.abs(diag[:, k - 1]))
        off[:, k - 1] = np.where(small, 0.0, beta)
        safe = np.where(small, 1.0, beta)
        Q[:, k] = w / safe[:, None]
        for b_i in np.nonzero(small)[0]:
            # Invariant subspace on slice b_i: restart in its orthogonal
            # complement (the off-diagonal 0 keeps T block-tridiagonal).
            qv = rng.standard_normal(dim)
            qv -= Q[b_i, :k].T @ (Q[b_i, :k] @ qv)
            nq = float(np.linalg.norm(qv))
            if nq < 1e-10:
                # Basis exhausted: theta is exact; retire the slice.
                exhausted[b_i] = True
            else:
                Q[b_i, k] = qv / nq
        finished = (stable >= 2) | exhausted
        if finished.any():
            result[idx[finished]] = theta[finished]
            keep = ~finished
            if not keep.any():
                return result
            idx = idx[keep]
            Q = Q[keep]
            diag = diag[keep]
            off = off[keep]
            theta_prev = theta_prev[keep]
            stable = stable[keep]


# ---------------------------------------------------------------------------
# Covariance spectral norm (matrix-free)
# ---------------------------------------------------------------------------


def covariance_spectral_norm(batch: np.ndarray, *, method: str = "auto",
                             maxiter: int | None = None,
                             tol: float = 1e-12, seed: int = 0) -> float:
    """|Cov(rows of batch)|_2 for a (trials, n) batch.

    method 'dense' reproduces the historical expression bit-for-bit
    (center, form C^T C / trials, dense 2-norm); 'lanczos' never forms
    the n x n matrix: it runs ``lanczos_lambda_max`` on the Gram
    operator of the tall-skinny orientation of the centered batch
    (dimension min(trials, n)), dividing by trials. 'auto' picks
    lanczos once n outgrows the dense crossover.
    """
    a = np.asarray(batch, dtype=np.float64)
    if a.ndim != 2:
        raise ValueError(f"batch must be (trials, n), got {a.shape}")
    trials, n = a.shape
    if trials == 0:
        return 0.0
    if method == "auto":
        method = "lanczos" if n > _DENSE_COV_MAX else "dense"
    centered = a - a.mean(axis=0, keepdims=True)
    if method == "dense":
        cov = centered.T @ centered / trials
        return float(np.linalg.norm(cov, 2))
    if method != "lanczos":
        raise ValueError(f"unknown cov method {method!r}")
    # Operate on the small side: X^T X is (k, k) with k = min(trials, n)
    # and shares its nonzero spectrum with the covariance * trials.
    # Stage the tall operand once (a device upload on the TPU path)
    # rather than per Lanczos matvec.
    X = _sm_ops.prepare_operand(centered if trials >= n else centered.T)
    k = X.shape[1]

    def mv(v: np.ndarray) -> np.ndarray:
        return _sm_ops.gram_matvec(X, v) / trials

    lam = lanczos_lambda_max(mv, k, maxiter=maxiter, tol=tol, seed=seed)
    return float(max(lam, 0.0))  # Gram operator is PSD; clip rounding


def covariance_spectral_norm_batch(batch: np.ndarray, *,
                                   method: str = "auto",
                                   maxiter: int | None = None,
                                   tol: float = 1e-12,
                                   seed: int = 0) -> np.ndarray:
    """|Cov|_2 for every slice of a (B, trials, n) stack at once.

    method 'blocked' is the sweep campaign's path: every slice is
    centered, oriented tall-skinny, stacked into one (B, R, k) operand,
    and all B norms come out of ONE lockstep Lanczos
    (``lanczos_lambda_max_batch`` over ``gram_matvec_batch``) -- a
    single kernel launch sequence instead of B python Lanczos loops.
    'dense' / 'lanczos' loop the per-slice ``covariance_spectral_norm``
    (the oracles the blocked path is differential-tested against);
    'auto' picks blocked once n outgrows the dense crossover.
    """
    a = np.asarray(batch, dtype=np.float64)
    if a.ndim != 3:
        raise ValueError(f"batch must be (B, trials, n), got {a.shape}")
    B, trials, n = a.shape
    if B == 0:
        return np.zeros(0, dtype=np.float64)
    if trials == 0:
        return np.zeros(B, dtype=np.float64)
    if method == "auto":
        method = "blocked" if n > _DENSE_COV_MAX else "dense"
    if method in ("dense", "lanczos"):
        return np.asarray([
            covariance_spectral_norm(a[i], method=method, maxiter=maxiter,
                                     tol=tol, seed=seed)
            for i in range(B)])
    if method != "blocked":
        raise ValueError(f"unknown batch cov method {method!r}")
    centered = a - a.mean(axis=1, keepdims=True)
    X = centered if trials >= n else centered.transpose(0, 2, 1)
    k = X.shape[2]
    if _sm_ops.uses_pallas():
        Xs = _sm_ops.prepare_operand(X)  # staged once on device
        # idx only changes at compaction events; cache the gathered
        # sub-stack so steady-state iterations pay no device copy.
        sub_cache = {"key": None, "sub": Xs}

        def mv(V: np.ndarray, idx: np.ndarray) -> np.ndarray:
            key = idx.tobytes()
            if sub_cache["key"] != key:
                sub_cache["sub"] = Xs if len(idx) == B else Xs[idx]
                sub_cache["key"] = key
            return _sm_ops.gram_matvec_batch(sub_cache["sub"],
                                             V) / trials
    else:
        # CPU float64 oracle path: per-slice GEMVs, no stack copies
        # when the active set shrinks.
        Xs_list = [np.ascontiguousarray(X[i]) for i in range(B)]

        def mv(V: np.ndarray, idx: np.ndarray) -> np.ndarray:
            return np.stack([_sm_ops.gram_matvec(Xs_list[i], V[j])
                             for j, i in enumerate(idx)]) / trials

    lam = lanczos_lambda_max_batch(mv, k, B, maxiter=maxiter, tol=tol,
                                   seed=seed)
    return np.maximum(lam, 0.0)  # Gram operators are PSD; clip rounding


def covariance_topk(batch: np.ndarray, k: int, *, method: str = "auto",
                    maxiter: int | None = None, tol: float = 1e-12,
                    seed: int = 0) -> np.ndarray:
    """Top-k eigenvalues of Cov(rows of batch), descending, for a
    (trials, n) batch.

    The paper's bounds only ever need the top eigenvalue
    (``covariance_spectral_norm``); the ablations want the leading
    spectrum, so this runs *block* Lanczos (block size min(k, dim),
    full reorthogonalization, explicit Rayleigh-Ritz) on the Gram
    operator of the tall-skinny orientation -- each iteration is one
    ``gram_matvec_block`` pass over the centered batch, k right-hand
    sides at a time. Eigenvalues beyond the covariance rank are exact
    zeros (padded, never iterated for). method 'dense' is the oracle
    (full eigvalsh of the n x n covariance); 'auto' picks the block
    path once n outgrows the dense crossover.
    """
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    a = np.asarray(batch, dtype=np.float64)
    if a.ndim != 2:
        raise ValueError(f"batch must be (trials, n), got {a.shape}")
    trials, n = a.shape
    k = min(k, n) if n else 0
    if trials == 0 or k == 0:
        return np.zeros(max(k, 0), dtype=np.float64)
    if method == "auto":
        method = "block" if n > _DENSE_COV_MAX else "dense"
    centered = a - a.mean(axis=0, keepdims=True)
    if method == "dense":
        cov = centered.T @ centered / trials
        eigs = np.linalg.eigvalsh(cov)[::-1][:k]
        return np.maximum(eigs, 0.0)
    if method != "block":
        raise ValueError(f"unknown topk method {method!r}")
    X = _sm_ops.prepare_operand(centered if trials >= n else centered.T)
    dim = X.shape[1]

    def mv_block(V: np.ndarray) -> np.ndarray:
        return _sm_ops.gram_matvec_block(X, V) / trials

    lam = _block_lanczos_topk(mv_block, dim, min(k, dim),
                              maxiter=maxiter, tol=tol, seed=seed)
    out = np.zeros(k, dtype=np.float64)  # rank-deficient tail is 0
    out[:lam.size] = np.maximum(lam, 0.0)
    return out


def _block_lanczos_topk(matvec_block: Callable[[np.ndarray], np.ndarray],
                        dim: int, k: int, *, maxiter: int | None = None,
                        tol: float = 1e-12, seed: int = 0) -> np.ndarray:
    """Top-k eigenvalues of a symmetric PSD operator via block Lanczos
    with explicit Rayleigh-Ritz: grow an orthonormal basis Q one
    k-column block per matvec sweep, keep A Q alongside, and read Ritz
    values off H = Q^T A Q. Full reorthogonalization plus random
    refill of rank-deficient block columns, so invariant subspaces are
    enumerated rather than truncated; when the basis exhausts R^dim the
    Ritz values are the exact spectrum. Stops early once all k leading
    Ritz values are stable to ``tol`` (relative) twice in a row.
    """
    if dim <= 0 or k <= 0:
        return np.zeros(0, dtype=np.float64)
    rng = np.random.default_rng(seed)
    b = min(k, dim)
    cap = dim if maxiter is None else min(dim, max(1, maxiter) * b)
    V = np.linalg.qr(rng.standard_normal((dim, b)))[0]
    Q = np.zeros((dim, 0))
    AQ = np.zeros((dim, 0))
    ritz_prev = None
    stable = 0
    while True:
        W = np.asarray(matvec_block(V), dtype=np.float64)
        Q = np.concatenate([Q, V], axis=1)
        AQ = np.concatenate([AQ, W], axis=1)
        H = Q.T @ AQ
        H = (H + H.T) / 2.0
        ritz = np.linalg.eigvalsh(H)[::-1][:k]
        if ritz_prev is not None and ritz_prev.size == ritz.size and \
                np.all(np.abs(ritz - ritz_prev) <=
                       tol * np.maximum(1.0, np.abs(ritz))):
            stable += 1
            if stable >= 2:
                return ritz
        else:
            stable = 0
        ritz_prev = ritz
        nxt = min(b, cap - Q.shape[1])
        if nxt <= 0:
            return ritz
        # Next block: A V orthogonalized against everything seen, twice;
        # rank-deficient columns refilled with fresh random directions.
        W = W[:, :nxt]
        for _ in range(2):
            W -= Q @ (Q.T @ W)
        cols = []
        for j in range(W.shape[1]):
            w = W[:, j]
            if cols:
                C = np.stack(cols, axis=1)
                w = w - C @ (C.T @ w)
            nw = float(np.linalg.norm(w))
            if nw <= 1e-10:
                for _ in range(3):  # refill: random, re-orthogonalized
                    w = rng.standard_normal(dim)
                    w -= Q @ (Q.T @ w)
                    if cols:
                        C = np.stack(cols, axis=1)
                        w -= C @ (C.T @ w)
                    nw = float(np.linalg.norm(w))
                    if nw > 1e-10:
                        break
                else:
                    # Space exhausted: Ritz values are exact already.
                    return ritz
            cols.append(w / nw)
        V = np.stack(cols, axis=1)


# ---------------------------------------------------------------------------
# Graph spectra
# ---------------------------------------------------------------------------


def circulant_spectrum(n: int, offsets: Sequence[int]) -> np.ndarray:
    """Exact adjacency spectrum of the circulant graph of Z_n with
    connection set {+-o : o in offsets} \\ {0} (deduplicated like
    ``graphs.circulant_graph``): lambda_k = sum_{s in S} e^{2 pi i ks/n}
    -- i.e. one FFT of the connection-set indicator. Returns the n
    eigenvalues in frequency order (index 0 is the degree)."""
    from .graphs import _canonical_offsets  # single dedup convention

    ind = np.zeros(n, dtype=np.float64)
    for o in _canonical_offsets(n, offsets):
        ind[o] = 1.0
        ind[n - o] = 1.0  # same slot when o = n/2: counted once
    # The connection set is symmetric, so the transform is real up to
    # rounding.
    return np.fft.fft(ind).real


def adjacency_matvec(graph: "Graph") -> Callable[[np.ndarray], np.ndarray]:
    """x -> Adj(G) x as a sparse bincount gather: O(m) per call, no
    dense n x n adjacency."""
    n = graph.n
    if not graph.edges:
        return lambda x: np.zeros(n, dtype=np.float64)
    e = np.asarray(graph.edges, dtype=np.int64)
    src = np.concatenate([e[:, 0], e[:, 1]])
    dst = np.concatenate([e[:, 1], e[:, 0]])

    def mv(x: np.ndarray) -> np.ndarray:
        return np.bincount(src, weights=np.asarray(x, np.float64)[dst],
                           minlength=n)

    return mv


@functools.lru_cache(maxsize=256)  # graphs are immutable; lambda_2 isn't
def graph_lambda2(graph: "Graph", method: str = "auto") -> float:
    """Second-largest adjacency eigenvalue of ``graph``.

    Matches ``sort(eigvalsh(Adj))[-2]`` (the historical definition,
    multiplicity included). Dispatch: 'fft' (exact, circulant metadata
    required), 'dense' (exact, O(n^3)), 'lanczos' (matrix-free; regular
    graphs only -- the top eigenvector is then the all-ones direction,
    which gets deflated so lambda_2 = lambda_max on 1-perp even when
    lambda_2 = d has multiplicity, e.g. disconnected graphs).
    """
    if method == "auto":
        if graph.circulant_offsets is not None:
            method = "fft"
        elif graph.n <= _DENSE_N_MAX or not graph.is_regular():
            method = "dense"
        else:
            method = "lanczos"
    if method == "fft":
        if graph.circulant_offsets is None:
            raise ValueError("fft lambda_2 needs circulant metadata")
        eigs = np.sort(circulant_spectrum(graph.n, graph.circulant_offsets))
        return float(eigs[-2])
    if method == "dense":
        eigs = np.sort(np.linalg.eigvalsh(graph.adjacency()))
        return float(eigs[-2])
    if method != "lanczos":
        raise ValueError(f"unknown lambda_2 method {method!r}")
    if not graph.is_regular():
        raise ValueError("lanczos lambda_2 needs a regular graph "
                         "(unknown Perron vector otherwise); use 'dense'")
    mv = adjacency_matvec(graph)
    d = float(graph.degrees()[0]) if graph.edges else 0.0

    def deflated(v: np.ndarray) -> np.ndarray:
        # P A P - (d+1) * 11^T/n: the all-ones direction is shifted to
        # -(d+1) < -d <= lambda_min, so lambda_max of this operator is
        # exactly lambda_2 (even when lambda_2 < 0, e.g. K_n).
        mean_in = v.mean()
        y = mv(v - mean_in)
        return y - y.mean() - (d + 1.0) * mean_in

    return float(lanczos_lambda_max(deflated, graph.n, seed=0))


def spectral_expansion(graph: "Graph", method: str = "auto") -> float:
    """lambda = max-degree - lambda_2; the ``Graph.spectral_expansion``
    implementation (see its docstring for semantics)."""
    d = float(np.max(graph.degrees())) if graph.edges else 0.0
    return d - graph_lambda2(graph, method)
