"""Online straggler estimation + per-step decoding policy.

The paper fixes its decoding strategy ahead of time from the *true*
straggler parameter p: Section VIII's fixed weights w = 1/(d(1-p))
need p, the alpha-bar debias scale needs p, and the lookahead depth
worth prefetching depends on how stagnant the straggler set is. On a
real cluster none of those are known -- but every round's alive mask
is observed, and the PR 9 ``MaskSource`` abstraction made the mask
stream a first-class object. This module closes the loop:

- ``OnlineStragglerEstimator`` consumes the observed mask stream and
  maintains p-hat (running straggle fraction, beta-prior smoothed)
  plus the 2x2 alive/straggle transition matrix of the per-machine
  Markov chain -- enough to recover both Bernoulli(p) and the
  stagnant-cluster ``MarkovStragglers`` process (Section VIII's
  empirical observation).
- ``DecodingPolicy.decide(estimate)`` maps an estimate to a
  ``PolicyDecision`` -- which decoder to run this step (optimal vs
  Section VIII fixed), with which p, and how deep a lookahead to
  prefetch. ``StaticPolicy`` reproduces the existing fixed-ahead-of-
  time behaviour exactly (the bit-identity anchor pinned in
  tests/test_adaptive.py); ``AdaptivePolicy`` switches on p-hat and
  scales lookahead with the estimated straggler persistence.
- ``replay_policy`` / ``policy_regret_report`` replay a recorded mask
  stream under each policy and report mean normalized decoding error
  against the omniscient baseline (always-optimal: optimal decoding is
  pointwise at least as good as any fixed-w choice, since the fixed
  weights lie inside the optimal decoder's feasible set). The
  BENCH_sweep.json adaptive-regret row is this report on a seeded
  markov stream; acceptance is adaptive regret < the best *static*
  fixed-decoding policy's regret.

Estimation protocol (shared with ``CodingRuntime``): a policy decides
from the estimator's state *before* the current round's mask is
observed -- the decision may only use the past -- and the estimator
observes the mask afterwards. p-hat is quantized (``P_HAT_DECIMALS``)
inside ``AdaptivePolicy`` so consecutive near-identical estimates hit
the runtime's memoized decode cache instead of thrashing it.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import numpy as np

from .assignment import Assignment
from .decoding import decode, normalized_error

# AdaptivePolicy quantizes p-hat to this many decimals: decisions (and
# the runtime's (method, p, mask) cache keys) stay stable while the
# estimate drifts by less than half a grid step.
P_HAT_DECIMALS = 3

ALIVE, STRAGGLE = 0, 1  # transition-matrix state indices


@dataclasses.dataclass(frozen=True)
class StragglerEstimate:
    """Snapshot of the estimator's belief after ``steps`` rounds."""

    p_hat: float
    transition_hat: np.ndarray  # (2, 2) row-stochastic, rows=from-state
    persistence_hat: float      # mean straggle sojourn, 1/P(S->A)
    steps: int


class OnlineStragglerEstimator:
    """Running estimate of the straggler process from observed masks.

    p-hat is the posterior-mean straggle fraction under a
    Beta(prior_weight * prior_p, prior_weight * (1 - prior_p)) prior
    over machine-rounds -- the prior keeps early decisions sane (and
    ``estimate()`` total before any mask arrives) without biasing the
    long-run limit. The transition matrix is counted over consecutive
    masks per machine with Laplace (+1) smoothing per row, so
    ``persistence_hat`` is finite even before a straggle->alive exit
    has been observed.
    """

    def __init__(self, m: int, *, prior_p: float = 0.1,
                 prior_weight: float = 4.0):
        if m <= 0:
            raise ValueError(f"m must be positive, got {m}")
        if not 0.0 <= prior_p < 1.0:
            raise ValueError(f"prior_p must be in [0, 1), got {prior_p}")
        if prior_weight <= 0:
            raise ValueError("prior_weight must be positive")
        self.m = m
        self.prior_p = float(prior_p)
        self.prior_weight = float(prior_weight)
        self.steps = 0
        self._machine_rounds = 0
        self._straggled = 0
        self._trans = np.zeros((2, 2), dtype=np.int64)
        self._prev_straggle: Optional[np.ndarray] = None

    def observe(self, alive: np.ndarray) -> None:
        alive = np.asarray(alive, dtype=bool)
        if alive.shape != (self.m,):
            raise ValueError(f"mask must be ({self.m},), got {alive.shape}")
        straggle = ~alive
        self.steps += 1
        self._machine_rounds += self.m
        self._straggled += int(straggle.sum())
        prev = self._prev_straggle
        if prev is not None:
            self._trans[ALIVE, ALIVE] += int(np.sum(~prev & ~straggle))
            self._trans[ALIVE, STRAGGLE] += int(np.sum(~prev & straggle))
            self._trans[STRAGGLE, ALIVE] += int(np.sum(prev & ~straggle))
            self._trans[STRAGGLE, STRAGGLE] += int(np.sum(prev & straggle))
        self._prev_straggle = straggle.copy()

    def estimate(self) -> StragglerEstimate:
        p_hat = ((self.prior_weight * self.prior_p + self._straggled)
                 / (self.prior_weight + self._machine_rounds))
        trans = (self._trans + 1).astype(np.float64)  # Laplace smoothing
        trans /= trans.sum(axis=1, keepdims=True)
        persistence = 1.0 / max(trans[STRAGGLE, ALIVE], 1e-9)
        return StragglerEstimate(p_hat=float(p_hat),
                                 transition_hat=trans,
                                 persistence_hat=float(persistence),
                                 steps=self.steps)


@dataclasses.dataclass(frozen=True)
class PolicyDecision:
    """One step's decoding choice: which decoder, with which p, and
    how deep a lookahead is worth prefetching."""

    method: str          # "optimal" | "fixed"
    p: float             # p fed to the decoder (fixed weights need it)
    lookahead: int = 1   # suggested prefetch horizon, >= 1


class DecodingPolicy:
    def decide(self, estimate: StragglerEstimate) -> PolicyDecision:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class StaticPolicy(DecodingPolicy):
    """The pre-adaptive behaviour as a policy: a fixed decision every
    step, ignoring the estimate. ``StaticPolicy("optimal", p)`` is the
    omniscient baseline; a grid of ``StaticPolicy("fixed", p)`` over
    candidate p values is the comparison set the adaptive policy must
    beat in the BENCH_sweep.json regret row."""

    method: str = "optimal"
    p: float = 0.0
    lookahead: int = 1

    def __post_init__(self):
        if self.method not in ("optimal", "fixed"):
            raise ValueError(f"unknown method {self.method!r}")
        if self.lookahead < 1:
            raise ValueError("lookahead must be >= 1")

    def decide(self, estimate: StragglerEstimate) -> PolicyDecision:
        return PolicyDecision(method=self.method, p=self.p,
                              lookahead=self.lookahead)


@dataclasses.dataclass(frozen=True)
class AdaptivePolicy(DecodingPolicy):
    """Estimate-driven per-step decoding.

    - Decoder: Section VIII's fixed weights are a near-free
      approximation of the optimal decode when stragglers are rare
      (w = 1/(d(1-p)) -> 1/d as p -> 0, and with every machine alive
      the optimal decode *is* uniform 1/d for a regular scheme), so
      below ``threshold`` the policy decodes fixed with p = p-hat; at
      or above it, the optimal decoder's accuracy is worth the O(m)
      component sweep. p-hat is quantized to ``P_HAT_DECIMALS`` so the
      runtime's decode memo keys repeat.
    - Lookahead: under a stagnant straggler set (Section VIII), masks
      repeat for ~persistence steps, so prefetching that many rounds
      of weights is free accuracy for the overlap engine; capped at
      ``max_lookahead``.
    """

    threshold: float = 0.05
    max_lookahead: int = 8

    def __post_init__(self):
        if not 0.0 <= self.threshold <= 1.0:
            raise ValueError("threshold must be in [0, 1]")
        if self.max_lookahead < 1:
            raise ValueError("max_lookahead must be >= 1")

    def decide(self, estimate: StragglerEstimate) -> PolicyDecision:
        p_hat = round(min(max(estimate.p_hat, 0.0), 0.999),
                      P_HAT_DECIMALS)
        method = "optimal" if p_hat >= self.threshold else "fixed"
        lookahead = int(np.clip(round(estimate.persistence_hat), 1,
                                self.max_lookahead))
        return PolicyDecision(method=method, p=p_hat, lookahead=lookahead)


POLICIES = ("adaptive", "always_optimal", "always_fixed")


def make_policy(spec, *, p: float = 0.0) -> DecodingPolicy:
    """Config-string -> policy (pass a ``DecodingPolicy`` through).

    ``always_optimal`` / ``always_fixed`` are the static anchors --
    the former is the omniscient baseline and the bit-identity pin for
    ``CodingRuntime(adaptive=...)``; ``p`` parameterizes them (the
    true p when known, as in the runtime's config)."""
    if isinstance(spec, DecodingPolicy):
        return spec
    if spec == "adaptive":
        return AdaptivePolicy()
    if spec == "always_optimal":
        return StaticPolicy(method="optimal", p=p)
    if spec == "always_fixed":
        return StaticPolicy(method="fixed", p=p)
    raise ValueError(f"unknown policy {spec!r}; known: {POLICIES}")


def replay_policy(assignment: Assignment, masks, policy: DecodingPolicy,
                  *, prior_p: float = 0.1,
                  prior_weight: float = 4.0) -> Dict[str, np.ndarray]:
    """Replay a recorded (T, m) mask stream under one policy.

    Per round: decide from the estimator's *past-only* state, decode
    the round's mask with that decision, then observe the mask -- the
    exact protocol ``CodingRuntime`` runs online, so replayed errors
    match what the runtime would have realized. Returns per-step
    normalized errors plus the decision trace (methods, ps,
    lookaheads) for burn-in analysis.
    """
    masks = np.asarray(masks, dtype=bool)
    if masks.ndim != 2 or masks.shape[1] != assignment.m:
        raise ValueError(f"masks must be (T, {assignment.m}), "
                         f"got {masks.shape}")
    est = OnlineStragglerEstimator(assignment.m, prior_p=prior_p,
                                   prior_weight=prior_weight)
    errors = np.zeros(masks.shape[0])
    methods, ps, lookaheads = [], [], []
    for t, alive in enumerate(masks):
        decision = policy.decide(est.estimate())
        res = decode(assignment, alive, method=decision.method,
                     p=decision.p)
        errors[t] = normalized_error(res.alpha)
        methods.append(decision.method)
        ps.append(decision.p)
        lookaheads.append(decision.lookahead)
        est.observe(alive)
    return {"errors": errors, "methods": np.array(methods),
            "ps": np.array(ps), "lookaheads": np.array(lookaheads)}


def policy_regret_report(assignment: Assignment, masks,
                         policies: Dict[str, DecodingPolicy], *,
                         burn_in: int = 0) -> Dict[str, Dict[str, float]]:
    """Mean error + regret per policy over one shared mask stream.

    The omniscient baseline is the always-optimal static policy:
    optimal decoding minimizes ||A w - 1|| over all w supported on the
    live machines, so no per-step method choice can beat it pointwise
    -- regret >= 0 up to float rounding for every policy. ``burn_in``
    drops the first rounds from the means (the estimator's prior
    dominates there), matching how the benchmark row scores the
    adaptive policy's steady state.
    """
    masks = np.asarray(masks, dtype=bool)
    if burn_in < 0 or burn_in >= masks.shape[0]:
        raise ValueError(f"burn_in must be in [0, {masks.shape[0]}), "
                         f"got {burn_in}")
    omniscient = replay_policy(assignment, masks,
                               StaticPolicy(method="optimal"))
    base = float(np.mean(omniscient["errors"][burn_in:]))
    report: Dict[str, Dict[str, float]] = {
        "omniscient": {"mean_error": base, "regret": 0.0}}
    for name, policy in policies.items():
        replay = replay_policy(assignment, masks, policy)
        mean = float(np.mean(replay["errors"][burn_in:]))
        report[name] = {"mean_error": mean, "regret": mean - base}
    return report
