"""Closed-form theoretical bounds from the paper, used by tests and
benchmarks to validate the implementation against the paper's claims.
"""

from __future__ import annotations

import numpy as np


def lower_bound_any_decoding(p: float, d: float) -> float:
    """Prop A.3: (1/n) E|alpha-bar - 1|^2 >= p^d / (1 - p^d) for any
    unbiased decoding with replication factor d. The FRC meets this."""
    pd = p ** d
    return pd / (1.0 - pd)


def lower_bound_fixed_decoding(p: float, d: float) -> float:
    """Prop A.1: fixed-coefficient unbiased decoding has
    (1/n) E|alpha-bar - 1|^2 >= p / (d (1 - p))."""
    return p / (d * (1.0 - p))


def lower_bound_fixed_cov(p: float, d: float) -> float:
    """Remark A.2: |Cov(alpha-bar)|_2 >= 2p/(d(1-p)) for graph schemes."""
    return 2.0 * p / (d * (1.0 - p))


def adversarial_bound_graph(p: float, d: float, lam: float) -> float:
    """Cor V.2: for a d-regular graph scheme with spectral expansion
    lambda, worst-case (1/n)|alpha - 1|^2 <= (2d - lam)/(2d) * p/(1-p)."""
    return (2.0 * d - lam) / (2.0 * d) * p / (1.0 - p)


def adversarial_bound_ramanujan(p: float, d: float) -> float:
    """Cor V.3 with lam = d - o(d): ~ p / (2 (1 - p))."""
    return 0.5 * p / (1.0 - p)


def adversarial_lower_bound_graph(p: float) -> float:
    """Remark V.4: any graph scheme suffers >= p/2 (isolating mp/d
    vertices)."""
    return p / 2.0


def frc_adversarial_error(p: float) -> float:
    """Table I: the FRC's worst case is p (whole groups erased)."""
    return p


def frc_random_error(p: float, d: float) -> float:
    """[8]: the FRC achieves the Prop A.3 optimum exactly."""
    return lower_bound_any_decoding(p, d)


def sgd_iterations_bound(eps: float, eps0: float, mu: float, L: float,
                         Lp: float, r: float, s: float, n: int) -> float:
    """Cor VI.2: iterations for SGD-ALG to reach E|x_k - x*|^2 <= eps.

    r = (1/n) E|beta - 1|^2, s = |Cov(beta)|_2, sigma^2 folded into r
    via the caller (we expose the raw formula; sigma^2 enters the last
    term)."""
    raise NotImplementedError("use sgd_iterations with explicit sigma2")


def sgd_iterations(eps: float, eps0: float, mu: float, L: float, Lp: float,
                   r: float, s: float, n: int, sigma2: float) -> float:
    """Cor VI.2 iteration count."""
    return 2.0 * np.log(2.0 * eps0 / eps) * (
        s * Lp / mu + L / mu
        + r * (1.0 + 1.0 / (n - 1)) * sigma2 / (mu ** 2 * eps))


def sgd_step_size(eps: float, mu: float, L: float, Lp: float, r: float,
                  s: float, n: int, sigma2: float) -> float:
    """Cor VI.2 step size."""
    return mu * eps / (2 * mu * eps * (s * Lp + L)
                       + 2 * r * (1 + 1 / (n - 1)) * sigma2)


def adversarial_noise_floor(mu: float, Lp: float, r: float,
                            sigma2: float) -> float:
    """Cor VII.2: |theta_k - theta*|^2 converges to
    <= 4 r sigma^2 / (mu - sqrt(mu r L'))^2, provided mu > r L'."""
    gap = mu - np.sqrt(mu * r * Lp)
    if gap <= 0:
        return np.inf
    return 4.0 * r * sigma2 / gap ** 2
