"""Grid-sweep Monte-Carlo engine: a whole (p_grid x trials) campaign
through one amortized decoding pipeline per scheme.

Common-random-numbers protocol
------------------------------
``monte_carlo_error(A, p, trials=T, seed=s)`` draws its masks as
``default_rng(s).random((T, m)) >= p`` -- the *same* uniforms for every
p. The sweep makes that sharing explicit: it samples
``u ~ U[0,1)^(T, m)`` once and derives ``alive = u >= p`` for every
grid point, so per-point results are bit-identical to calling
``monte_carlo_error`` once per p with the same seed, while paying mask
sampling, graph preprocessing (``_cover_dense``) and the jax jit
compile (one (T, m) shape for the whole grid) exactly once.

Warm-started labels
-------------------
Under shared uniforms the masks are *nested in p*: lowering p only
revives machines. The graph decoder therefore walks the grid in
descending p, seeding each point's label propagation with the previous
point's fixed-point cover labels: a finer component structure whose
labels are valid upper bounds for the coarser one, so min-propagation
converges in the few rounds it takes newly revived edges to merge
components -- and, because the fixed point (per-component label
minima) is seed-independent, alphas stay bit-identical to cold starts.

The per-p statistics then run through the fused ``batched_alpha``
error kernel and, for the covariance norm, the matrix-free spectral
pipeline (``core.spectral``) -- O(trials * n * iters) Lanczos instead
of the dense n x n SVD that dominated the per-point harness at the
paper's n=2184 scale.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from ..kernels.batched_alpha import ops as _ba_ops
from .assignment import Assignment
from .batched_decoding import (batched_alpha, batched_optimal_alpha_graph,
                               is_graph_scheme)
from .spectral import covariance_spectral_norm


def bernoulli_uniforms(m: int, trials: int, seed: int = 0) -> np.ndarray:
    """The shared-uniform draw of the sweep protocol: the (trials, m)
    batch ``monte_carlo_error`` thresholds against p."""
    return np.random.default_rng(seed).random((trials, m))


def decode_grid(assignment: Assignment, masks, *, method: str = "optimal",
                p_grid: Optional[Sequence[float]] = None,
                backend: str = "auto",
                warm_start: bool = False) -> np.ndarray:
    """Decode a (P, trials, m) stack of mask batches -> (P, trials, n).

    One shared pipeline for the whole grid: graph schemes reuse the
    cached cover incidence and the single jitted propagator across all
    P points; other schemes dispatch through ``batched_alpha`` per
    point (``p_grid`` supplies the per-point p for 'fixed' decoding).

    ``warm_start=True`` chains label propagation through the grid *in
    the given order*, seeding point i+1 with point i's labels. Only
    sound when each point's alive sets contain the previous point's
    (per trial) -- e.g. a shared-uniform Bernoulli grid ordered by
    descending p; the nesting is validated (a stale label seed would
    otherwise silently corrupt alphas). Results are bit-identical
    either way; warm starts only cut propagation rounds.
    """
    masks = np.asarray(masks, dtype=bool)
    if masks.ndim != 3:
        raise ValueError(f"masks must be (P, trials, m), got {masks.shape}")
    P = masks.shape[0]
    if p_grid is not None and len(p_grid) != P:
        raise ValueError(f"p_grid has {len(p_grid)} entries for {P} "
                         "mask batches")
    if method == "fixed" and p_grid is None:
        raise ValueError("fixed decoding needs the per-point p: pass "
                         "p_grid (weights are 1/(d (1-p)))")
    out = np.empty((P, masks.shape[1], assignment.n), dtype=np.float64)
    if method == "optimal" and is_graph_scheme(assignment):
        g = assignment.graph
        labels = None
        for i in range(P):
            if warm_start:
                if i and not np.all(masks[i] >= masks[i - 1]):
                    raise ValueError(
                        "warm_start needs nested masks: grid point "
                        f"{i} revokes machines alive at point {i - 1} "
                        "(order a shared-uniform grid by descending p, "
                        "or pass warm_start=False)")
                out[i], labels = batched_optimal_alpha_graph(
                    g, masks[i], backend=backend, labels0=labels,
                    return_labels=True)
            else:
                out[i] = batched_optimal_alpha_graph(g, masks[i],
                                                     backend=backend)
    else:
        for i in range(P):
            p_i = 0.0 if p_grid is None else float(p_grid[i])
            out[i] = batched_alpha(assignment, masks[i], method=method,
                                   p=p_i, backend=backend)
    return out


def sweep_error(assignment: Assignment, p_grid: Sequence[float], *,
                trials: int, method: str = "optimal", seed: int = 0,
                debias: bool = True, backend: str = "auto",
                cov: bool = True, cov_method: str = "auto",
                warm_start: bool = True) -> List[Dict]:
    """Run the full Figure-3 grid for one scheme in one engine pass.

    Returns one dict per grid point (in ``p_grid`` order) with the
    ``monte_carlo_error`` keys plus ``p``; ``mean_error``/``std_error``
    are bit-identical to per-point ``monte_carlo_error(A, p,
    trials=trials, seed=seed)`` calls (shared-uniform protocol, same
    decode, same fused error kernel). ``cov_method`` selects the
    covariance-norm path ('dense' reproduces the historical SVD
    expression exactly; 'lanczos' is matrix-free; 'auto' switches to
    lanczos once n outgrows the dense crossover).
    """
    p_list = [float(p) for p in p_grid]
    u = bernoulli_uniforms(assignment.m, trials, seed)
    masks = np.stack([u >= p for p in p_list]) if p_list else \
        np.zeros((0, trials, assignment.m), dtype=bool)
    # Descending p = ascending alive sets: the nesting that makes
    # warm-started labels valid. Results are unsorted back afterwards.
    order = np.argsort(-np.asarray(p_list), kind="stable") if p_list \
        else np.zeros(0, dtype=np.int64)
    alphas = np.empty((len(p_list), trials, assignment.n))
    alphas[order] = decode_grid(
        assignment, masks[order], method=method,
        p_grid=[p_list[i] for i in order], backend=backend,
        warm_start=warm_start)
    rows: List[Dict] = []
    for i, p in enumerate(p_list):
        errs, scale = _ba_ops.fused_error(alphas[i], debias=debias)
        row = {
            "p": p,
            "mean_error": float(errs.mean()),
            "std_error": float(errs.std()),
        }
        if cov:
            row["cov_norm"] = covariance_spectral_norm(
                alphas[i] * scale, method=cov_method)
        rows.append(row)
    return rows
