"""Grid-sweep Monte-Carlo engine: a whole (p_grid x trials) campaign
through one amortized decoding pipeline per scheme.

Common-random-numbers protocol
------------------------------
``monte_carlo_error(A, p, trials=T, seed=s)`` draws its masks as
``default_rng(s).random((T, m)) >= p`` -- the *same* uniforms for every
p. The sweep makes that sharing explicit: it samples
``u ~ U[0,1)^(T, m)`` once and derives ``alive = u >= p`` for every
grid point, so per-point results are bit-identical to calling
``monte_carlo_error`` once per p with the same seed, while paying mask
sampling, graph preprocessing (``_cover_dense``) and the jax jit
compile (one (T, m) shape for the whole grid) exactly once.

Warm-started labels
-------------------
Under shared uniforms the masks are *nested in p*: lowering p only
revives machines. The graph decoder therefore walks the grid in
descending p, seeding each point's label propagation with the previous
point's fixed-point cover labels: a finer component structure whose
labels are valid upper bounds for the coarser one, so min-propagation
converges in the few rounds it takes newly revived edges to merge
components -- and, because the fixed point (per-component label
minima) is seed-independent, alphas stay bit-identical to cold starts.

The per-p statistics then run through the fused ``batched_alpha``
error kernel and, for the covariance norm, the matrix-free spectral
pipeline (``core.spectral``) -- O(trials * n * iters) Lanczos instead
of the dense n x n SVD that dominated the per-point harness at the
paper's n=2184 scale.

Campaigns
---------
The paper's headline comparisons are *cross-scheme* (Figure 3,
Table I: ours vs FRC vs the expander code of [6] on the same straggler
draw). ``sweep_campaign`` runs several schemes' whole grids through
one pipeline: one uniform draw and mask stack per machine count, the
entire fixed/FRC grid as one stacked exact-counts GEMM, graph decodes
warm-started per scheme, and every (scheme, p) covariance norm from
one blocked lockstep Lanczos. Per-(scheme, p) rows stay bit-identical
to per-scheme ``sweep_error`` (the oracle this engine is
differential-tested against in tests/test_campaign.py).

Scheme zoo
----------
``scheme_zoo_entries(q)`` packages the cross-paper comparison grid:
every rival construction cited in PAPERS.md, instantiated at the ONE
machine count m = q(q+1) they all share (q an affine-plane order), so
the whole zoo faces the same ``bernoulli_uniforms(m, trials, seed)``
draw. At the default q=3 (m=12, d=q+1=4) the ``CampaignEntry`` table
is:

=====================  =======================================  ===  ==========
label                  construction                             n    decode
=====================  =======================================  ===  ==========
expander:optimal       paper's d-regular vertex-transitive      6    O(m) graph
                       expander (Def II.1)
frc:fixed              fractional repetition code (Table I)     3    counts GEMM
cyclic_mds:optimal     circulant shifted code (Raviv et al.,    12   pinv Eq. 9
                       1707.03858)
bibd_affine:optimal    affine-plane AG(2,q) block design        9    pinv Eq. 9
                       (Kadhe et al., 1904.13373); load q,
                       replication q+1
random_regular:        union of d random perfect matchings      6    O(m) graph
optimal                (Charles et al., 1711.06771)
=====================  =======================================  ===  ==========

Each entry's campaign rows are pinned bit-for-bit against its own
per-point oracle -- ``sweep_error`` and scalar ``monte_carlo_error``
-- in tests/test_scheme_zoo.py, and the cyclic/BIBD adversarial worst
cases against C(m, pm) brute force in
tests/test_adversarial_oracle.py.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..kernels.batched_alpha import ops as _ba_ops
from .assignment import (Assignment, bibd_assignment,
                         cyclic_mds_assignment, expander_assignment,
                         frc_assignment, random_matching_assignment)
from .batched_decoding import (batched_alpha, fixed_alpha_grid,
                               frc_alpha_grid, is_graph_scheme)
from .spectral import (covariance_spectral_norm,
                       covariance_spectral_norm_batch, covariance_topk)


def bernoulli_uniforms(m: int, trials: int, seed: int = 0) -> np.ndarray:
    """The shared-uniform draw of the sweep protocol: the (trials, m)
    batch ``monte_carlo_error`` thresholds against p."""
    return np.random.default_rng(seed).random((trials, m))


def decode_grid(assignment: Assignment, masks, *, method: str = "optimal",
                p_grid: Optional[Sequence[float]] = None,
                backend: str = "auto",
                warm_start: bool = False) -> np.ndarray:
    """Decode a (P, trials, m) stack of mask batches -> (P, trials, n).

    One shared pipeline for the whole grid: graph schemes reuse the
    cached cover incidence and the single jitted propagator across all
    P points; other schemes dispatch through ``batched_alpha`` per
    point (``p_grid`` supplies the per-point p for 'fixed' decoding).

    ``warm_start=True`` chains label propagation through the grid *in
    the given order*, seeding point i+1 with point i's labels. Only
    sound when each point's alive sets contain the previous point's
    (per trial) -- e.g. a shared-uniform Bernoulli grid ordered by
    descending p; the nesting is validated (a stale label seed would
    otherwise silently corrupt alphas). Results are bit-identical
    either way; warm starts only cut propagation rounds.
    """
    masks = np.asarray(masks, dtype=bool)
    if masks.ndim != 3:
        raise ValueError(f"masks must be (P, trials, m), got {masks.shape}")
    P = masks.shape[0]
    if p_grid is not None and len(p_grid) != P:
        raise ValueError(f"p_grid has {len(p_grid)} entries for {P} "
                         "mask batches")
    if method == "fixed" and p_grid is None:
        raise ValueError("fixed decoding needs the per-point p: pass "
                         "p_grid (weights are 1/(d (1-p)))")
    out = np.empty((P, masks.shape[1], assignment.n), dtype=np.float64)
    if method == "optimal" and is_graph_scheme(assignment):
        # Label chaining goes through the dispatching batched_alpha
        # entry point (its labels0/return_labels plumbing), so the
        # warm-start protocol reads the same for every pipeline that
        # sits on decode_grid.
        labels = None
        for i in range(P):
            if warm_start and i and not np.all(masks[i] >= masks[i - 1]):
                raise ValueError(
                    "warm_start needs nested masks: grid point "
                    f"{i} revokes machines alive at point {i - 1} "
                    "(order a shared-uniform grid by descending p, "
                    "or pass warm_start=False)")
            out[i], labels = batched_alpha(
                assignment, masks[i], method="optimal", backend=backend,
                labels0=labels if warm_start else None,
                return_labels=True)
    else:
        for i in range(P):
            p_i = 0.0 if p_grid is None else float(p_grid[i])
            out[i] = batched_alpha(assignment, masks[i], method=method,
                                   p=p_i, backend=backend)
    return out


def sweep_error(assignment: Assignment, p_grid: Sequence[float], *,
                trials: int, method: str = "optimal", seed: int = 0,
                debias: bool = True, backend: str = "auto",
                cov: bool = True, cov_method: str = "auto",
                warm_start: bool = True) -> List[Dict]:
    """Run the full Figure-3 grid for one scheme in one engine pass.

    Returns one dict per grid point (in ``p_grid`` order) with the
    ``monte_carlo_error`` keys plus ``p``; ``mean_error``/``std_error``
    are bit-identical to per-point ``monte_carlo_error(A, p,
    trials=trials, seed=seed)`` calls (shared-uniform protocol, same
    decode, same fused error kernel). ``cov_method`` selects the
    covariance-norm path ('dense' reproduces the historical SVD
    expression exactly; 'lanczos' is matrix-free; 'auto' switches to
    lanczos once n outgrows the dense crossover).
    """
    p_list = [float(p) for p in p_grid]
    u = bernoulli_uniforms(assignment.m, trials, seed)
    masks = np.stack([u >= p for p in p_list]) if p_list else \
        np.zeros((0, trials, assignment.m), dtype=bool)
    # Descending p = ascending alive sets: the nesting that makes
    # warm-started labels valid. Results are unsorted back afterwards.
    order = np.argsort(-np.asarray(p_list), kind="stable") if p_list \
        else np.zeros(0, dtype=np.int64)
    alphas = np.empty((len(p_list), trials, assignment.n))
    alphas[order] = decode_grid(
        assignment, masks[order], method=method,
        p_grid=[p_list[i] for i in order], backend=backend,
        warm_start=warm_start)
    rows: List[Dict] = []
    for i, p in enumerate(p_list):
        errs, scale = _ba_ops.fused_error(alphas[i], debias=debias)
        row = {
            "p": p,
            "mean_error": float(errs.mean()),
            "std_error": float(errs.std()),
        }
        if cov:
            row["cov_norm"] = covariance_spectral_norm(
                alphas[i] * scale, method=cov_method)
        rows.append(row)
    return rows


# ---------------------------------------------------------------------------
# Multi-scheme campaigns
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CampaignEntry:
    """One scheme's seat in a ``sweep_campaign``.

    ``masks`` overrides the shared Bernoulli draw with an explicit
    (P, trials, m) stack -- the adversarial-attack harness, where each
    grid point's masks come from ``adversarial_mask`` rather than a
    straggler probability (warm-started labels are skipped there: the
    attack stacks are not nested in p). ``debias=False`` reports raw
    (1/n)|alpha - 1|^2 errors, as the worst-case tables do.
    """

    assignment: Assignment
    method: str = "optimal"      # 'optimal' | 'fixed'
    label: Optional[str] = None
    masks: Optional[np.ndarray] = None
    debias: bool = True

    def resolved_label(self) -> str:
        return self.label or f"{self.assignment.name}:{self.method}"


def scheme_zoo_entries(q: int = 3, *, seed: int = 0
                       ) -> List[CampaignEntry]:
    """The cross-paper comparison zoo at one shared machine count.

    m = q(q+1) is the unique count all five constructions share (see
    the module docstring's table): the affine plane of order q has
    exactly q^2 + q lines/machines, and d = q+1 then divides m (FRC),
    divides 2m (expander / random matchings), and is a valid circulant
    shift width -- so ``sweep_campaign(scheme_zoo_entries(q), ...)``
    evaluates every scheme against the SAME shared uniform draw, the
    protocol behind the paper's Figure-3/Table-I comparisons. q must
    be a prime affine-plane order (q=3 -> m=12 by default).
    """
    d, m = q + 1, q * (q + 1)
    return [
        CampaignEntry(expander_assignment(m, d, vertex_transitive=True,
                                          seed=seed),
                      method="optimal", label="expander:optimal"),
        CampaignEntry(frc_assignment(m, d), method="fixed",
                      label="frc:fixed"),
        CampaignEntry(cyclic_mds_assignment(m, d), method="optimal",
                      label="cyclic_mds:optimal"),
        CampaignEntry(bibd_assignment(q * q, q, design="affine"),
                      method="optimal", label="bibd_affine:optimal"),
        CampaignEntry(random_matching_assignment(m, d, seed=seed),
                      method="optimal", label="random_regular:optimal"),
    ]


EntryLike = Union[CampaignEntry, Assignment,
                  Tuple[Assignment, str], Tuple[Assignment, str, str]]


def _as_entry(e: EntryLike) -> CampaignEntry:
    if isinstance(e, CampaignEntry):
        return e
    if isinstance(e, Assignment):
        return CampaignEntry(assignment=e)
    if isinstance(e, tuple) and len(e) in (2, 3) and \
            isinstance(e[0], Assignment):
        return CampaignEntry(assignment=e[0], method=e[1],
                             label=e[2] if len(e) == 3 else None)
    raise TypeError(f"campaign entry must be CampaignEntry, Assignment "
                    f"or (assignment, method[, label]); got {e!r}")


def _campaign_alphas(entry: CampaignEntry, masks: np.ndarray,
                     p_list: List[float], *, backend: str,
                     warm_start: bool) -> np.ndarray:
    """(P, T, m) masks -> (P, T, n) alphas for one entry, through the
    cheapest pipeline that stays bit-identical to the per-scheme
    ``sweep_error`` oracle (see each branch)."""
    A = entry.assignment
    if entry.method == "fixed":
        # One stacked exact-counts GEMM for the whole grid
        # (bit-identical to per-point batched_fixed_alpha: integer
        # counts are summation-order-invariant).
        return fixed_alpha_grid(A, masks, p_list)
    if entry.method != "optimal":
        raise ValueError(f"unknown method {entry.method!r}")
    if is_graph_scheme(A):
        # Same descending-p / warm-started-label walk as sweep_error.
        order = np.argsort(-np.asarray(p_list), kind="stable") if \
            entry.masks is None and len(p_list) else \
            np.arange(len(p_list), dtype=np.int64)
        out = np.empty((len(p_list), masks.shape[1], A.n))
        out[order] = decode_grid(
            A, masks[order], method="optimal", backend=backend,
            warm_start=warm_start and entry.masks is None)
        return out
    if A.name.startswith("frc"):
        return frc_alpha_grid(A, masks)  # stacked exact counts
    return np.stack([batched_alpha(A, masks[i], method="optimal",
                                   backend=backend)
                     for i in range(masks.shape[0])]) if len(p_list) \
        else np.zeros((0, masks.shape[1], A.n))


def sweep_campaign(entries: Sequence[EntryLike],
                   p_grid: Sequence[float], *, trials: int,
                   seed: int = 0, backend: str = "auto",
                   debias: bool = True, cov: bool = True,
                   cov_method: str = "auto", warm_start: bool = True,
                   cov_topk: int = 0) -> Dict[str, List[Dict]]:
    """Run several schemes' whole Figure-3 grids in ONE pipeline.

    The cross-scheme protocol of the paper's headline comparisons
    (Figure 3, Table I): every scheme of the same machine count m faces
    the *same* straggler draw. The campaign samples one
    ``bernoulli_uniforms(m, trials, seed)`` per distinct m, thresholds
    the whole (P, trials, m) mask stack once, and shares it across all
    entries of that m -- so per-(scheme, p) rows are bit-identical to
    per-scheme ``sweep_error(A, p_grid, trials=trials, seed=seed,
    method=...)`` calls (and hence to per-point ``monte_carlo_error``),
    while the work the sequential loop re-pays per scheme is paid once:

    * mask sampling + thresholding, per m instead of per scheme;
    * fixed/FRC decoding as ONE stacked (P * trials, m) exact-counts
      GEMM per scheme instead of P skinny per-point matmuls;
    * graph decodes warm-started through the nested-in-p label chain
      (as in ``sweep_error``), reusing the per-graph cover cache and
      jit entry;
    * ALL (scheme, p) covariance norms through one blocked lockstep
      Lanczos over the stacked batch (``cov_method='blocked'``; 'auto'
      picks it past the dense crossover) -- a single kernel launch
      sequence instead of S*P Lanczos loops.

    ``entries`` accepts ``CampaignEntry`` (mask-stack overrides,
    per-entry debias), bare assignments (optimal decoding), or
    ``(assignment, method[, label])`` tuples. Returns an insertion-
    ordered dict label -> ``sweep_error``-shaped rows; ``cov_topk > 0``
    adds the leading covariance spectrum (``covariance_topk``) per row.
    """
    ents = [_as_entry(e) for e in entries]
    if not ents:
        raise ValueError("campaign needs at least one entry")
    labels = [e.resolved_label() for e in ents]
    if len(set(labels)) != len(labels):
        raise ValueError(f"duplicate campaign labels {labels}; pass "
                         "explicit label= to disambiguate")
    p_list = [float(p) for p in p_grid]
    P = len(p_list)

    # One shared draw + mask stack per distinct machine count.
    shared_masks: Dict[int, np.ndarray] = {}
    for e in ents:
        m = e.assignment.m
        if e.masks is None and m not in shared_masks:
            u = bernoulli_uniforms(m, trials, seed)
            shared_masks[m] = np.stack([u >= p for p in p_list]) if P \
                else np.zeros((0, trials, m), dtype=bool)

    results: Dict[str, List[Dict]] = {}
    cov_slices: List[Tuple[str, int, np.ndarray]] = []
    for e, label in zip(ents, labels):
        if e.masks is not None:
            masks = np.asarray(e.masks, dtype=bool)
            if masks.ndim != 3 or masks.shape[0] != P or \
                    masks.shape[2] != e.assignment.m:
                raise ValueError(
                    f"entry {label!r} mask stack must be (P={P}, "
                    f"trials, m={e.assignment.m}), got {masks.shape}")
        else:
            masks = shared_masks[e.assignment.m]
        alphas = _campaign_alphas(e, masks, p_list, backend=backend,
                                  warm_start=warm_start)
        rows: List[Dict] = []
        for i, p in enumerate(p_list):
            errs, scale = _ba_ops.fused_error(
                alphas[i], debias=debias and e.debias)
            rows.append({
                "p": p,
                "mean_error": float(errs.mean()),
                "std_error": float(errs.std()),
            })
            if cov or cov_topk:
                scaled = alphas[i] * scale
                if cov:
                    cov_slices.append((label, i, scaled))
                if cov_topk:
                    rows[-1]["cov_topk"] = covariance_topk(
                        scaled, cov_topk).tolist()
        results[label] = rows

    if cov_slices:
        # Group equal-(trials, n) slices so the blocked path can stack
        # them; ``covariance_spectral_norm_batch`` owns the method
        # dispatch ('dense'/'lanczos' loop the per-point oracle, i.e.
        # bit-identical to sweep_error rows with that cov_method).
        groups: Dict[Tuple[int, int], List[int]] = {}
        for idx, (_, _, s) in enumerate(cov_slices):
            groups.setdefault(s.shape, []).append(idx)
        for idxs in groups.values():
            norms = covariance_spectral_norm_batch(
                np.stack([cov_slices[i][2] for i in idxs]),
                method=cov_method)
            for i, norm in zip(idxs, norms):
                label, pt, _ = cov_slices[i]
                results[label][pt]["cov_norm"] = float(norm)
    return results
