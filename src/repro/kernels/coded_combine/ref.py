"""Pure-jnp oracle for the coded gradient combine."""

import jax.numpy as jnp


def coded_combine(grads: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """out = sum_b w[b] * grads[b].

    grads: (n_blocks, D); w: (n_blocks,). fp32 accumulation, output in
    grads.dtype.
    """
    out = jnp.einsum("b,bd->d", w.astype(jnp.float32),
                     grads.astype(jnp.float32))
    return out.astype(grads.dtype)
