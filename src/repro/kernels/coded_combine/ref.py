"""Pure-jnp oracle for the coded gradient combine, and the exact
float64 NumPy reference the quantized combine pins against."""

import jax.numpy as jnp
import numpy as np


def coded_combine(grads: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """out = sum_b w[b] * grads[b].

    grads: (n_blocks, D); w: (n_blocks,). fp32 accumulation, output in
    grads.dtype.
    """
    out = jnp.einsum("b,bd->d", w.astype(jnp.float32),
                     grads.astype(jnp.float32))
    return out.astype(grads.dtype)


def quantized_combine(q: jnp.ndarray, scales: jnp.ndarray,
                      w: jnp.ndarray) -> jnp.ndarray:
    """Fused dequantize-weight-combine, jnp fallback path.

    q: (n_blocks, D) quantized payload (int8, or float32 for the
    'none' codec); scales: (n_blocks,) float32 per-row dequant scales;
    w: (n_blocks,) decoding weights. out[d] = sum_b (w[b] * scales[b])
    * q[b, d] in float32 -- the per-machine float32 gradients are never
    materialised: the dequant scale folds into the combine weight and
    the payload feeds the accumulation chain directly.
    """
    u = w.astype(jnp.float32) * scales.astype(jnp.float32)
    acc = jnp.zeros((q.shape[1],), jnp.float32)
    for b in range(q.shape[0]):
        acc = acc + u[b] * q[b].astype(jnp.float32)
    return acc


def packed_sign_combine(q: jnp.ndarray, scales: jnp.ndarray,
                        w: jnp.ndarray, d: int) -> jnp.ndarray:
    """Fused unpack-weight-combine over packed signs, jnp fallback.

    q: (n_blocks, ceil(d/8)) uint8 bit-planes (little-endian, bit=1
    <-> +1); scales, w: (n_blocks,). Mirrors ``quantized_combine``'s
    accumulation chain with the dequant replaced by shift/mask
    unpacking -- one (8 * bytes,) sign strip per row, never an
    (n_blocks, d) float32 tile. Positions >= d (trailing-byte zero
    padding) are sliced off before they contribute.
    """
    u = w.astype(jnp.float32) * scales.astype(jnp.float32)
    shifts = jnp.arange(8, dtype=jnp.uint8)
    acc = jnp.zeros((q.shape[1] * 8,), jnp.float32)
    for b in range(q.shape[0]):
        bits = ((q[b][:, None] >> shifts) & jnp.uint8(1)).reshape(-1)
        acc = acc + u[b] * (2.0 * bits.astype(jnp.float32) - 1.0)
    return acc[:d]


def packed_sign_combine_np(q: np.ndarray, scales: np.ndarray,
                           w: np.ndarray, d: int) -> np.ndarray:
    """NumPy oracle for ``packed_sign_combine``: exact float64 combine,
    decoded by ``np.unpackbits(bitorder="little")`` -- an unpacker
    independent of the codec's own shift/mask implementation, so this
    pin cross-checks the bit-order convention as well as the
    arithmetic. Same two comparison regimes as ``quantized_combine_np``
    (bitwise on power-of-two w/scales -- a +-1 payload is integral --
    and tolerance in general).
    """
    u = (np.asarray(w, np.float32)
         * np.asarray(scales, np.float32)).astype(np.float64)
    bits = np.unpackbits(np.asarray(q, np.uint8), axis=1,
                         bitorder="little")[:, :d]
    signs = 2.0 * bits.astype(np.float64) - 1.0
    acc = np.zeros(d, np.float64)
    for b in range(q.shape[0]):
        acc = acc + u[b] * signs[b]
    return acc.astype(np.float32)


def quantized_combine_np(q: np.ndarray, scales: np.ndarray,
                         w: np.ndarray) -> np.ndarray:
    """NumPy dequantize oracle for ``quantized_combine``: the EXACT
    combine, evaluated in float64 and rounded once at the end.

    Every term is exactly representable in double: ``u_b = w_b * s_b``
    is one rounded float32 multiply (reproduced here bitwise), and a
    float32-by-float32 product needs 48 <= 53 mantissa bits, so
    ``u_b * q_bd`` carries no rounding at all in f64. For the row
    counts here the f64 accumulation is the mathematically exact sum,
    making this the codec-true reference the kernel is measured
    against.

    Two regimes of comparison (tests/test_kernels.py):

    * BITWISE on exactness-preserving inputs -- power-of-two ``w`` and
      ``scales`` with integer payloads keep every float32 partial sum
      exact (n * 127 * 2^spread << 2^24), so any accumulation order or
      FMA contraction the backend picks lands on the identical bits.
      This pin survives compiler changes by construction.
    * TOLERANCE on general inputs -- the float32 chain's rounding
      differs from exact by O(n * eps): XLA CPU contracts the chain's
      multiply-adds into FMAs *per vector lane*, a vectorization-
      dependent mix (measured: plain, natural-order FMA and
      first-product FMA coexist within one launch), so no single
      float32 emulation is bit-stable across shapes. The tolerance
      ladder entry (ROADMAP differential-testing convention) applies.
    """
    u = (np.asarray(w, np.float32)
         * np.asarray(scales, np.float32)).astype(np.float64)
    acc = np.zeros(np.asarray(q).shape[1], np.float64)
    for b in range(q.shape[0]):
        acc = acc + u[b] * np.asarray(q[b]).astype(np.float64)
    return acc.astype(np.float32)
