"""Coded gradient combine Pallas TPU kernel: out = sum_b w_b * g_b.

The decode step of the paper (Eq. 1): the parameter server's weighted
sum of per-machine gradient messages. On TPU this runs on each host
over its locally-landed gradient shards before/after the cross-replica
reduce. It is a pure VPU streaming reduction (no MXU): arithmetic
intensity is ~2 FLOPs per 4 bytes, so the kernel tiles the parameter
axis into (n_blocks, block_d) VMEM strips, reads each gradient byte
exactly once, and keeps the fp32 accumulator implicit in registers.

Grid: (D // block_d,); the weights vector (n_blocks,) is broadcast to
every step as a whole VMEM block (it is tiny).

``quantized_combine`` is the compression-composed variant: the same
streaming reduction over an int8 (or float32) payload with per-row
dequant scales folded into the combine weights -- dequantize, weight
and reduce in one pass, reading 1 byte/component off the wire format
instead of 4.

``packed_sign_combine`` pushes the wire format to its 1-bit floor: the
payload is the ``sign_packed`` codec's uint8 bit-plane (8 signs/byte,
little-endian), and the kernel unpacks (shift/mask), maps bits to
+-1, weights and reduces in one pass -- 1/8 byte/component off the
wire, and as with ``quantized_combine`` no float32 per-machine
gradient tile is ever materialised (one (block_d,) sign strip per
accumulation step).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _combine_kernel(g_ref, w_ref, o_ref):
    g = g_ref[...].astype(jnp.float32)          # (n_blocks, block_d)
    w = w_ref[...].astype(jnp.float32)          # (n_blocks,)
    o_ref[...] = (w @ g).astype(o_ref.dtype)    # (block_d,)


def _pick_block_d(n_blocks: int, d: int) -> int:
    budget = 4 * 1024 * 1024 // (4 * max(n_blocks, 1))  # ~4 MiB tile
    bd = max(128, min(d, budget))
    if bd > 128:
        bd -= bd % 128  # lane alignment
    return min(bd, d)


def _quantized_combine_kernel(q_ref, u_ref, o_ref):
    # Static unrolled fold: acc += u[b] * q[b]. Written as the
    # accumulation chain (not a matvec) so the payload dequant stays a
    # per-element cast inside the multiply-accumulate -- no float32
    # (n_blocks, block_d) gradient tile ever exists. The chain is
    # differential-tested against ref.quantized_combine_np (bitwise on
    # exactness-preserving inputs, tolerance in general -- see its
    # docstring on XLA's per-lane FMA contraction).
    q = q_ref[...]                               # (n_blocks, block_d)
    u = u_ref[...].astype(jnp.float32)           # (n_blocks,)
    acc = jnp.zeros((q.shape[1],), jnp.float32)
    for b in range(q.shape[0]):
        acc = acc + u[b] * q[b].astype(jnp.float32)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def quantized_combine(q: jnp.ndarray, scales: jnp.ndarray,
                      w: jnp.ndarray, *, block_d: int | None = None,
                      interpret: bool = False) -> jnp.ndarray:
    """Fused dequantize-weight-combine: (n_blocks, D) quantized payload
    + (n_blocks,) scales + (n_blocks,) decoding weights -> (D,) float32.

    The dequant scale folds into the combine weight on the host side of
    the launch (u = w * scales, one tiny elementwise op), so the kernel
    streams the compressed payload once -- 1 byte/component for the
    int8/sign codecs against the float32 combine's 4 -- and the float32
    per-machine gradients are never materialised. Padding rows of the
    parameter axis contribute exact zeros (u * 0). Note the int8 native
    tile on TPU is (32, 128); smoke-scale n_blocks rides interpret mode
    (CPU CI) where the constraint does not bind.
    """
    n_blocks, d = q.shape
    u = w.astype(jnp.float32) * scales.astype(jnp.float32)
    bd = block_d or _pick_block_d(n_blocks, d)
    pad = (-d) % bd
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad)))
    padded_d = q.shape[1]
    out = pl.pallas_call(
        _quantized_combine_kernel,
        grid=(padded_d // bd,),
        in_specs=[
            pl.BlockSpec((n_blocks, bd), lambda i: (0, i)),
            pl.BlockSpec((n_blocks,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bd,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded_d,), jnp.float32),
        interpret=interpret,
    )(q, u)
    return out[:d] if pad else out


def _packed_sign_combine_kernel(q_ref, u_ref, o_ref):
    # Same accumulation-chain shape as _quantized_combine_kernel, with
    # the dequant replaced by an in-register unpack: shift/mask the
    # byte tile into its 8 bit planes, map {0,1} -> {-1,+1}, and fold
    # u[b] * sign into the accumulator one row strip at a time.
    q = q_ref[...]                               # (n_blocks, block_db) u8
    u = u_ref[...].astype(jnp.float32)           # (n_blocks,)
    shifts = jnp.arange(8, dtype=jnp.uint8)
    acc = jnp.zeros((q.shape[1] * 8,), jnp.float32)
    for b in range(q.shape[0]):
        bits = ((q[b][:, None] >> shifts) & jnp.uint8(1)).reshape(-1)
        acc = acc + u[b] * (2.0 * bits.astype(jnp.float32) - 1.0)
    o_ref[...] = acc


def _pick_block_db(n_blocks: int, db: int) -> int:
    # Per grid step: n_blocks * block_db payload bytes + 32 * block_db
    # bytes of unpacked f32 strip/accumulator.
    budget = 4 * 1024 * 1024 // (max(n_blocks, 1) + 32)
    bd = max(128, min(db, budget))
    if bd > 128:
        bd -= bd % 128  # byte-lane alignment (f32 out stays 128-lane)
    return min(bd, db)


@functools.partial(jax.jit,
                   static_argnames=("d", "block_db", "interpret"))
def packed_sign_combine(q: jnp.ndarray, scales: jnp.ndarray,
                        w: jnp.ndarray, *, d: int,
                        block_db: int | None = None,
                        interpret: bool = False) -> jnp.ndarray:
    """Fused unpack-dequantize-weight-combine over a packed sign
    payload: (n_blocks, ceil(d/8)) uint8 bit-planes + (n_blocks,)
    scales + (n_blocks,) decoding weights -> (d,) float32.

    ``d`` is the true component count (static): byte padding -- both
    the codec's trailing-byte zero bits and the grid's block padding --
    unpacks to -1 signs at positions >= d, which the final slice
    drops before they can contribute. As in ``quantized_combine`` the
    dequant scale folds into the combine weight outside the grid
    (u = w * scales), dead rows contribute exact zeros (u_b = 0), and
    the uint8 native tile on TPU is (32, 128); smoke-scale n_blocks
    rides interpret mode (CPU CI) where the constraint does not bind.
    """
    n_blocks, db = q.shape
    if db != (d + 7) // 8:
        raise ValueError(f"payload width {db} != ceil({d}/8)")
    u = w.astype(jnp.float32) * scales.astype(jnp.float32)
    bd = block_db or _pick_block_db(n_blocks, db)
    pad = (-db) % bd
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad)))
    padded_db = q.shape[1]
    out = pl.pallas_call(
        _packed_sign_combine_kernel,
        grid=(padded_db // bd,),
        in_specs=[
            pl.BlockSpec((n_blocks, bd), lambda i: (0, i)),
            pl.BlockSpec((n_blocks,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((8 * bd,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((8 * padded_db,), jnp.float32),
        interpret=interpret,
    )(q, u)
    return out[:d]


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def coded_combine(grads: jnp.ndarray, w: jnp.ndarray, *,
                  block_d: int | None = None,
                  interpret: bool = False) -> jnp.ndarray:
    """grads: (n_blocks, D); w: (n_blocks,) -> (D,) in grads.dtype."""
    n_blocks, d = grads.shape
    bd = block_d or _pick_block_d(n_blocks, d)
    pad = (-d) % bd
    if pad:
        grads = jnp.pad(grads, ((0, 0), (0, pad)))
    padded_d = grads.shape[1]
    out = pl.pallas_call(
        _combine_kernel,
        grid=(padded_d // bd,),
        in_specs=[
            pl.BlockSpec((n_blocks, bd), lambda i: (0, i)),
            pl.BlockSpec((n_blocks,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bd,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded_d,), grads.dtype),
        interpret=interpret,
    )(grads, w)
    return out[:d] if pad else out
