"""Coded gradient combine Pallas TPU kernel: out = sum_b w_b * g_b.

The decode step of the paper (Eq. 1): the parameter server's weighted
sum of per-machine gradient messages. On TPU this runs on each host
over its locally-landed gradient shards before/after the cross-replica
reduce. It is a pure VPU streaming reduction (no MXU): arithmetic
intensity is ~2 FLOPs per 4 bytes, so the kernel tiles the parameter
axis into (n_blocks, block_d) VMEM strips, reads each gradient byte
exactly once, and keeps the fp32 accumulator implicit in registers.

Grid: (D // block_d,); the weights vector (n_blocks,) is broadcast to
every step as a whole VMEM block (it is tiny).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _combine_kernel(g_ref, w_ref, o_ref):
    g = g_ref[...].astype(jnp.float32)          # (n_blocks, block_d)
    w = w_ref[...].astype(jnp.float32)          # (n_blocks,)
    o_ref[...] = (w @ g).astype(o_ref.dtype)    # (block_d,)


def _pick_block_d(n_blocks: int, d: int) -> int:
    budget = 4 * 1024 * 1024 // (4 * max(n_blocks, 1))  # ~4 MiB tile
    bd = max(128, min(d, budget))
    if bd > 128:
        bd -= bd % 128  # lane alignment
    return min(bd, d)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def coded_combine(grads: jnp.ndarray, w: jnp.ndarray, *,
                  block_d: int | None = None,
                  interpret: bool = False) -> jnp.ndarray:
    """grads: (n_blocks, D); w: (n_blocks,) -> (D,) in grads.dtype."""
    n_blocks, d = grads.shape
    bd = block_d or _pick_block_d(n_blocks, d)
    pad = (-d) % bd
    if pad:
        grads = jnp.pad(grads, ((0, 0), (0, pad)))
    padded_d = grads.shape[1]
    out = pl.pallas_call(
        _combine_kernel,
        grid=(padded_d // bd,),
        in_specs=[
            pl.BlockSpec((n_blocks, bd), lambda i: (0, i)),
            pl.BlockSpec((n_blocks,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bd,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded_d,), grads.dtype),
        interpret=interpret,
    )(grads, w)
    return out[:d] if pad else out
