"""Coded gradient combine Pallas TPU kernel: out = sum_b w_b * g_b.

The decode step of the paper (Eq. 1): the parameter server's weighted
sum of per-machine gradient messages. On TPU this runs on each host
over its locally-landed gradient shards before/after the cross-replica
reduce. It is a pure VPU streaming reduction (no MXU): arithmetic
intensity is ~2 FLOPs per 4 bytes, so the kernel tiles the parameter
axis into (n_blocks, block_d) VMEM strips, reads each gradient byte
exactly once, and keeps the fp32 accumulator implicit in registers.

Grid: (D // block_d,); the weights vector (n_blocks,) is broadcast to
every step as a whole VMEM block (it is tiny).

``quantized_combine`` is the compression-composed variant: the same
streaming reduction over an int8 (or float32) payload with per-row
dequant scales folded into the combine weights -- dequantize, weight
and reduce in one pass, reading 1 byte/component off the wire format
instead of 4.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _combine_kernel(g_ref, w_ref, o_ref):
    g = g_ref[...].astype(jnp.float32)          # (n_blocks, block_d)
    w = w_ref[...].astype(jnp.float32)          # (n_blocks,)
    o_ref[...] = (w @ g).astype(o_ref.dtype)    # (block_d,)


def _pick_block_d(n_blocks: int, d: int) -> int:
    budget = 4 * 1024 * 1024 // (4 * max(n_blocks, 1))  # ~4 MiB tile
    bd = max(128, min(d, budget))
    if bd > 128:
        bd -= bd % 128  # lane alignment
    return min(bd, d)


def _quantized_combine_kernel(q_ref, u_ref, o_ref):
    # Static unrolled fold: acc += u[b] * q[b]. Written as the
    # accumulation chain (not a matvec) so the payload dequant stays a
    # per-element cast inside the multiply-accumulate -- no float32
    # (n_blocks, block_d) gradient tile ever exists. The chain is
    # differential-tested against ref.quantized_combine_np (bitwise on
    # exactness-preserving inputs, tolerance in general -- see its
    # docstring on XLA's per-lane FMA contraction).
    q = q_ref[...]                               # (n_blocks, block_d)
    u = u_ref[...].astype(jnp.float32)           # (n_blocks,)
    acc = jnp.zeros((q.shape[1],), jnp.float32)
    for b in range(q.shape[0]):
        acc = acc + u[b] * q[b].astype(jnp.float32)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def quantized_combine(q: jnp.ndarray, scales: jnp.ndarray,
                      w: jnp.ndarray, *, block_d: int | None = None,
                      interpret: bool = False) -> jnp.ndarray:
    """Fused dequantize-weight-combine: (n_blocks, D) quantized payload
    + (n_blocks,) scales + (n_blocks,) decoding weights -> (D,) float32.

    The dequant scale folds into the combine weight on the host side of
    the launch (u = w * scales, one tiny elementwise op), so the kernel
    streams the compressed payload once -- 1 byte/component for the
    int8/sign codecs against the float32 combine's 4 -- and the float32
    per-machine gradients are never materialised. Padding rows of the
    parameter axis contribute exact zeros (u * 0). Note the int8 native
    tile on TPU is (32, 128); smoke-scale n_blocks rides interpret mode
    (CPU CI) where the constraint does not bind.
    """
    n_blocks, d = q.shape
    u = w.astype(jnp.float32) * scales.astype(jnp.float32)
    bd = block_d or _pick_block_d(n_blocks, d)
    pad = (-d) % bd
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad)))
    padded_d = q.shape[1]
    out = pl.pallas_call(
        _quantized_combine_kernel,
        grid=(padded_d // bd,),
        in_specs=[
            pl.BlockSpec((n_blocks, bd), lambda i: (0, i)),
            pl.BlockSpec((n_blocks,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bd,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded_d,), jnp.float32),
        interpret=interpret,
    )(q, u)
    return out[:d] if pad else out


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def coded_combine(grads: jnp.ndarray, w: jnp.ndarray, *,
                  block_d: int | None = None,
                  interpret: bool = False) -> jnp.ndarray:
    """grads: (n_blocks, D); w: (n_blocks,) -> (D,) in grads.dtype."""
    n_blocks, d = grads.shape
    bd = block_d or _pick_block_d(n_blocks, d)
    pad = (-d) % bd
    if pad:
        grads = jnp.pad(grads, ((0, 0), (0, pad)))
    padded_d = grads.shape[1]
    out = pl.pallas_call(
        _combine_kernel,
        grid=(padded_d // bd,),
        in_specs=[
            pl.BlockSpec((n_blocks, bd), lambda i: (0, i)),
            pl.BlockSpec((n_blocks,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bd,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded_d,), grads.dtype),
        interpret=interpret,
    )(grads, w)
    return out[:d] if pad else out
