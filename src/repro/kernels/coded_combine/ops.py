"""Public wrapper for the coded gradient combine.

Applies w-weighted summation across the leading (block/machine) axis of
every leaf of a gradient pytree. Backend dispatch as in the other
kernels. No custom_vjp: this runs on gradients (no higher-order autodiff
needed in the training loop); the jnp fallback remains differentiable
anyway.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel, ref

_FORCE = None  # None | "ref" | "pallas"


def coded_combine(grads: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """grads: (n_blocks, D); w: (n_blocks,) -> (D,)."""
    if _FORCE == "ref":
        return ref.coded_combine(grads, w)
    if _FORCE == "pallas":
        return kernel.coded_combine(
            grads, w, interpret=jax.default_backend() != "tpu")
    if jax.default_backend() == "tpu":
        return kernel.coded_combine(grads, w)
    return ref.coded_combine(grads, w)


def coded_combine_tree(grad_tree, w: jnp.ndarray):
    """Weighted-sum the leading axis of every leaf: leaf (n_blocks, ...)
    -> (...). Leaves are flattened to (n_blocks, -1) for the kernel."""
    def one(leaf):
        n = leaf.shape[0]
        flat = leaf.reshape(n, -1)
        return coded_combine(flat, w).reshape(leaf.shape[1:])
    return jax.tree.map(one, grad_tree)
