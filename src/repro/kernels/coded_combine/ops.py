"""Public wrapper for the coded gradient combine.

Applies w-weighted summation across the leading (block/machine) axis of
every leaf of a gradient pytree. Backend dispatch as in the other
kernels. No custom_vjp: this runs on gradients (no higher-order autodiff
needed in the training loop); the jnp fallback remains differentiable
anyway.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from . import kernel, ref

_FORCE = None  # None | "ref" | "pallas"


def coded_combine(grads: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """grads: (n_blocks, D); w: (n_blocks,) -> (D,)."""
    if _FORCE == "ref":
        return ref.coded_combine(grads, w)
    if _FORCE == "pallas":
        return kernel.coded_combine(
            grads, w, interpret=jax.default_backend() != "tpu")
    if jax.default_backend() == "tpu":
        return kernel.coded_combine(grads, w)
    return ref.coded_combine(grads, w)


def coded_combine_tree(grad_tree, w: jnp.ndarray):
    """Weighted-sum the leading axis of every leaf: leaf (n_blocks, ...)
    -> (...). Leaves are flattened to (n_blocks, -1) for the kernel."""
    def one(leaf):
        n = leaf.shape[0]
        flat = leaf.reshape(n, -1)
        return coded_combine(flat, w).reshape(leaf.shape[1:])
    return jax.tree.map(one, grad_tree)


def quantized_combine(q: jnp.ndarray, scales: jnp.ndarray,
                      w: jnp.ndarray) -> jnp.ndarray:
    """q: (n_blocks, D) payload; scales, w: (n_blocks,) -> (D,) f32."""
    if _FORCE == "ref":
        return ref.quantized_combine(q, scales, w)
    if _FORCE == "pallas":
        return kernel.quantized_combine(
            q, scales, w, interpret=jax.default_backend() != "tpu")
    if jax.default_backend() == "tpu":
        return kernel.quantized_combine(q, scales, w)
    return ref.quantized_combine(q, scales, w)


def packed_sign_combine(q: jnp.ndarray, scales: jnp.ndarray,
                        w: jnp.ndarray, d: int) -> jnp.ndarray:
    """q: (n_blocks, ceil(d/8)) packed signs; scales, w: (n_blocks,)
    -> (d,) f32."""
    if _FORCE == "ref":
        return ref.packed_sign_combine(q, scales, w, d)
    if _FORCE == "pallas":
        return kernel.packed_sign_combine(
            q, scales, w, d=d, interpret=jax.default_backend() != "tpu")
    if jax.default_backend() == "tpu":
        return kernel.packed_sign_combine(q, scales, w, d=d)
    return ref.packed_sign_combine(q, scales, w, d)


def packed_sign_combine_tree(q_tree, scale_tree, w: jnp.ndarray, shapes):
    """Fused unpack-weight-combine over a packed-sign payload pytree.

    ``q_tree`` leaves are (n_blocks, ceil(size/8)) uint8 bit-planes --
    the packed payload cannot carry its own unpacked width, so
    ``shapes`` is the matching pytree of combined-output shapes (each
    original leaf's shape with the leading row axis dropped). Dead
    rows' payloads never contribute (w_b * scale_b == 0 exactly), as
    in ``quantized_combine_tree``.
    """
    q_leaves, treedef = jax.tree.flatten(q_tree)
    s_leaves = treedef.flatten_up_to(scale_tree)
    shape_leaves = treedef.flatten_up_to(shapes)
    outs = []
    for q, s, shp in zip(q_leaves, s_leaves, shape_leaves):
        d = math.prod(shp)
        out = packed_sign_combine(q.reshape(q.shape[0], -1), s, w, d)
        outs.append(out.reshape(tuple(shp)))
    return jax.tree.unflatten(treedef, outs)


def quantized_combine_tree(q_tree, scale_tree, w: jnp.ndarray):
    """Fused dequantize-weight-combine over a payload pytree.

    ``q_tree`` leaves are (n_blocks, ...) quantized payloads,
    ``scale_tree`` the matching (n_blocks,) per-row scales; returns the
    float32 combined tree with the leading axis reduced away. The
    combine weights carry the decode's straggler zeros, so dead rows'
    payloads never contribute (w_b * scale_b == 0 exactly).
    """
    q_leaves, treedef = jax.tree.flatten(q_tree)
    s_leaves = treedef.flatten_up_to(scale_tree)
    outs = []
    for q, s in zip(q_leaves, s_leaves):
        n = q.shape[0]
        flat = q.reshape(n, -1)
        outs.append(quantized_combine(flat, s, w).reshape(q.shape[1:]))
    return jax.tree.unflatten(treedef, outs)
