"""jit'd public wrapper for RMSNorm.

Differentiable everywhere: custom_vjp whose forward dispatches to the
Pallas kernel on TPU (ref oracle elsewhere) and whose backward is the
closed-form jnp gradient. ``force`` overrides dispatch for tests:
"pallas" (interpret on CPU), "ref", or None (auto).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel, ref

_FORCE = None  # test hook: None | "ref" | "pallas"


def _forward(x, scale, eps):
    if _FORCE == "ref":
        return ref.rmsnorm(x, scale, eps=eps)
    if _FORCE == "pallas":
        return kernel.rmsnorm(x, scale, eps=eps,
                              interpret=jax.default_backend() != "tpu")
    if jax.default_backend() == "tpu":
        return kernel.rmsnorm(x, scale, eps=eps)
    return ref.rmsnorm(x, scale, eps=eps)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rmsnorm(x, scale, eps=1e-6):
    return _forward(x, scale, eps)


def _fwd(x, scale, eps):
    return _forward(x, scale, eps), (x, scale)


def _bwd(eps, res, g):
    x, scale = res
    xf = x.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    sf = scale.astype(jnp.float32)
    d = x.shape[-1]
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    inv = (var + eps) ** -0.5
    xhat = xf * inv
    # y = xhat * scale
    dscale = jnp.sum(gf * xhat, axis=tuple(range(x.ndim - 1)))
    gx_hat = gf * sf
    # dxhat/dx: inv * (I - xhat xhat^T / d)
    dx = inv * (gx_hat - xhat * jnp.mean(gx_hat * xhat, axis=-1,
                                         keepdims=True))
    return dx.astype(x.dtype), dscale.astype(scale.dtype)


rmsnorm.defvjp(_fwd, _bwd)
