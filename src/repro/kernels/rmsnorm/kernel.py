"""Fused RMSNorm Pallas TPU kernel.

Tiling: grid over row blocks; each step loads a (block_rows, d) VMEM
tile, reduces mean-of-squares in fp32 on the VPU, rescales, and writes
back. ``d`` stays whole per tile (the reduction axis must be resident);
block_rows is chosen so the tile fits comfortably in VMEM
(block_rows * d * 4B <= ~2 MiB), with the row dimension padded to the
8-sublane boundary by pallas.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def _pick_block_rows(rows: int, d: int) -> int:
    budget = 2 * 1024 * 1024 // (4 * max(d, 1))  # ~2 MiB fp32 tile
    br = max(8, min(rows, budget))
    # round down to a multiple of 8 sublanes when possible
    if br > 8:
        br -= br % 8
    return max(1, min(br, rows))


@functools.partial(jax.jit, static_argnames=("eps", "interpret",
                                             "block_rows"))
def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, *, eps: float = 1e-6,
            interpret: bool = False, block_rows: int | None = None
            ) -> jnp.ndarray:
    """x: (..., d); scale: (d,). Returns same shape/dtype as x."""
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = block_rows or _pick_block_rows(rows, d)
    # pad rows to a multiple of br
    pad = (-rows) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    grid = (x2.shape[0] // br,)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, scale)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
