"""Pure-jnp oracle for the fused RMSNorm kernel."""

import jax.numpy as jnp


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray,
            eps: float = 1e-6) -> jnp.ndarray:
    """y = x / sqrt(mean(x^2) + eps) * scale, reduction in fp32."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * (var + eps) ** -0.5
    return (y * scale.astype(jnp.float32)).astype(x.dtype)
