"""NumPy float64 oracle for the fused tall-skinny Gram matvec.

This is the CPU path of the matrix-free spectral pipeline: Lanczos in
``core.spectral`` drives all its large-array work through this matvec,
and float64 here is what lets the matrix-free covariance norm match the
dense SVD to ~1e-8 relative off-TPU.
"""

import numpy as np


def gram_matvec(x: np.ndarray, v: np.ndarray) -> np.ndarray:
    """x: (R, k), v: (k,) -> x^T (x v), all float64.

    Two passes over x (the tall operand) and never materializes the
    (k, k) Gram matrix -- O(R * k) per call.
    """
    x = np.asarray(x, dtype=np.float64)
    v = np.asarray(v, dtype=np.float64)
    return x.T @ (x @ v)


def gram_matvec_block(x: np.ndarray, V: np.ndarray) -> np.ndarray:
    """x: (R, k), V: (k, b) -> x^T (x V), all float64.

    The block-Lanczos form of the Gram matvec (b right-hand sides per
    sweep over x); still never materializes the (k, k) Gram matrix.
    """
    x = np.asarray(x, dtype=np.float64)
    V = np.asarray(V, dtype=np.float64)
    return x.T @ (x @ V)


def gram_matvec_batch(x: np.ndarray, v: np.ndarray) -> np.ndarray:
    """x: (B, R, k), v: (B, k) -> (B, k) per-slice x_b^T (x_b v_b).

    The blocked-Lanczos workhorse: one call applies every slice's Gram
    operator (the sweep campaign stacks all (scheme, p) covariance
    batches into one operand). On CPU the per-slice GEMV loop *is* the
    fastest float64 formulation (batched einsum/GEMM lose to clean
    BLAS strides at these shapes), and it keeps the batch oracle
    definitionally consistent with the single-slice one; the fused
    single-launch-sequence form lives in the Pallas kernel.
    """
    x = np.asarray(x, dtype=np.float64)
    v = np.asarray(v, dtype=np.float64)
    if x.shape[0] == 0:
        return np.zeros_like(v)
    return np.stack([gram_matvec(x[i], v[i]) for i in range(x.shape[0])])
