"""NumPy float64 oracle for the fused tall-skinny Gram matvec.

This is the CPU path of the matrix-free spectral pipeline: Lanczos in
``core.spectral`` drives all its large-array work through this matvec,
and float64 here is what lets the matrix-free covariance norm match the
dense SVD to ~1e-8 relative off-TPU.
"""

import numpy as np


def gram_matvec(x: np.ndarray, v: np.ndarray) -> np.ndarray:
    """x: (R, k), v: (k,) -> x^T (x v), all float64.

    Two passes over x (the tall operand) and never materializes the
    (k, k) Gram matrix -- O(R * k) per call.
    """
    x = np.asarray(x, dtype=np.float64)
    v = np.asarray(v, dtype=np.float64)
    return x.T @ (x @ v)
