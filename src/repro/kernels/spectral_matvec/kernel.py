"""Fused tall-skinny Gram matvec Pallas TPU kernel: y = X^T (X v).

The matrix-free spectral pipeline (``core.spectral``) estimates
|Cov|_2 by Lanczos iteration whose only large-array work is this Gram
matvec against the centered (trials, n) alpha batch (or its transpose,
whichever orientation is tall-skinny). Each grid step owns a
(block_r, k) VMEM strip of X: it computes the strip's projection
y = X_blk v and immediately folds X_blk^T y into the (1, k) output
block on the MXU, so X streams through VMEM exactly once per matvec
and no (R,)-sized intermediate ever round-trips to HBM.

Grid: (R // block_r,); the output BlockSpec maps every step to the same
(1, k) tile (initialised at step 0) -- the standard revisiting-
accumulator pattern, safe because TPU grid steps run sequentially. The
k axis pads to the 128-lane boundary and R to the block size, both
with zeros (zero rows/columns contribute exactly zero).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block_r(rows: int, k: int) -> int:
    budget = 2 * 1024 * 1024 // (4 * max(k, 1))  # ~2 MiB strip
    br = max(8, min(rows, budget))
    if br > 8:
        br -= br % 8  # sublane alignment
    return br


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def gram_matvec(x: jnp.ndarray, v: jnp.ndarray, *,
                block_r: int | None = None,
                interpret: bool = False) -> jnp.ndarray:
    """x: (R, k); v: (k,) or (bv, k) -> float32 X^T (X v).

    A 1-D ``v`` returns (k,); a 2-D ``v`` is bv stacked right-hand
    sides (the block-Lanczos case) and returns (bv, k) -- the same
    revisiting-accumulator kernel, with the (1, kp) projection/output
    tiles widened to (bv, kp) so all bv columns ride one pass over X.
    """
    vec = v.ndim == 1
    rows, k = x.shape
    x = x.astype(jnp.float32)
    v = jnp.asarray(v, jnp.float32).reshape(-1, k)
    bv = v.shape[0]
    pad_k = (-k) % 128
    if pad_k:
        x = jnp.pad(x, ((0, 0), (0, pad_k)))
        v = jnp.pad(v, ((0, 0), (0, pad_k)))
    kp = k + pad_k
    br = block_r or _pick_block_r(rows, kp)
    pad_r = (-rows) % br
    if pad_r:
        x = jnp.pad(x, ((0, pad_r), (0, 0)))

    def body(x_ref, v_ref, o_ref):
        @pl.when(pl.program_id(0) == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        xb = x_ref[...]                              # (br, kp)
        y = jax.lax.dot_general(                     # (br, bv) = X_blk V^T
            xb, v_ref[...], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        o_ref[...] += jax.lax.dot_general(           # (bv, kp) = Y^T X_blk
            y, xb, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    out = pl.pallas_call(
        body,
        grid=((rows + pad_r) // br,),
        in_specs=[
            pl.BlockSpec((br, kp), lambda i: (i, 0)),
            pl.BlockSpec((bv, kp), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bv, kp), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((bv, kp), jnp.float32),
        interpret=interpret,
    )(x, v)
    return out[0, :k] if vec else out[:, :k]


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def gram_matvec_batch(x: jnp.ndarray, v: jnp.ndarray, *,
                      block_r: int | None = None,
                      interpret: bool = False) -> jnp.ndarray:
    """x: (B, R, k); v: (B, k) -> (B, k) float32 per-slice X^T (X v).

    The lockstep-Lanczos batch form: grid (B, R // block_r) with the
    row axis innermost, so each slice's (1, 1, kp) output tile is
    revisited consecutively (the sequential-grid accumulator pattern of
    the single-slice kernel) and the whole stack runs in one kernel
    launch sequence instead of B.
    """
    nb, rows, k = x.shape
    x = x.astype(jnp.float32)
    v = jnp.asarray(v, jnp.float32).reshape(nb, 1, k)
    pad_k = (-k) % 128
    if pad_k:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad_k)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k)))
    kp = k + pad_k
    br = block_r or _pick_block_r(rows, kp)
    pad_r = (-rows) % br
    if pad_r:
        x = jnp.pad(x, ((0, 0), (0, pad_r), (0, 0)))

    def body(x_ref, v_ref, o_ref):
        @pl.when(pl.program_id(1) == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        xb = x_ref[0]                                # (br, kp)
        y = jax.lax.dot_general(                     # (br, 1) = X_blk v
            xb, v_ref[0], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        o_ref[0] += jax.lax.dot_general(             # (1, kp) = y^T X_blk
            y, xb, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    out = pl.pallas_call(
        body,
        grid=(nb, (rows + pad_r) // br),
        in_specs=[
            pl.BlockSpec((1, br, kp), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, 1, kp), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, kp), lambda b, i: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, 1, kp), jnp.float32),
        interpret=interpret,
    )(x, v)
    return out[:, 0, :k]
