"""Public wrapper for the fused tall-skinny Gram matvec.

Backend dispatch as in the other kernel packages: the Pallas kernel on
TPU, the float64 NumPy oracle on CPU. Note the TPU path accumulates in
float32; the 1e-8-grade agreement of the matrix-free covariance norm
with the dense SVD is a property of the CPU/float64 path (callers that
enforce tolerances should branch on ``uses_pallas()``).
"""

from __future__ import annotations

import numpy as np

from . import ref

_FORCE = None  # None | "ref" | "pallas"


def _dispatch():
    """-> ('ref', False) or ('pallas', interpret)."""
    if _FORCE == "ref":
        return "ref", False
    use_pallas = _FORCE == "pallas"
    interpret = False
    if use_pallas or _FORCE is None:
        try:
            import jax

            on_tpu = jax.default_backend() == "tpu"
        except Exception:  # pragma: no cover
            on_tpu = False
        if use_pallas:
            interpret = not on_tpu
        else:
            use_pallas = on_tpu
    return ("pallas", interpret) if use_pallas else ("ref", False)


def uses_pallas() -> bool:
    """True when gram_matvec will run the float32 Pallas kernel."""
    return _dispatch()[0] == "pallas"


def prepare_operand(x):
    """Stage the tall operand once for a run of gram_matvec calls
    (e.g. a Lanczos iteration): device float32 when the Pallas path is
    active -- avoiding a host upload per matvec -- float64 NumPy
    otherwise (a no-copy view for float64 input). Also stages stacked
    (B, R, k) operands for the batch/lockstep calls."""
    if uses_pallas():
        import jax.numpy as jnp

        return jnp.asarray(x, jnp.float32)
    return np.asarray(x, np.float64)


def gram_matvec(x, v) -> np.ndarray:
    """x: (R, k), v: (k,) -> x^T (x v) as float64 NumPy.

    ``x`` may be a NumPy array or an operand staged by
    ``prepare_operand`` (passed through without a host round-trip).
    """
    v = np.asarray(v)
    if getattr(x, "ndim", 0) != 2 or v.shape != (x.shape[1],):
        raise ValueError(f"need x (R, k) and v (k,), got "
                         f"{getattr(x, 'shape', None)} and {v.shape}")
    mode, interpret = _dispatch()
    if mode == "pallas":
        import jax.numpy as jnp

        from . import kernel

        out = kernel.gram_matvec(jnp.asarray(x, jnp.float32),
                                 jnp.asarray(v, jnp.float32),
                                 interpret=interpret)
        return np.asarray(out, np.float64)
    return ref.gram_matvec(x, v)


def gram_matvec_block(x, V) -> np.ndarray:
    """x: (R, k), V: (k, b) -> x^T (x V) as float64 NumPy -- the
    block-Lanczos form (b right-hand sides per pass over x)."""
    V = np.asarray(V)
    if getattr(x, "ndim", 0) != 2 or V.ndim != 2 or \
            V.shape[0] != x.shape[1]:
        raise ValueError(f"need x (R, k) and V (k, b), got "
                         f"{getattr(x, 'shape', None)} and {V.shape}")
    mode, interpret = _dispatch()
    if mode == "pallas":
        import jax.numpy as jnp

        from . import kernel

        out = kernel.gram_matvec(jnp.asarray(x, jnp.float32),
                                 jnp.asarray(V.T, jnp.float32),
                                 interpret=interpret)
        return np.asarray(out, np.float64).T
    return ref.gram_matvec_block(x, V)


def gram_matvec_batch(x, v) -> np.ndarray:
    """x: (B, R, k), v: (B, k) -> (B, k) per-slice x_b^T (x_b v_b) as
    float64 NumPy -- the lockstep-Lanczos batch form (one fused pass
    over the whole stack per iteration).

    ``x`` may be staged by ``prepare_operand`` (device-resident on the
    Pallas path, so only the small (B, k) vectors travel per call).
    """
    v = np.asarray(v)
    if getattr(x, "ndim", 0) != 3 or \
            v.shape != (x.shape[0], x.shape[2]):
        raise ValueError(f"need x (B, R, k) and v (B, k), got "
                         f"{getattr(x, 'shape', None)} and {v.shape}")
    mode, interpret = _dispatch()
    if mode == "pallas":
        import jax.numpy as jnp

        from . import kernel

        out = kernel.gram_matvec_batch(jnp.asarray(x, jnp.float32),
                                       jnp.asarray(v, jnp.float32),
                                       interpret=interpret)
        return np.asarray(out, np.float64)
    return ref.gram_matvec_batch(x, v)
