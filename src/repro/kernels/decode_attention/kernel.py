"""Flash-decode Pallas TPU kernel: one query token vs a long KV cache.

This is the serving hot spot for ``decode_32k`` / ``long_500k``: the op
is entirely memory-bound (arithmetic intensity ~ 1 FLOP/byte), so the
kernel's job is to stream K/V through VMEM exactly once in MXU-friendly
tiles while keeping the online-softmax state (m, l, acc) resident.

Grid: (B, KVH, S // block_k). TPU iterates the last axis sequentially,
so the (m, l, acc) VMEM scratch accumulates across the KV blocks of one
(batch, kv-head) pair and is reset when the block index wraps to 0.
K/V tiles are (block_k, Dh) VMEM blocks; the G = H/KVH query heads of
the group stay resident as a (G, Dh) tile. ``lengths`` rides in SMEM via
scalar prefetch so the mask needs no extra HBM traffic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_attn_kernel(lengths_ref,  # scalar prefetch: (B,) int32 SMEM
                        q_ref,        # (1, 1, G, Dh) VMEM
                        k_ref,        # (1, block_k, 1, Dh) VMEM
                        v_ref,        # (1, block_k, 1, Dh) VMEM
                        o_ref,        # (1, 1, G, Dh) VMEM
                        m_ref, l_ref, acc_ref,  # VMEM scratch
                        *, block_k: int, scale: float):
    b = pl.program_id(0)
    s = pl.program_id(2)
    num_s = pl.num_programs(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (G, Dh)
    k = k_ref[0, :, 0].astype(jnp.float32)         # (block_k, Dh)
    v = v_ref[0, :, 0].astype(jnp.float32)         # (block_k, Dh)

    scores = (q @ k.T) * scale                      # (G, block_k)
    length = lengths_ref[b]
    positions = s * block_k + jax.lax.broadcasted_iota(
        jnp.int32, scores.shape, 1)
    scores = jnp.where(positions < length, scores, NEG_INF)

    m_prev = m_ref[...]                             # (G, 1)
    m_cur = jnp.max(scores, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(scores - m_new)                     # (G, block_k)
    correction = jnp.exp(m_prev - m_new)
    l_ref[...] = correction * l_ref[...] + jnp.sum(p, axis=-1,
                                                   keepdims=True)
    acc_ref[...] = correction * acc_ref[...] + p @ v
    m_ref[...] = m_new

    @pl.when(s == num_s - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     lengths: jnp.ndarray, *, block_k: int = 512,
                     interpret: bool = False) -> jnp.ndarray:
    """q: (B, H, Dh); k, v: (B, S, KVH, Dh); lengths: (B,) int32.

    Returns (B, H, Dh) in q.dtype.
    """
    B, H, Dh = q.shape
    S, KVH = k.shape[1], k.shape[2]
    if H % KVH:
        raise ValueError("H must be a multiple of KVH")
    G = H // KVH
    block_k = min(block_k, S)
    if S % block_k:
        raise ValueError("S must be a multiple of block_k")
    qg = q.reshape(B, KVH, G, Dh)

    grid = (B, KVH, S // block_k)
    out = pl.pallas_call(
        functools.partial(_decode_attn_kernel, block_k=block_k,
                          scale=Dh ** -0.5),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, Dh), lambda b, h, s, *_: (b, h, 0, 0)),
                pl.BlockSpec((1, block_k, 1, Dh),
                             lambda b, h, s, *_: (b, s, h, 0)),
                pl.BlockSpec((1, block_k, 1, Dh),
                             lambda b, h, s, *_: (b, s, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, Dh),
                                   lambda b, h, s, *_: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, 1), jnp.float32),   # m
                pltpu.VMEM((G, 1), jnp.float32),   # l
                pltpu.VMEM((G, Dh), jnp.float32),  # acc
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KVH, G, Dh), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, k, v)
    return out.reshape(B, H, Dh)
