"""Pure-jnp oracle for flash-decode attention (one query token vs a long
KV cache, grouped-query)."""

import jax.numpy as jnp


def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     lengths: jnp.ndarray) -> jnp.ndarray:
    """q: (B, H, Dh); k, v: (B, S, KVH, Dh); lengths: (B,) valid prefix.

    Returns (B, H, Dh). H must be a multiple of KVH (GQA groups).
    fp32 softmax; output in q.dtype.
    """
    B, H, Dh = q.shape
    S, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    qf = q.reshape(B, KVH, G, Dh).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scale = Dh ** -0.5
    # scores: (B, KVH, G, S)
    scores = jnp.einsum("bhgd,bshd->bhgs", qf, kf) * scale
    pos = jnp.arange(S)[None, None, None, :]
    mask = pos < lengths[:, None, None, None]
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhgs,bshd->bhgd", probs, vf)
    return out.reshape(B, H, Dh).astype(q.dtype)
