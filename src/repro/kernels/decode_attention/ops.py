"""Public wrapper: flash-decode attention with backend dispatch.

Forward-only (serving path). Pallas kernel on TPU; pure-jnp oracle
elsewhere. ``_FORCE`` is a test hook ("pallas" runs the kernel in
interpret mode on CPU).
"""

from __future__ import annotations

import jax

from . import kernel, ref

_FORCE = None  # None | "ref" | "pallas"


def decode_attention(q, k, v, lengths, *, block_k: int = 512):
    """q: (B, H, Dh); k, v: (B, S, KVH, Dh); lengths: (B,)."""
    if _FORCE == "ref":
        return ref.decode_attention(q, k, v, lengths)
    if _FORCE == "pallas":
        return kernel.decode_attention(
            q, k, v, lengths, block_k=block_k,
            interpret=jax.default_backend() != "tpu")
    if jax.default_backend() == "tpu":
        return kernel.decode_attention(q, k, v, lengths, block_k=block_k)
    return ref.decode_attention(q, k, v, lengths)
