"""NumPy float64 oracle for the fused batched-alpha error reduction.

This is the CPU path of the batched Monte-Carlo pipeline: it keeps the
exact float64 arithmetic of the original per-trial harness, so wiring
the kernel package into ``monte_carlo_error`` changes nothing
numerically off-TPU.
"""

import numpy as np


def fused_error(alphas: np.ndarray, scale: float) -> np.ndarray:
    """errs_t = (1/n) |scale * alpha_t - 1|_2^2.

    alphas: (trials, n) float64; scale: the debias factor
    sqrt(n)/|E[alpha]|_2 (or 1.0). Returns (trials,) float64.
    """
    d = alphas * scale - 1.0
    return np.mean(d * d, axis=1)
