"""Public wrapper for the fused batched-alpha error reduction.

Computes the debias scale (paper's alpha-bar normalisation) and the
per-trial normalized decoding errors in one call. Backend dispatch as in
the other kernels: the Pallas kernel on TPU, the float64 NumPy oracle on
CPU (which keeps ``monte_carlo_error`` bit-identical to the historical
per-trial path).
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

from . import ref

_FORCE = None  # None | "ref" | "pallas"


def debias_scale(alphas: np.ndarray) -> float:
    """The paper's alpha-bar normalisation: |1|_2 / |E[alpha]|_2 =
    sqrt(n)/max(|mean|_2, tiny). Single source of truth, also used by
    ``decoding.debias_alpha``."""
    mean = alphas.mean(axis=0)
    return float(np.sqrt(alphas.shape[1]) /
                 max(np.linalg.norm(mean), 1e-30))


def fused_error(alphas, *, debias: bool = True) -> Tuple[np.ndarray, float]:
    """alphas: (trials, n) -> (errs (trials,), scale).

    scale is ``debias_scale`` when debias else 1.0;
    errs_t = (1/n)|scale * alpha_t - 1|^2.
    """
    a = np.asarray(alphas, dtype=np.float64)
    if a.ndim != 2:
        raise ValueError(f"alphas must be (trials, n), got {a.shape}")
    if a.shape[0] == 0:
        return np.zeros((0,), dtype=np.float64), 1.0
    scale = debias_scale(a) if debias else 1.0
    if _FORCE == "ref":
        return ref.fused_error(a, scale), scale
    use_pallas = _FORCE == "pallas"
    interpret = False
    if use_pallas or _FORCE is None:
        try:
            import jax

            on_tpu = jax.default_backend() == "tpu"
        except Exception:  # pragma: no cover
            on_tpu = False
        if use_pallas:
            interpret = not on_tpu
        else:
            use_pallas = on_tpu
    if use_pallas:
        import jax.numpy as jnp

        from . import kernel

        errs = kernel.fused_error(jnp.asarray(a, jnp.float32),
                                  jnp.float32(scale), interpret=interpret)
        return np.asarray(errs, np.float64), scale
    return ref.fused_error(a, scale), scale
