"""Fused debias + decoding-error Pallas TPU kernel.

Computes errs_t = (1/n) sum_i (scale * alpha_{t,i} - 1)^2 for a whole
(trials, n) batch of decoded alphas in one pass: the debias rescale, the
subtraction and the squared-norm reduction fuse into a single VPU
streaming sweep (same roofline shape as ``coded_combine``: ~3 FLOPs per
4 bytes read, each alpha byte read exactly once).

Grid: (trials // block_t,); each step owns a (block_t, n) VMEM strip and
emits block_t per-trial errors. The scalar ``scale`` is broadcast to
every step as a whole (tiny) block. The n axis is padded to the 128-lane
boundary with 1/scale so padding contributes exactly zero error; padded
trailing trials are sliced off.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block_t(trials: int, n: int) -> int:
    budget = 4 * 1024 * 1024 // (4 * max(n, 1))  # ~4 MiB tile
    bt = max(8, min(trials, budget))
    if bt > 8:
        bt -= bt % 8  # sublane alignment
    return bt


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def fused_error(alphas: jnp.ndarray, scale: jnp.ndarray, *,
                block_t: int | None = None,
                interpret: bool = False) -> jnp.ndarray:
    """alphas: (trials, n); scale: scalar -> (trials,) float32 errors."""
    trials, n = alphas.shape
    alphas = alphas.astype(jnp.float32)
    scale = jnp.asarray(scale, jnp.float32).reshape(1)
    pad_n = (-n) % 128
    if pad_n:
        fill = jnp.broadcast_to(1.0 / scale[0], (trials, pad_n))
        alphas = jnp.concatenate([alphas, fill], axis=1)
    n_pad = alphas.shape[1]
    bt = block_t or _pick_block_t(trials, n_pad)
    pad_t = (-trials) % bt
    if pad_t:
        alphas = jnp.pad(alphas, ((0, pad_t), (0, 0)))
    padded_trials = alphas.shape[0]
    inv_n = 1.0 / n  # true n: padding columns contribute 0 to the sum

    def body(a_ref, s_ref, o_ref):
        a = a_ref[...].astype(jnp.float32)      # (bt, n_pad)
        d = a * s_ref[0] - 1.0
        o_ref[...] = (jnp.sum(d * d, axis=1) * inv_n).astype(o_ref.dtype)

    out = pl.pallas_call(
        body,
        grid=(padded_trials // bt,),
        in_specs=[
            pl.BlockSpec((bt, n_pad), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bt,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded_trials,), jnp.float32),
        interpret=interpret,
    )(alphas, scale)
    return out[:trials] if pad_t else out
