"""Failure detection: per-step machine heartbeats -> observed masks.

Everything upstream of this module *samples* straggler masks from a
synthetic ``core.stragglers`` process. This is the other half of the
paper's story -- decode around the machines that actually failed: each
of the m coded workers reports a completion timestamp for every train
step (its heartbeat), and the ``HeartbeatMonitor`` turns those
timestamps into the round's alive mask by deadline:

* a machine whose report lands within its current deadline is alive
  this round;
* a late or missing report is a **miss**: the machine is excluded from
  this round's combine (exactly what the optimal decoder is for), and
  its next-round deadline grows by an exponential backoff factor -- a
  genuinely slow-but-alive machine gets progressively more slack
  before each re-declaration instead of flapping at a fixed cutoff;
* the first ``grace`` consecutive misses are forgiven in the *event
  stream* (no ``straggle`` event yet -- transient jitter does not page
  anyone) though never in the mask: a machine that missed its deadline
  contributed nothing to the round and the decode must route around it
  regardless of how charitable the event log feels;
* ``dead_after`` (K) consecutive misses declare the machine **dead**:
  permanently excluded, heartbeats ignored from then on, and the
  ``dead`` event is what triggers elastic re-assignment
  (``coded_train.elastic_reassign`` -- re-draw the expander over the
  m-1 survivors and keep training).

The monitor is a pure host-side ledger over (step, timestamps): it
neither sleeps nor threads, so the same code path serves the chaos
harness's virtual timestamps (``repro.dist.chaos``) and a real
cluster's RPC-reported ones. Every state transition is recorded as a
structured ``FailureEvent`` -- the observability surface the train
summary and the BENCH_train chaos row aggregate (steps-to-detect,
per-machine miss runs, death steps).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

# Machine states the monitor tracks (per original machine id).
OK, STRAGGLING, DEAD = "ok", "straggling", "dead"


@dataclasses.dataclass(frozen=True)
class FailureEvent:
    """One observed state transition, as the event log records it.

    ``kind``: ``straggle`` (consecutive misses exceeded the grace
    allowance), ``recover`` (a heartbeat landed after misses),
    ``dead`` (``dead_after`` consecutive misses -- permanent),
    ``reassign`` (elastic re-draw; emitted by the driver, not the
    monitor, with the surviving-machine detail).
    """

    step: int
    kind: str
    machine: int
    detail: dict = dataclasses.field(default_factory=dict)

    def to_json(self) -> dict:
        return {"step": int(self.step), "kind": self.kind,
                "machine": int(self.machine),
                "detail": {k: (v.tolist() if isinstance(v, np.ndarray)
                               else v) for k, v in self.detail.items()}}


@dataclasses.dataclass
class HeartbeatMonitor:
    """Per-step, per-machine heartbeat ledger -> observed alive masks.

    ``deadline`` is the base per-step completion budget (same unit as
    the reported timestamps); a machine with ``k`` consecutive misses
    is next judged against ``deadline * backoff**k`` (capped at
    ``max_backoff`` doublings). ``grace`` consecutive misses are
    tolerated before a ``straggle`` event is emitted; ``dead_after``
    consecutive misses declare the machine dead for good. Missing
    heartbeats are reported as ``np.inf`` (or ``nan``) timestamps.
    """

    m: int
    deadline: float = 1.0
    backoff: float = 2.0
    max_backoff: int = 4
    grace: int = 1
    dead_after: int = 3

    def __post_init__(self):
        if self.m < 1:
            raise ValueError("m must be >= 1")
        if self.deadline <= 0:
            raise ValueError("deadline must be positive")
        if self.backoff < 1.0:
            raise ValueError("backoff must be >= 1")
        if self.dead_after < 1:
            raise ValueError("dead_after must be >= 1")
        self.misses = np.zeros(self.m, dtype=np.int64)
        self.state = [OK] * self.m
        self.dead_at: Dict[int, int] = {}     # machine -> death step
        self.first_miss: Dict[int, int] = {}  # machine -> run start
        self.events: List[FailureEvent] = []
        self._drained = 0

    def current_deadline(self, j: int) -> float:
        """Machine j's deadline for the next report, after backoff."""
        k = min(int(self.misses[j]), self.max_backoff)
        return self.deadline * self.backoff ** k

    def is_dead(self, j: int) -> bool:
        return self.state[j] == DEAD

    @property
    def dead_machines(self) -> np.ndarray:
        return np.array(sorted(self.dead_at), dtype=np.int64)

    def observe(self, step: int, times: np.ndarray) -> np.ndarray:
        """Record one step's heartbeats; return the observed mask.

        ``times`` is (m,) seconds-per-machine for this step (``inf`` /
        ``nan`` = no heartbeat arrived). Returns the (m,) alive mask
        this round's combine should decode around: True only for
        machines whose report beat their current (backoff-scaled)
        deadline. Dead machines stay False forever; their timestamps
        are ignored (a revived process must re-register as a new
        machine -- consistent with elastic re-assignment having
        already re-drawn the code without it).
        """
        times = np.asarray(times, dtype=np.float64)
        if times.shape != (self.m,):
            raise ValueError(f"times must be ({self.m},), "
                             f"got {times.shape}")
        alive = np.zeros(self.m, dtype=bool)
        for j in range(self.m):
            if self.state[j] == DEAD:
                continue
            t = times[j]
            on_time = np.isfinite(t) and t <= self.current_deadline(j)
            if on_time:
                if self.misses[j]:
                    self.events.append(FailureEvent(
                        step, "recover", j,
                        {"missed_steps": int(self.misses[j])}))
                self.misses[j] = 0
                self.state[j] = OK
                self.first_miss.pop(j, None)
                alive[j] = True
                continue
            # A miss: excluded from this round's combine regardless of
            # grace -- grace only delays the *event*, never widens the
            # mask (a machine that did not report has no gradient).
            self.first_miss.setdefault(j, step)
            self.misses[j] += 1
            if self.misses[j] == self.grace + 1 and \
                    self.state[j] == OK:
                self.state[j] = STRAGGLING
                self.events.append(FailureEvent(
                    step, "straggle", j,
                    {"deadline": float(self.current_deadline(j)),
                     "since_step": int(self.first_miss[j])}))
            if self.misses[j] >= self.dead_after:
                self.state[j] = DEAD
                self.dead_at[j] = step
                self.events.append(FailureEvent(
                    step, "dead", j,
                    {"since_step": int(self.first_miss[j]),
                     "steps_to_detect":
                         int(step - self.first_miss[j] + 1)}))
        return alive

    def drain_events(self) -> List[FailureEvent]:
        """Events appended since the last drain (the driver's per-step
        poll; the full history stays in ``.events``)."""
        new = self.events[self._drained:]
        self._drained = len(self.events)
        return new

    def steps_to_detect(self) -> Dict[int, int]:
        """machine -> steps from first miss to declared dead, for every
        machine that died (the BENCH chaos-row detection metric)."""
        out = {}
        for ev in self.events:
            if ev.kind == "dead":
                out[ev.machine] = ev.detail["steps_to_detect"]
        return out


def events_to_json(events) -> list:
    """Serialize a FailureEvent list for the summary / artifact log."""
    return [ev.to_json() for ev in events]


@dataclasses.dataclass
class SurvivorMap:
    """Original machine ids <-> current logical machine indices.

    The heartbeat monitor and the chaos injector speak *original*
    machine ids for the whole run; after an elastic re-assignment the
    coding runtime's m' logical machines are the survivors in original-
    id order. This map does the bookkeeping both ways and shrinks as
    machines die.
    """

    m: int

    def __post_init__(self):
        self.survivors = np.arange(self.m, dtype=np.int64)

    @property
    def alive_count(self) -> int:
        return int(self.survivors.size)

    def remove(self, dead) -> np.ndarray:
        """Drop original ids in ``dead``; returns the new survivors."""
        dead = set(int(d) for d in np.atleast_1d(dead))
        unknown = dead - set(self.survivors.tolist())
        if unknown:
            raise ValueError(f"machines {sorted(unknown)} are not "
                             "current survivors")
        self.survivors = np.array(
            [j for j in self.survivors if int(j) not in dead],
            dtype=np.int64)
        return self.survivors

    def localize(self, mask: np.ndarray) -> np.ndarray:
        """(m_original,) observed mask -> (m_current,) logical mask."""
        mask = np.asarray(mask, dtype=bool)
        if mask.shape != (self.m,):
            raise ValueError(f"mask must be ({self.m},), "
                            f"got {mask.shape}")
        return mask[self.survivors]
