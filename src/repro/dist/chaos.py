"""Chaos-mode fault injection: seeded virtual failures for the
heartbeat pipeline.

The chaos harness closes the loop the failure detector opens: instead
of *sampling* straggler masks, a run under ``--chaos`` simulates the
per-machine completion timestamps a real cluster would report -- with
faults injected from a seeded schedule -- and lets the
``failures.HeartbeatMonitor`` derive the masks by deadline, exactly as
it would from real heartbeats. Nothing downstream (decode, combine,
elastic re-assignment) can tell the difference; that is the point.

Spec format (``--chaos <spec>``, semicolon-separated events, machine
ids are *original* ids on the starting m machines)::

    kill:J@S            machine J dies permanently at step S
                        (heartbeats stop forever)
    rack:J,K,...@S      correlated failure: every listed machine dies
                        at step S (one rack, one switch)
    delay:J@S-E[:X]     transient straggle: machine J's completion
                        time is multiplied by X (default 10) for steps
                        S <= step < E, then recovers
    flap:J@S-E[:K]      flapping: machine J alternates K steps dark /
                        K steps healthy (default K=1) for S <= step < E

Example: ``kill:1@3;delay:2@5-8:20;flap:0@4-12:2``.

``random_schedule(m, steps, seed)`` draws a seeded mix of the above for
soak runs. ``ChaosInjector`` turns the schedule into per-step (m,)
timestamp vectors: healthy machines report ``base_time`` plus seeded
jitter, delayed machines report scaled times, killed/flapping-dark
machines report ``inf`` (no heartbeat). All randomness is a
``default_rng(seed)`` stream consumed in step order, so a chaos run is
exactly reproducible from (spec, seed) -- the property the elastic
differential pin and the CI smoke lean on.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

KINDS = ("kill", "rack", "delay", "flap")


@dataclasses.dataclass(frozen=True)
class ChaosEvent:
    """One scheduled fault. ``end`` is exclusive; permanent faults
    (kill/rack) carry ``end=None``. ``magnitude`` is the delay factor
    for ``delay`` and the dark/healthy period for ``flap``."""

    kind: str
    machines: Tuple[int, ...]
    start: int
    end: int = None
    magnitude: float = 0.0

    def active(self, step: int) -> bool:
        if step < self.start:
            return False
        return self.end is None or step < self.end


def _parse_window(text: str) -> Tuple[int, int]:
    lo, _, hi = text.partition("-")
    start, end = int(lo), int(hi)
    if end <= start:
        raise ValueError(f"empty chaos window {text!r}")
    return start, end


def parse_chaos_spec(spec: str, m: int) -> List[ChaosEvent]:
    """Parse ``--chaos`` spec text into a validated event list."""
    events = []
    for part in filter(None, (s.strip() for s in spec.split(";"))):
        try:
            kind, _, rest = part.partition(":")
            body, _, when = rest.partition("@")
            if kind in ("kill", "rack"):
                machines = tuple(int(j) for j in body.split(","))
                events.append(ChaosEvent(kind, machines, int(when)))
            elif kind == "delay":
                when, _, mag = when.partition(":")
                start, end = _parse_window(when)
                events.append(ChaosEvent(
                    kind, (int(body),), start, end,
                    float(mag) if mag else 10.0))
            elif kind == "flap":
                when, _, period = when.partition(":")
                start, end = _parse_window(when)
                events.append(ChaosEvent(
                    kind, (int(body),), start, end,
                    float(int(period)) if period else 1.0))
            else:
                raise ValueError(f"unknown chaos kind {kind!r} "
                                 f"(known: {KINDS})")
        except ValueError as e:
            raise ValueError(f"bad chaos event {part!r}: {e}") from e
    for ev in events:
        for j in ev.machines:
            if not 0 <= j < m:
                raise ValueError(f"chaos machine {j} out of range "
                                 f"for m={m}")
    return events


def random_schedule(m: int, steps: int, seed: int = 0, *,
                    n_events: int = 3) -> List[ChaosEvent]:
    """A seeded mixed schedule for soak/fuzz runs: at most one kill
    (keep a decodable majority), the rest transient delays and flaps
    spread over the run."""
    rng = np.random.default_rng(seed)
    events: List[ChaosEvent] = []
    machines = rng.permutation(m)
    for i in range(n_events):
        j = int(machines[i % m])
        start = int(rng.integers(1, max(2, steps - 2)))
        if i == 0 and m > 2:
            events.append(ChaosEvent("kill", (j,), start))
            continue
        end = int(min(steps, start + rng.integers(2, 5)))
        if rng.random() < 0.5:
            events.append(ChaosEvent("delay", (j,), start, end,
                                     float(rng.integers(5, 30))))
        else:
            events.append(ChaosEvent("flap", (j,), start, end, 1.0))
    return events


@dataclasses.dataclass
class ChaosInjector:
    """Schedule -> per-step virtual heartbeat timestamps.

    ``completion_times(step)`` returns the (m,) vector of seconds each
    *original* machine took this step: ``base_time`` + seeded jitter
    when healthy, scaled by the delay factor under an active ``delay``
    window, ``inf`` when killed or in a flap's dark phase. The jitter
    draw happens for every machine every step (dead included), so the
    stream a given (spec, seed) produces is independent of detection
    timing -- reproducibility the differential tests rely on.
    """

    schedule: List[ChaosEvent]
    m: int
    base_time: float = 0.1
    jitter: float = 0.2
    seed: int = 0

    def __post_init__(self):
        self.rng = np.random.default_rng(self.seed + 0xC4A05)
        self._killed_at = {}
        for ev in self.schedule:
            if ev.kind in ("kill", "rack"):
                for j in ev.machines:
                    self._killed_at[j] = min(
                        ev.start, self._killed_at.get(j, ev.start))

    def killed(self, step: int) -> np.ndarray:
        """(m,) bool: machines whose kill step has passed."""
        out = np.zeros(self.m, dtype=bool)
        for j, s in self._killed_at.items():
            out[j] = step >= s
        return out

    def completion_times(self, step: int) -> np.ndarray:
        times = self.base_time * (
            1.0 + self.jitter * self.rng.random(self.m))
        for ev in self.schedule:
            if not ev.active(step):
                continue
            for j in ev.machines:
                if ev.kind in ("kill", "rack"):
                    times[j] = np.inf
                elif ev.kind == "delay":
                    times[j] *= ev.magnitude
                elif ev.kind == "flap":
                    period = max(1, int(ev.magnitude))
                    dark = ((step - ev.start) // period) % 2 == 0
                    if dark:
                        times[j] = np.inf
        return times
