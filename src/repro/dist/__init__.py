"""Distributed shard_map/pjit runtime for coded training.

- ``sharding``:    mesh-axis helpers and path-pattern partition specs
  (params, optimizer state, decode caches) with a divisibility fallback
  to replication, valid on any (pod x data x model) mesh including the
  1-device test mesh.
- ``coded_train``: the coded train/prefill/serve steps and the
  ``CodingRuntime`` host bridge (straggler sampling + optimal decoding
  -> per-step w*), built on the single-host oracle in ``repro.core`` --
  the two are tested against each other in tests/test_dist.py.
"""

from . import coded_train, sharding

__all__ = ["coded_train", "sharding"]
