"""Sharding rules: param/optimizer/cache partition specs for the mesh.

The mesh carries up to three axes -- ``pod`` and ``data`` (the coded
gradient workers: machine j of the paper's m machines lives at one
(pod, data) coordinate) and ``model`` (tensor parallelism). Parameters
are replicated across the worker axes (every worker holds the full
model and computes its blocks' gradients) and sharded over ``model``
by *path patterns* on the param pytree, the reason params are plain
nested dicts (see models/layers.py).

Every rule passes through a divisibility check: a dim that does not
divide the model-axis size falls back to replication instead of
emitting an invalid spec, so the same rules are valid on the 2x16x16
production mesh, the (data, model) single pod, and the 1-device test
mesh (where everything divides 1 and the specs degenerate gracefully).
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The mesh axes the coded workers (and hence the batch's machine
    axis) are sharded over: ("pod", "data") on multi-pod meshes,
    ("data",) otherwise."""
    return (("pod", "data") if "pod" in mesh.axis_names else ("data",))


def named(mesh: Mesh, specs):
    """PartitionSpec pytree -> NamedSharding pytree on ``mesh``."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def _worker_count(mesh: Mesh) -> int:
    n = 1
    for a in data_axes(mesh):
        n *= int(mesh.shape[a])
    return n


def _leading_axis_shardings(mesh: Mesh, batch, *, divisible: bool):
    """Leaf leading axis over the worker axes, the rest replicated;
    with ``divisible`` a leading dim that does not divide the worker
    count falls back to full replication instead of an invalid spec."""
    da = data_axes(mesh)
    da1 = da if len(da) > 1 else da[0]
    n_workers = _worker_count(mesh)

    def spec(v) -> NamedSharding:
        if divisible and (v.ndim == 0 or v.shape[0] % n_workers):
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(*([da1] + [None] * (v.ndim - 1))))

    return jax.tree.map(spec, batch)


def batch_shardings(mesh: Mesh, batch):
    """Coded-batch shardings: every leaf's leading (machine) axis over
    the worker axes, the rest replicated. Works on arrays and
    ShapeDtypeStructs; the single source the train driver and the
    train-step benchmark both jit against."""
    return _leading_axis_shardings(mesh, batch, divisible=False)


def block_shardings(mesh: Mesh, batch):
    """Dedup unique-block batch shardings: the leading n-block axis
    over the worker (pod, data) axes -- the same placement the
    replicated batch's machine axis gets -- with a divisibility
    fallback to replication for block counts that do not divide the
    worker count (FRC / irregular dedup batches on wide meshes, or the
    1-device test mesh)."""
    return _leading_axis_shardings(mesh, batch, divisible=True)


def _model_size(mesh: Mesh) -> int:
    return int(mesh.shape.get("model", 1))


def _sharded_dim(path: Tuple[str, ...], shape: Tuple[int, ...]) -> int:
    """Which dim of this param leaf the model axis splits, or -1.

    Patterns match the last two path keys (param dicts nest as
    ``.../<layer-name>/<w|b|scale|table>``); stacked blocks carry a
    leading layer axis, which the negative dim indices skip naturally.
    """
    parent = path[-2] if len(path) >= 2 else ""
    leaf = path[-1]
    if leaf == "table":                      # embedding (V, D): split vocab
        return 0 if len(shape) == 2 else -1
    if parent == "lm_head" and leaf == "w":  # (D, V): split vocab
        return len(shape) - 1
    if len(shape) < 2:
        return -1                            # biases / norms / scalars
    # MoE expert stacks are raw (E, d_in, d_out) arrays, not nested
    # linears: match on the leaf name itself.
    if leaf in ("w_gate", "w_up"):
        return len(shape) - 1
    if leaf == "w_down":
        return len(shape) - 2
    if leaf != "w":
        return -1
    # Column-parallel projections: split the output features.
    if parent in ("wq", "wk", "wv", "wi_gate", "wi_up", "xz_proj",
                  "bcdt_proj"):
        return len(shape) - 1
    # Row-parallel projections: split the input features.
    if parent in ("wo", "out_proj"):
        return len(shape) - 2
    return -1


def safe_param_specs(params, mesh: Mesh):
    """PartitionSpec pytree for a param pytree: path-pattern tensor
    parallelism over ``model`` with a divisibility fallback to
    replication. Works on concrete arrays and ShapeDtypeStructs."""
    msize = _model_size(mesh)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)

    def spec_for(path, leaf) -> P:
        keys = tuple(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path)
        shape = tuple(leaf.shape)
        dim = _sharded_dim(keys, shape)
        if dim < 0 or msize <= 1 or shape[dim] % msize:
            return P()                       # fallback: replicate
        axes = [None] * len(shape)
        axes[dim] = "model"
        return P(*axes)

    return jax.tree_util.tree_unflatten(
        treedef, [spec_for(path, leaf) for path, leaf in flat])


def fsdp_specs(params, mesh: Mesh):
    """FSDP-style param (and optimizer-moment) PartitionSpecs: shard
    over the worker axes *on top of* the model-axis tensor parallelism.

    ``safe_param_specs`` replicates every leaf across the (pod, data)
    worker axes -- each coded worker holds the full model, which is
    what keeps yi-34b/deepseek-33b dry-run-only. Here each leaf
    additionally donates one dim to the worker axes: the largest dim
    not already taken by the model axis whose size divides the worker
    count. Leaves with no such dim keep their ``safe_param_specs``
    placement (the same divisibility-fallback contract, so the rules
    stay valid from the 1-device test mesh -- where everything divides
    1 -- to the 2x16x16 production mesh). GSPMD all-gathers a layer's
    params at use and frees them after, trading collective time for
    the m-fold parameter memory the replicated placement pays.

    Adam's m/v moments follow the same specs (the driver maps these
    over opt_state), so the optimizer state -- 2x the param bytes --
    shards identically.
    """
    base = safe_param_specs(params, mesh)
    da = data_axes(mesh)
    da1 = da if len(da) > 1 else da[0]
    n_workers = _worker_count(mesh)
    if n_workers <= 1:
        return base

    def upgrade(leaf, spec: P) -> P:
        shape = tuple(leaf.shape)
        axes = list(spec) + [None] * (len(shape) - len(spec))
        best = -1
        for dim, size in enumerate(shape):
            if axes[dim] is not None:
                continue
            if size % n_workers:
                continue
            if best < 0 or size > shape[best]:
                best = dim
        if best < 0:
            return spec
        axes[best] = da1
        return P(*axes)

    leaves, treedef = jax.tree.flatten(params)
    specs = treedef.flatten_up_to(base)
    return treedef.unflatten(
        [upgrade(leaf, spec) for leaf, spec in zip(leaves, specs)])


def bytes_per_device(shapes, specs, mesh: Mesh) -> int:
    """Per-device bytes of a pytree under its PartitionSpec placement.

    Pure metadata (works on ShapeDtypeStructs -- no compile, no
    allocation): each leaf's bytes divided by the product of the mesh
    axis sizes its spec names, summed over leaves. ``specs`` leaves may
    be PartitionSpecs or NamedShardings. This is the accounting the
    dry-run reports for the replicated-vs-FSDP parameter memory
    comparison.
    """
    leaves, treedef = jax.tree.flatten(shapes)
    spec_leaves = treedef.flatten_up_to(specs)
    total = 0
    for leaf, spec in zip(leaves, spec_leaves):
        spec = getattr(spec, "spec", spec)
        shards = 1
        for entry in spec:
            if entry is None:
                continue
            for a in (entry if isinstance(entry, tuple) else (entry,)):
                shards *= int(mesh.shape[a])
        nbytes = math.prod(leaf.shape) * jnp.dtype(leaf.dtype).itemsize
        total += -(-nbytes // shards)  # ceil: padding shard counts too
    return int(total)


def cache_batch_dim(keys: Tuple[str, ...]) -> int:
    """Which dim of a decode-cache leaf is the batch (request-slot)
    axis: per-layer stacked leaves carry a leading layer axis so batch
    is dim 1; the unstacked encoder output ("enc") has batch leading.
    Shared by ``cache_specs`` and the serving cache pool's slot-reset
    mask so the two can never disagree about where a request's state
    lives."""
    return 0 if (keys and keys[0] == "enc") else 1


def cache_specs(cache, mesh: Mesh, *, batch_replicated: bool = False):
    """Decode-cache PartitionSpecs: shard the batch dim over the data
    axes (dim 1 for the per-layer stacked leaves, dim 0 for the
    unstacked encoder output), replicate when the batch is smaller than
    the worker count (``batch_replicated``) or does not divide it."""
    da = data_axes(mesh)
    n_data = _worker_count(mesh)
    da1 = da if len(da) > 1 else da[0]
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)

    def spec_for(path, leaf) -> P:
        keys = tuple(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path)
        shape = tuple(leaf.shape)
        batch_dim = cache_batch_dim(keys)
        if (batch_replicated or len(shape) <= batch_dim
                or n_data <= 1 or shape[batch_dim] % n_data):
            return P()
        axes = [None] * len(shape)
        axes[batch_dim] = da1
        return P(*axes)

    return jax.tree_util.tree_unflatten(
        treedef, [spec_for(path, leaf) for path, leaf in flat])
