"""Coded training on a device mesh: the paper's update, for real.

The parameter-server view (Glasgow & Wootters, Algorithm 2) is

    theta <- theta - eta * sum_j w*_j g_j

over m coded workers, where g_j is worker j's sum of assigned block
gradients and w* comes from the O(m) optimal decoder applied to this
round's straggler mask. On the mesh, the m workers are the (pod, data)
shards: the coded batch carries a leading machine axis of size m (see
``data.pipeline.CodedBatcher``), the per-worker weighted loss

    L(theta) = (1/N) sum_j w_j sum_{l} block_weight_{jl} * L_{jl}(theta)

is *linear in w*, so its autodiff gradient IS the paper's combine
``sum_j w_j g_j`` -- the contract ``tests/test_dist.py`` pins against
the explicit ``coded_combine_tree``. Under ``jit`` the machine axis is
data-sharded and GSPMD inserts the psum; ``coded_allreduce`` is the
same combine as an explicit ``shard_map`` collective for runs that
want manual control over the reduction.

Host side, ``CodingRuntime`` bridges ``repro.core``'s oracle into the
training loop: it instantiates the assignment (expander / FRC /
uncoded), samples one of the ``core.stragglers`` processes each step,
and emits per-step w* through the shared
``core.step_weights`` pipeline (decode dispatch + alpha-bar debias via
the batched engine), memoising repeated masks -- stagnant stragglers
(the paper's cluster observation, the Markov model here) make the
decode cache hit almost every step.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

try:
    from jax.experimental.shard_map import shard_map
except ImportError:  # newer jax moved it to the top level
    shard_map = jax.shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import CodingConfig, ModelConfig
import repro.core.step_weights as sw
from repro.core.assignment import (Assignment, expander_assignment,
                                   frc_assignment, uncoded_assignment)
from repro.kernels.coded_combine import ops as cc_ops
from repro.models import model as M
from repro.optim import optimizers as opt_mod

from .sharding import data_axes


# ---------------------------------------------------------------------------
# Coded loss and train/prefill/serve steps
# ---------------------------------------------------------------------------


def coded_loss_fn(params, coded_batch: Dict[str, jnp.ndarray],
                  w: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Per-block weighted coded loss; grad == sum_j w_j g_j (Eq. 1).

    coded_batch leaves are (m, load, bs, ...) with a ``block_weight``
    (m, load) mask (0 on padding slots of irregular assignments); w is
    the (m,) decoding weights. The machine/load/batch axes flatten into
    one forward pass, so the machine axis shards over the data axes of
    the mesh without any per-machine python loop.
    """
    bw = coded_batch["block_weight"]                      # (m, load)
    m, load = bw.shape
    flat = {k: v.reshape((-1,) + v.shape[3:])
            for k, v in coded_batch.items() if k != "block_weight"}
    per_seq = M.train_loss(params, flat, cfg, per_example=True)
    per_block = per_seq.reshape(m, load, -1).sum(axis=2)  # (m, load)
    norm = coded_batch["labels"].size
    return (w[:, None] * bw * per_block).sum() / norm


def make_train_step(cfg: ModelConfig, optimizer: opt_mod.Optimizer,
                    n_microbatches: int = 1):
    """(params, opt_state, coded_batch, w) -> (params, opt_state,
    metrics).

    ``n_microbatches`` > 1 accumulates gradients over equal splits of
    the per-block batch axis under ``lax.scan`` (constant HLO size,
    rematerialised activations): the mean of per-microbatch losses /
    gradients equals the single-shot step because the coded loss is a
    normalised sum over sequences. Accumulation is deliberately
    float32 -- exact for the float32 param configs shipped here, and
    the standard higher-precision accumulator if params ever go bf16
    (where the single-shot step would differ by the grads' bf16
    rounding, not by this sum).
    """
    nm = int(n_microbatches)
    if nm < 1:
        raise ValueError("n_microbatches must be >= 1")

    def step(params, opt_state, batch, w):
        if nm == 1:
            loss, grads = jax.value_and_grad(coded_loss_fn)(
                params, batch, w, cfg)
        else:
            bw = batch["block_weight"]

            def to_micro(leaf):
                m_, l_, bs_ = leaf.shape[:3]
                if bs_ % nm:
                    raise ValueError(
                        f"block batch {bs_} not divisible by "
                        f"{nm} microbatches")
                x = leaf.reshape((m_, l_, nm, bs_ // nm) + leaf.shape[3:])
                return jnp.moveaxis(x, 2, 0)   # (nm, m, load, bs/nm, ...)

            micro = {k: to_micro(v) for k, v in batch.items()
                     if k != "block_weight"}

            def body(carry, mb):
                g_acc, l_acc = carry
                mb = dict(mb)
                mb["block_weight"] = bw
                l, g = jax.value_and_grad(coded_loss_fn)(params, mb, w,
                                                         cfg)
                return (jax.tree.map(jnp.add, g_acc, g), l_acc + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / nm, gsum)
            loss = lsum / nm
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = opt_mod.apply_updates(params, updates)
        metrics = {"loss": loss,
                   "grad_norm": opt_mod.global_norm(grads)}
        return params, opt_state, metrics

    return step


def make_prefill_step(cfg: ModelConfig):
    """(params, batch) -> last-position logits (B, V_pad)."""
    def step(params, batch):
        return M.prefill(params, batch["tokens"], cfg,
                         prefix=batch.get("prefix"),
                         src=batch.get("src"))
    return step


def make_serve_step(cfg: ModelConfig, window: Optional[int] = None):
    """(params, token, cache) -> (logits, new_cache)."""
    def step(params, token, cache):
        return M.decode_step(params, token, cache, cfg, window=window)
    return step


def coded_allreduce(grads, w: jnp.ndarray, mesh):
    """The paper combine as an explicit shard_map collective.

    ``grads`` leaves carry a leading (global) machine axis of size m
    sharded over the (pod, data) axes; ``w`` is the (m,) decoding
    weights sharded the same way. Each shard w-weights and sums its
    local machines through the ``coded_combine`` kernel, then a psum
    over the worker axes produces the replicated global
    ``sum_j w_j g_j``.
    """
    axes = data_axes(mesh)
    lead = axes if len(axes) > 1 else axes[0]
    gspecs = jax.tree.map(lambda _: P(lead), grads)

    def local_combine(g, w_local):
        out = cc_ops.coded_combine_tree(g, w_local)
        return jax.tree.map(lambda x: jax.lax.psum(x, axes), out)

    return shard_map(local_combine, mesh=mesh,
                     in_specs=(gspecs, P(lead)),
                     out_specs=jax.tree.map(lambda _: P(), grads))(
        grads, w)


# ---------------------------------------------------------------------------
# Host-side coding runtime
# ---------------------------------------------------------------------------


def make_assignment(coding: CodingConfig, m: int) -> Assignment:
    """Instantiate the block assignment for m coded workers."""
    if coding.scheme == "expander":
        return expander_assignment(m, coding.replication,
                                   vertex_transitive=True,
                                   seed=coding.seed)
    if coding.scheme == "frc":
        return frc_assignment(m, coding.replication)
    if coding.scheme == "uncoded":
        return uncoded_assignment(m)
    raise ValueError(f"unknown scheme {coding.scheme!r} "
                     "(expander | frc | uncoded)")


@dataclasses.dataclass
class CodingRuntime:
    """Host bridge: assignment + straggler process + per-step weights.

    One instance per run. ``step_weights()`` samples this round's alive
    mask from the configured ``core.stragglers`` model and returns the
    debiased decoding weights w (w_j = 0 on stragglers) for the train
    step, memoised by mask: under stagnant straggler processes
    (markov / adversarial) the same mask repeats for many consecutive
    rounds and decoding drops out of the step latency entirely.

    The alpha-bar debias scale is estimated once at construction --
    optimal decoding shrinks alpha below 1 on average, and the scale
    makes the expected update unbiased without per-step work. For the
    stochastic models it is one ``batched_alpha`` decode of a Bernoulli
    mask batch (``core.step_weights.debias_scale_mc``); the adversarial
    model replays a single fixed mask, so its exact scale comes from
    that mask's own alpha. Fixed decoding is already unbiased by
    construction, so the scale stays 1 there.
    """

    coding: CodingConfig
    m: int
    debias: bool = True
    debias_trials: int = 256
    cache_size: int = 4096

    def __post_init__(self):
        self.assignment = make_assignment(self.coding, self.m)
        self.model = sw.make_straggler_model(
            self.assignment, self.coding.straggler_model,
            self.coding.straggler_p)
        self.rng = np.random.default_rng(self.coding.seed)
        self.scale = 1.0
        if self.debias and self.coding.decoding == "optimal":
            if self.coding.straggler_model == "adversarial":
                # The attack mask is deterministic: the exact debias
                # factor is sqrt(n)/|alpha| of that one decode.
                _, alpha = sw.step_weights(
                    self.assignment, self.model.sample(self.rng),
                    method="optimal")
                self.scale = float(
                    np.sqrt(alpha.size) /
                    max(np.linalg.norm(alpha), 1e-30))
            else:
                # Offset the seed: bernoulli_uniforms(seed) replays the
                # exact uniform stream the training masks consume, so
                # the same seed would fit the scale in-sample on the
                # run's own first `debias_trials` masks.
                self.scale = sw.debias_scale_mc(
                    self.assignment, p=self.coding.straggler_p,
                    trials=self.debias_trials,
                    seed=self.coding.seed + 0x5EED)
        self._cache: Dict[bytes, np.ndarray] = {}
        self.decode_calls = 0
        self.steps_sampled = 0

    def step_weights(self) -> Tuple[np.ndarray, np.ndarray]:
        """Sample one round: returns (w (m,) float32, alive (m,) bool)."""
        alive = self.model.sample(self.rng)
        self.steps_sampled += 1
        key = alive.tobytes()
        w = self._cache.get(key)
        if w is None:
            w, _ = sw.step_weights(
                self.assignment, alive, method=self.coding.decoding,
                p=self.coding.straggler_p, scale=self.scale)
            w = w.astype(np.float32)
            if len(self._cache) >= self.cache_size:
                # FIFO eviction: i.i.d. models at large m never repeat
                # masks, and the cache must not grow with step count.
                self._cache.pop(next(iter(self._cache)))
            self._cache[key] = w
            self.decode_calls += 1
        return w, alive

    def decode_batch(self, masks) -> Tuple[np.ndarray, np.ndarray]:
        """Batched (T, m) masks -> (W, alphas) through the shared
        pipeline -- the lookahead/benchmark path."""
        return sw.batched_step_weights(
            self.assignment, masks, method=self.coding.decoding,
            p=self.coding.straggler_p, scale=self.scale)
