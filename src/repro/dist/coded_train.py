"""Coded training on a device mesh: the paper's update, for real.

The parameter-server view (Glasgow & Wootters, Algorithm 2) is

    theta <- theta - eta * sum_j w*_j g_j

over m coded workers, where g_j is worker j's sum of assigned block
gradients and w* comes from the O(m) optimal decoder applied to this
round's straggler mask. On the mesh, the m workers are the (pod, data)
shards: the coded batch carries a leading machine axis of size m (see
``data.pipeline.CodedBatcher``), the per-worker weighted loss

    L(theta) = (1/N) sum_j w_j sum_{l} block_weight_{jl} * L_{jl}(theta)

is *linear in w*, so its autodiff gradient IS the paper's combine
``sum_j w_j g_j`` -- the contract ``tests/test_dist.py`` pins against
the explicit ``coded_combine_tree``. Under ``jit`` the machine axis is
data-sharded and GSPMD inserts the psum; ``coded_allreduce`` is the
same combine as an explicit ``shard_map`` collective for runs that
want manual control over the reduction.

Four execution models, one algebra
----------------------------------

The module offers the paper's update in four equivalent forms; picking
between them is picking what the mesh is *simulating*:

* **Replicated-machine** (``coded_loss_fn``): the batch carries the
  (m, load, ...) machine axis with every block materialised d times,
  exactly as a real straggling cluster would compute it -- machine j
  really does redo block i's forward/backward. This is the right model
  when the mesh shards *are* the m unreliable workers (a real cluster,
  or fault-injection studies where per-machine compute matters).
* **Dedup-block** (``coded_loss_fn_dedup``): for a *reproduction* on a
  reliable mesh, the d-fold replication is a coding-layer fact, not a
  compute obligation. The combine ``sum_j w_j g_j`` is algebraically
  ``sum_i (A w)_i grad L_i`` over the n unique blocks (machine j's
  gradient is the sum of its blocks' gradients -- the same identity
  Charles et al. use to analyse the decoded gradient), so the step
  runs each block once, weighted by ``v = A @ w``
  (``core.step_weights.block_weights``), at ~1x the uncoded FLOPs
  instead of ~d x. Gradients, optimizer updates and loss trajectories
  match the replicated path to float32 tolerance
  (tests/test_dedup.py); only the wall-clock differs.
* **Compressed combine** (``make_train_step(compress=...)``): the
  bandwidth-bound regime, where shipping full-precision g_j costs a
  d-fold comms tax exactly where dedup already closed the FLOP tax.
  Each machine's (or, on the dedup path, each unique block's) gradient
  is quantized by a ``core.compress`` codec (int8 / signSGD sign) with
  per-worker error feedback, and the decode-weighted combine runs
  directly on the quantized payload through the fused
  ``quantized_combine`` kernel -- dequantize, w-weight and reduce in
  one pass, never materialising float32 per-machine gradients. The
  step's state grows a residual pytree next to ``opt_state`` (the
  telescoping error-feedback memory, checkpointed with it); at codec
  'none' the path pins to the float32 step at the per-machine-grads
  tolerance of tests/test_dist.py, and under int8/sign/sign_packed to
  the quantization bound (tests/test_compress.py).
* **Streaming combine**
  (``make_manual_collective_train_step(streaming_chunk=...)``): the
  memory-bound regime. The combine ``sum_j w_j g_j`` is linear in the
  per-machine gradients, so it never needs them all live at once --
  the same identity Charles et al. use to analyse the decoded
  gradient lets the reduction stream machine-by-machine. A
  ``lax.scan`` walks the machine axis in chunks (one chunk per worker
  shard per step, so data parallelism is preserved), computes that
  chunk's gradients, runs the per-chunk coded (or quantized/packed)
  allreduce, and folds the result into a single float32 accumulator
  pytree: peak live-gradient memory drops from the materialising
  path's m-rows-at-once to O(chunk). The scan reassociates the sum,
  so this path pins to the materialising manual step at float32
  tolerance (tests/test_streaming.py), and
  ``benchmarks/train_step.py`` records both paths' compiled peak
  bytes to show the drop is real.

``coded_allreduce`` / ``make_manual_collective_train_step`` keep the
combine as an explicit shard_map psum for runs that want manual
control over the reduction instead of the GSPMD-inserted one;
``quantized_coded_allreduce`` is the same collective carrying the
quantized payload (each shard dequant-combines its local machines,
then one float32 psum of the partial combines), and
``packed_sign_coded_allreduce`` the variant whose wire payload is the
``sign_packed`` codec's 1-bit planes. All three share one shard_map
skeleton (``_coded_psum_allreduce``).

Host side, ``CodingRuntime`` bridges ``repro.core``'s oracle into the
training loop: it instantiates the assignment (expander / FRC /
uncoded), pulls one alive mask per step from its ``MaskSource``, and
emits per-step w* through the shared
``core.step_weights`` pipeline (decode dispatch + alpha-bar debias via
the batched engine), memoising repeated masks -- stagnant stragglers
(the paper's cluster observation, the Markov model here) make the
decode cache hit almost every step. ``weights_lookahead`` pre-samples
a horizon of masks and decodes the novel ones in one
``decode_batch`` call, for pipelined loops that refuse even the
per-step cache-lookup latency.

Observed-mask execution model (elastic fault tolerance)
-------------------------------------------------------

Where the masks come from is a ``core.step_weights.MaskSource``:
*sampled* (the default -- a synthetic ``core.stragglers`` process,
bit-identical to the pre-abstraction inline RNG), *observed* (the
driver pushes masks the ``dist.failures.HeartbeatMonitor`` derived
from per-machine completion timestamps -- a miss means that machine
shipped no gradient this round, so the decode routes around it
exactly as it would a sampled straggler), or *replayed* (a recorded
(T, m) stream, for deterministic re-execution of failure traces).
Everything downstream of ``step_weights()`` is source-agnostic.

Observed masks add one genuinely new transition: permanent death.
When the monitor declares a machine dead, ``elastic_reassign``
re-draws the code over the m-1 survivors -- seed derived by the pure
``elastic_seed(seed, generation)``, replication degraded
deterministically to the largest feasible degree
(``elastic_coding``) -- and the driver rebuilds its per-generation
machinery (batcher, block shardings via the divisibility fallback,
jitted step) around the live {params, opt_state}. Because both the
re-assignment and a from-scratch launch on the survivors derive the
same coding from (seed, generation) and data batches are a pure
function of the step index, the elastic continuation is bit-identical
to a fresh run started from the same state (tests/test_elastic.py).
Lookahead prefetching only applies to sampled streams; observed masks
decode per step, since the future cannot be pre-observed.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

try:
    from jax.experimental.shard_map import shard_map
except ImportError:  # newer jax moved it to the top level
    shard_map = jax.shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import CodingConfig, ModelConfig
import repro.core.compress as compress_mod
import repro.core.step_weights as sw
from repro.core.adaptive import (DecodingPolicy, OnlineStragglerEstimator,
                                 PolicyDecision, make_policy)
from repro.core.assignment import (Assignment, bibd_assignment,
                                   cyclic_mds_assignment,
                                   expander_assignment, frc_assignment,
                                   random_matching_assignment,
                                   uncoded_assignment)
from repro.kernels.coded_combine import ops as cc_ops
from repro.models import model as M
from repro.optim import optimizers as opt_mod

from .sharding import data_axes


# ---------------------------------------------------------------------------
# Coded loss and train/prefill/serve steps
# ---------------------------------------------------------------------------


def coded_loss_fn(params, coded_batch: Dict[str, jnp.ndarray],
                  w: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Per-block weighted coded loss; grad == sum_j w_j g_j (Eq. 1).

    coded_batch leaves are (m, load, bs, ...) with a ``block_weight``
    (m, load) mask (0 on padding slots of irregular assignments); w is
    the (m,) decoding weights. The machine/load/batch axes flatten into
    one forward pass, so the machine axis shards over the data axes of
    the mesh without any per-machine python loop.
    """
    bw = coded_batch["block_weight"]                      # (m, load)
    m, load = bw.shape
    flat = {k: v.reshape((-1,) + v.shape[3:])
            for k, v in coded_batch.items() if k != "block_weight"}
    per_seq = M.train_loss(params, flat, cfg, per_example=True)
    per_block = per_seq.reshape(m, load, -1).sum(axis=2)  # (m, load)
    norm = coded_batch["labels"].size
    return (w[:, None] * bw * per_block).sum() / norm


def coded_loss_fn_dedup(params, block_batch: Dict[str, jnp.ndarray],
                        v: jnp.ndarray, cfg: ModelConfig,
                        norm_scale: float = 1.0) -> jnp.ndarray:
    """Per-unique-block weighted coded loss; grad == sum_j w_j g_j.

    block_batch leaves are (n, block_rows, ...) unique blocks
    (``CodedBatcher.unique_blocks``); v is the (n,) per-block weights
    ``A @ w`` (``core.step_weights.block_weights``). Since the
    replicated combine is ``sum_j w_j sum_l bw_jl L_jl = sum_i v_i
    L_i``, this computes the identical loss/gradient from one forward
    pass per block -- ~1x the uncoded FLOPs instead of ~d x.

    ``norm_scale`` reproduces the replicated path's normalisation: the
    replicated batch counts m*load block slots of labels (padding
    included), the dedup batch counts n, so passing
    ``dedup_norm_scale(assignment) = m*load/n`` makes losses (not just
    gradients-up-to-scale) match ``coded_loss_fn`` exactly.
    """
    labels = block_batch["labels"]
    n = labels.shape[0]
    flat = {k: x.reshape((-1,) + x.shape[2:])
            for k, x in block_batch.items()}
    per_seq = M.train_loss(params, flat, cfg, per_example=True)
    per_block = per_seq.reshape(n, -1).sum(axis=1)   # (n,)
    norm = labels.size * norm_scale
    return (v * per_block).sum() / norm


def dedup_norm_scale(assignment: Assignment) -> float:
    """m*load/n: the factor that aligns the dedup loss normalisation
    with the replicated batch's (padded) label count."""
    return assignment.m * assignment.load / assignment.n


def compress_combine_tree(grads, residual, w, codec, *,
                          error_feedback: bool = True):
    """Quantize per-row gradients and run the fused combine per leaf.

    ``grads`` leaves carry a leading row axis (m machines or n unique
    blocks); ``residual`` is the matching error-feedback pytree
    (``core.compress.init_state``); ``w`` the (rows,) decode weights
    (machine w or block v = A @ w). Per leaf: compress ``g + e``
    row-wise, combine the quantized payload through
    ``quantized_combine`` -- or ``packed_sign_combine`` for a packed
    codec, which unpacks the 1-bit planes inside the kernel -- (the
    float32 per-row gradients are never materialised past this
    point), and keep ``e' = (g + e) - dequant``. Returns (combined
    float32 tree, new residual tree).
    """
    g_leaves, treedef = jax.tree.flatten(grads)
    r_leaves = treedef.flatten_up_to(residual)
    outs, new_rs = [], []
    for g, r in zip(g_leaves, r_leaves):
        rows = g.shape[0]
        flat = g.reshape(rows, -1).astype(jnp.float32)
        d = flat.shape[1]
        pre = flat + r.reshape(rows, -1) if error_feedback else flat
        q, s = codec.compress(pre)
        if codec.packed:
            outs.append(cc_ops.packed_sign_combine(q, s, w, d)
                        .reshape(g.shape[1:]))
        else:
            outs.append(cc_ops.quantized_combine(q, s, w)
                        .reshape(g.shape[1:]))
        new_rs.append(
            (pre - codec.decompress(q, s, d=d)).reshape(g.shape)
            if error_feedback else r)
    return (jax.tree.unflatten(treedef, outs),
            jax.tree.unflatten(treedef, new_rs))


def _per_machine_values_and_grads(params, batch, cfg, norm=None):
    """vmapped per-machine (loss_j, g_j) over the replicated (m, load,
    ...) batch -- the materialised form both the manual collective and
    the compressed replicated path reduce. ``norm`` overrides the loss
    normaliser (the streaming path passes the *full* batch's label
    count while feeding machine chunks)."""
    bw = batch["block_weight"]
    load = bw.shape[1]
    if norm is None:
        norm = batch["labels"].size

    def machine_loss(p, mb, bw_j):
        flat = {k: x.reshape((-1,) + x.shape[2:])
                for k, x in mb.items()}
        per_seq = M.train_loss(p, flat, cfg, per_example=True)
        per_block = per_seq.reshape(load, -1).sum(axis=1)
        return (bw_j * per_block).sum() / norm

    data = {k: v for k, v in batch.items() if k != "block_weight"}
    return jax.vmap(
        lambda mb, bw_j: jax.value_and_grad(machine_loss)(
            params, mb, bw_j))(data, bw)


def make_train_step(cfg: ModelConfig, optimizer: opt_mod.Optimizer,
                    n_microbatches: int = 1, *, dedup: bool = False,
                    norm_scale: float = 1.0, alpha_weights=None,
                    compress=None, error_feedback: bool = True):
    """(params, opt_state, coded_batch, w) -> (params, opt_state,
    metrics).

    ``n_microbatches`` > 1 accumulates gradients over equal splits of
    the per-block batch axis under ``lax.scan`` (constant HLO size,
    rematerialised activations): the mean of per-microbatch losses /
    gradients equals the single-shot step because the coded loss is a
    normalised sum over sequences. Accumulation is deliberately
    float32 -- exact for the float32 param configs shipped here, and
    the standard higher-precision accumulator if params ever go bf16
    (where the single-shot step would differ by the grads' bf16
    rounding, not by this sum).

    ``dedup=True`` builds the deduplicated-block step instead: the
    batch is ``CodedBatcher.unique_blocks`` output and ``w`` is the
    per-block ``v = A @ w`` (pass ``norm_scale=dedup_norm_scale(A)``
    to keep loss values aligned with the replicated path).

    Metrics stay on device so pipelined loops never block on them:
    ``alpha_bar`` (the debias divisor the driver used to fetch as a
    host-side ``A @ w`` every step) is folded into the metrics dict --
    ``mean(v)`` directly on the dedup path, ``(colsum(A)/n) . w`` via
    ``alpha_weights`` on the replicated one (omitted if None).

    ``compress`` (a ``core.compress`` codec name or Codec) switches to
    the compressed-combine execution model: the step's signature grows
    the error-feedback state, ``(params, opt_state, comp_state, batch,
    w) -> (params, opt_state, comp_state, metrics)``. Per-row (machine
    or unique-block) gradients are materialised by a vmapped backward
    pass, quantized with error feedback, and reduced through the fused
    ``quantized_combine`` kernel; metrics gain ``comm_bytes`` (the
    payload the combine consumed this step, a trace-time constant).
    Incompatible with ``n_microbatches > 1`` (the residual update is
    defined per full-batch compression round).
    """
    nm = int(n_microbatches)
    if nm < 1:
        raise ValueError("n_microbatches must be >= 1")
    aw = (None if alpha_weights is None
          else jnp.asarray(alpha_weights, jnp.float32))

    if compress is not None:
        if nm != 1:
            raise ValueError("compress does not compose with "
                             "n_microbatches > 1")
        codec = compress_mod.get_codec(compress)

        def compressed_step(params, opt_state, comp_state, batch, w):
            if dedup:
                labels = batch["labels"]
                norm = labels.size * norm_scale

                def block_loss(p, blk):
                    per_seq = M.train_loss(p, blk, cfg,
                                           per_example=True)
                    return per_seq.sum() / norm

                losses, grads = jax.vmap(
                    lambda blk: jax.value_and_grad(block_loss)(
                        params, blk))(batch)
            else:
                losses, grads = _per_machine_values_and_grads(
                    params, batch, cfg)
            loss = (w * losses).sum()
            combined, new_resid = compress_combine_tree(
                grads, comp_state["residual"], w, codec,
                error_feedback=error_feedback)
            rows = w.shape[0]
            comm = compress_mod.comm_bytes_per_step(
                codec, int(rows), params)
            updates, opt_state = optimizer.update(combined, opt_state,
                                                  params)
            params = opt_mod.apply_updates(params, updates)
            metrics = {"loss": loss,
                       "grad_norm": opt_mod.global_norm(combined),
                       "comm_bytes": jnp.asarray(comm, jnp.float32)}
            if dedup:
                metrics["alpha_bar"] = w.mean()
            elif aw is not None:
                metrics["alpha_bar"] = jnp.dot(aw, w)
            return params, opt_state, {"residual": new_resid}, metrics

        return compressed_step

    def loss_fn(p, b, wv):
        if dedup:
            return coded_loss_fn_dedup(p, b, wv, cfg,
                                       norm_scale=norm_scale)
        return coded_loss_fn(p, b, wv, cfg)

    def step(params, opt_state, batch, w):
        if nm == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch, w)
        else:
            # microbatch split along the per-block batch axis:
            # replicated leaves are (m, load, bs, ...), dedup (n, bs, ...)
            bax = 1 if dedup else 2
            bw = None if dedup else batch["block_weight"]

            def to_micro(leaf):
                bs_ = leaf.shape[bax]
                if bs_ % nm:
                    raise ValueError(
                        f"block batch {bs_} not divisible by "
                        f"{nm} microbatches")
                x = leaf.reshape(leaf.shape[:bax] + (nm, bs_ // nm)
                                 + leaf.shape[bax + 1:])
                return jnp.moveaxis(x, bax, 0)

            micro = {k: to_micro(v) for k, v in batch.items()
                     if k != "block_weight"}

            def body(carry, mb):
                g_acc, l_acc = carry
                if bw is not None:
                    mb = dict(mb)
                    mb["block_weight"] = bw
                l, g = jax.value_and_grad(loss_fn)(params, mb, w)
                return (jax.tree.map(jnp.add, g_acc, g), l_acc + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / nm, gsum)
            loss = lsum / nm
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = opt_mod.apply_updates(params, updates)
        metrics = {"loss": loss,
                   "grad_norm": opt_mod.global_norm(grads)}
        if dedup:
            metrics["alpha_bar"] = w.mean()
        elif aw is not None:
            metrics["alpha_bar"] = jnp.dot(aw, w)
        return params, opt_state, metrics

    return step


def make_prefill_step(cfg: ModelConfig):
    """(params, batch) -> last-position logits (B, V_pad)."""
    def step(params, batch):
        return M.prefill(params, batch["tokens"], cfg,
                         prefix=batch.get("prefix"),
                         src=batch.get("src"))
    return step


def make_serve_step(cfg: ModelConfig, window: Optional[int] = None):
    """(params, token, cache) -> (logits, new_cache)."""
    def step(params, token, cache):
        return M.decode_step(params, token, cache, cfg, window=window)
    return step


def _coded_psum_allreduce(mesh, local_combine_fn, trees, w: jnp.ndarray):
    """The one shard_map skeleton the coded-allreduce family shares.

    Every payload tree in ``trees`` (and ``w``) carries a leading
    (global) machine axis sharded over the (pod, data) worker axes;
    ``local_combine_fn(*local_trees, w_local)`` reduces each shard's
    local machines to one partial combine, and a psum over the worker
    axes produces the replicated global result. The variants differ
    only in what crosses the machine axis (float32 gradients, int8
    payloads, packed sign bit-planes) and which fused kernel reduces
    it locally.
    """
    axes = data_axes(mesh)
    lead = axes if len(axes) > 1 else axes[0]
    in_specs = tuple(jax.tree.map(lambda _: P(lead), t) for t in trees)

    def body(*args):
        *local, w_local = args
        out = local_combine_fn(*local, w_local)
        return jax.tree.map(lambda x: jax.lax.psum(x, axes), out)

    return shard_map(body, mesh=mesh, in_specs=(*in_specs, P(lead)),
                     out_specs=jax.tree.map(lambda _: P(), trees[0]))(
        *trees, w)


def coded_allreduce(grads, w: jnp.ndarray, mesh):
    """The paper combine as an explicit shard_map collective.

    ``grads`` leaves carry a leading (global) machine axis of size m
    sharded over the (pod, data) axes; ``w`` is the (m,) decoding
    weights sharded the same way. Each shard w-weights and sums its
    local machines through the ``coded_combine`` kernel, then a psum
    over the worker axes produces the replicated global
    ``sum_j w_j g_j``.
    """
    return _coded_psum_allreduce(mesh, cc_ops.coded_combine_tree,
                                 (grads,), w)


def quantized_coded_allreduce(q_tree, scale_tree, w: jnp.ndarray, mesh):
    """``coded_allreduce`` carrying the quantized payload.

    ``q_tree`` leaves are (m, ...) codec payloads (int8 for int8/sign,
    float32 for 'none') with matching (m,) per-machine scales in
    ``scale_tree``, both sharded over the worker axes like the float32
    gradients would be -- so the bytes crossing the machine axis are
    the codec's wire format, not float32. Each shard runs the fused
    ``quantized_combine`` over its local machines and a single float32
    psum of the partial combines produces the replicated global
    ``sum_j w_j * scale_j * q_j``.
    """
    return _coded_psum_allreduce(mesh, cc_ops.quantized_combine_tree,
                                 (q_tree, scale_tree), w)


def packed_sign_coded_allreduce(q_tree, scale_tree, w: jnp.ndarray,
                                mesh, shapes):
    """``coded_allreduce`` carrying the 1-bit packed sign payload.

    ``q_tree`` leaves are (m, ceil(size/8)) uint8 bit-planes (the
    ``sign_packed`` codec's wire format -- 1/32 of the float32 bytes
    crossing the machine axis); ``shapes`` is the matching pytree of
    combined-output shapes, which the packed payload cannot carry
    itself. Each shard runs the fused ``packed_sign_combine`` (unpack,
    +-1, weight, reduce in one pass) over its local machines, then the
    shared float32 psum.
    """
    def local_combine(qt, st, w_local):
        return cc_ops.packed_sign_combine_tree(qt, st, w_local, shapes)

    return _coded_psum_allreduce(mesh, local_combine,
                                 (q_tree, scale_tree), w)


def alpha_bar_weights(assignment: Assignment) -> np.ndarray:
    """(m,) vector a with a . w == mean(A @ w): the on-device form of
    the alpha-bar debias divisor (colsum(A)/n), so train steps can
    report it in metrics instead of the driver syncing ``A @ w`` to
    the host every step."""
    return (assignment.A.sum(axis=0) / assignment.n).astype(np.float32)


def _quantize_rows(grads, residual, codec, error_feedback: bool):
    """Row-wise quantize of g (+ residual) per leaf, flat payloads.

    Returns (q_tree, scale_tree, new_residual_tree, shapes_tree):
    payload leaves stay flat (rows, D) -- or (rows, ceil(D/8)) for a
    packed codec -- and ``shapes_tree`` carries each leaf's
    combined-output shape (the original shape minus the row axis) for
    the post-combine reshape the flat payload can't express itself.
    """
    g_leaves, treedef = jax.tree.flatten(grads)
    r_leaves = treedef.flatten_up_to(residual)
    q_l, s_l, r_l, shp_l = [], [], [], []
    for g, r in zip(g_leaves, r_leaves):
        rows = g.shape[0]
        flat = g.reshape(rows, -1).astype(jnp.float32)
        pre = flat + r.reshape(rows, -1) if error_feedback else flat
        q, s = codec.compress(pre)
        q_l.append(q)
        s_l.append(s)
        r_l.append(
            (pre - codec.decompress(q, s, d=flat.shape[1]))
            .reshape(g.shape) if error_feedback else r)
        shp_l.append(tuple(g.shape[1:]))
    unflatten = treedef.unflatten
    return (unflatten(q_l), unflatten(s_l), unflatten(r_l),
            unflatten(shp_l))


def _compressed_allreduce(q_tree, scale_tree, w, codec, shapes, mesh):
    """Codec-dispatching wire collective over flat row payloads."""
    if codec.packed:
        return packed_sign_coded_allreduce(q_tree, scale_tree, w, mesh,
                                           shapes)
    out = quantized_coded_allreduce(q_tree, scale_tree, w, mesh)
    treedef = jax.tree.structure(out)
    return treedef.unflatten(
        [x.reshape(s) for x, s in zip(jax.tree.leaves(out),
                                      treedef.flatten_up_to(shapes))])


def _n_worker_shards(mesh) -> int:
    m = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        m *= mesh.shape["pod"]
    return m


def _to_stream_chunks(leaf, n_shards: int, chunk: int):
    """(m, ...) -> (T, n_shards * chunk, ...) machine regrouping.

    The machine axis is block-sharded over the worker shards (shard s
    owns machines [s*m/W, (s+1)*m/W)), so a scan over contiguous
    machine chunks would serialise the shards. This regrouping makes
    scan step t carry ``chunk`` consecutive machines *from every
    shard* -- full data parallelism per step, O(chunk) live gradients
    per device -- and the slice's leading axis stays block-contiguous
    per shard, so the per-chunk allreduce's P(lead) specs still hold.
    """
    m = leaf.shape[0]
    per = m // n_shards
    x = leaf.reshape((n_shards, per // chunk, chunk) + leaf.shape[1:])
    x = jnp.moveaxis(x, 1, 0)
    return x.reshape((per // chunk, n_shards * chunk) + leaf.shape[1:])


def _from_stream_chunks(leaf, n_shards: int, chunk: int):
    """(T, n_shards * chunk, ...) -> (m, ...): exact inverse of
    ``_to_stream_chunks`` (the residual pytree's way home)."""
    t = leaf.shape[0]
    x = leaf.reshape((t, n_shards, chunk) + leaf.shape[2:])
    x = jnp.moveaxis(x, 0, 1)
    return x.reshape((n_shards * t * chunk,) + leaf.shape[2:])


def make_manual_collective_train_step(cfg: ModelConfig,
                                      optimizer: opt_mod.Optimizer,
                                      mesh, alpha_weights=None,
                                      compress=None,
                                      error_feedback: bool = True,
                                      streaming_chunk: Optional[int]
                                      = None):
    """Replicated-path train step whose combine is the explicit
    ``coded_allreduce`` shard_map psum instead of the GSPMD-inserted
    one (the ROADMAP manual-vs-gspmd comparison).

    Unlike ``make_train_step`` -- where autodiff of the w-weighted
    loss fuses the per-machine gradients into one backward pass -- the
    manual route must materialise what the collective reduces: per-
    machine gradients g_j via a vmapped value_and_grad over the
    machine axis (same backward FLOPs, m x the gradient memory), then
    ``sum_j w_j g_j`` as coded_combine + psum over the worker axes.
    That makes it the fidelity-first option (the reduction is
    inspectable and the per-machine g_j exist as tensors, as on a real
    cluster), not the fast one; ``benchmarks/train_step.py`` carries a
    ``collective: manual`` row tracking exactly what that costs.

    ``compress`` routes the combine through
    ``quantized_coded_allreduce`` (or, for the packed 1-bit codec,
    ``packed_sign_coded_allreduce``) instead: the per-machine
    gradients are quantized (with error feedback) *before* the
    collective, so what crosses the worker axes is the codec's wire
    payload. As in ``make_train_step``, the compressed step's
    signature carries the residual state as a third positional
    argument.

    ``streaming_chunk`` bounds how many of the m per-machine gradients
    are ever live at once: a ``lax.scan`` walks the machine axis in
    groups of ``chunk`` machines per worker shard (``_to_stream_chunks``
    regroups the block-sharded machine axis so every scan step keeps
    all shards busy), runs the per-chunk collective, and accumulates
    into one float32 pytree -- the combine is linear in the g_j, so
    streaming only reassociates the sum (pinned to the materialising
    step at float32 tolerance in tests/test_streaming.py). Composes
    with ``compress``: quantization, error feedback and the wire
    collective all happen per chunk, and the residual chunks are
    scanned out and restored to machine order. Requires m divisible by
    (worker shards) * chunk.
    """
    aw = (None if alpha_weights is None
          else jnp.asarray(alpha_weights, jnp.float32))
    codec = (None if compress is None
             else compress_mod.get_codec(compress))
    if streaming_chunk is not None and int(streaming_chunk) < 1:
        raise ValueError("streaming_chunk must be >= 1")

    def _finish(params, opt_state, loss, grads, w, extra=None):
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = opt_mod.apply_updates(params, updates)
        metrics = {"loss": loss,
                   "grad_norm": opt_mod.global_norm(grads)}
        if extra:
            metrics.update(extra)
        if aw is not None:
            metrics["alpha_bar"] = jnp.dot(aw, w)
        return params, opt_state, metrics

    if streaming_chunk is not None:
        chunk = int(streaming_chunk)
        n_shards = _n_worker_shards(mesh)

        def _check_divisible(m):
            if m % (n_shards * chunk):
                raise ValueError(
                    f"streaming needs m divisible by worker shards x "
                    f"chunk = {n_shards} x {chunk}, got m={m}")

        def _scan_combine(params, batch, w, residual):
            """Shared streaming core: scan machine chunks, accumulate
            the (possibly quantized) combine and the w-weighted loss;
            returns (grads, loss, new_residual-or-None)."""
            m = w.shape[0]
            _check_divisible(m)
            norm = batch["labels"].size
            b_xs = {k: _to_stream_chunks(v, n_shards, chunk)
                    for k, v in batch.items()}
            w_xs = _to_stream_chunks(w, n_shards, chunk)
            xs = (b_xs, w_xs)
            if residual is not None:
                xs += (jax.tree.map(
                    lambda r: _to_stream_chunks(r, n_shards, chunk),
                    residual),)

            def body(carry, xs_t):
                g_acc, l_acc = carry
                cb, w_c = xs_t[0], xs_t[1]
                losses, grads = _per_machine_values_and_grads(
                    params, cb, cfg, norm=norm)
                if codec is None:
                    contrib = coded_allreduce(grads, w_c, mesh)
                    new_r = None
                else:
                    q_t, s_t, new_r, shapes = _quantize_rows(
                        grads, xs_t[2], codec, error_feedback)
                    contrib = _compressed_allreduce(
                        q_t, s_t, w_c, codec, shapes, mesh)
                g_acc = jax.tree.map(jnp.add, g_acc, contrib)
                l_acc = l_acc + (w_c * losses).sum()
                return (g_acc, l_acc), new_r

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), r_ys = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)), xs)
            if residual is not None:
                r_ys = jax.tree.map(
                    lambda r: _from_stream_chunks(r, n_shards, chunk),
                    r_ys)
            return grads, loss, r_ys

        if codec is not None:
            def streaming_compressed_step(params, opt_state, comp_state,
                                          batch, w):
                grads, loss, new_resid = _scan_combine(
                    params, batch, w, comp_state["residual"])
                comm = compress_mod.comm_bytes_per_step(
                    codec, int(w.shape[0]), params)
                params, opt_state, metrics = _finish(
                    params, opt_state, loss, grads, w,
                    extra={"comm_bytes": jnp.asarray(comm,
                                                     jnp.float32)})
                return params, opt_state, {"residual": new_resid}, \
                    metrics

            return streaming_compressed_step

        def streaming_step(params, opt_state, batch, w):
            grads, loss, _ = _scan_combine(params, batch, w, None)
            return _finish(params, opt_state, loss, grads, w)

        return streaming_step

    if codec is not None:
        def compressed_step(params, opt_state, comp_state, batch, w):
            losses, grads = _per_machine_values_and_grads(
                params, batch, cfg)
            loss = (w * losses).sum()
            q_tree, s_tree, new_resid, shapes = _quantize_rows(
                grads, comp_state["residual"], codec, error_feedback)
            combined = _compressed_allreduce(q_tree, s_tree, w, codec,
                                             shapes, mesh)
            comm = compress_mod.comm_bytes_per_step(
                codec, int(w.shape[0]), params)
            params, opt_state, metrics = _finish(
                params, opt_state, loss, combined, w,
                extra={"comm_bytes": jnp.asarray(comm, jnp.float32)})
            return params, opt_state, {"residual": new_resid}, metrics

        return compressed_step

    def step(params, opt_state, batch, w):
        losses, grads = _per_machine_values_and_grads(params, batch,
                                                      cfg)
        grads = coded_allreduce(grads, w, mesh)   # (m, ...) -> combine
        loss = (w * losses).sum()
        return _finish(params, opt_state, loss, grads, w)

    return step


# ---------------------------------------------------------------------------
# Host-side coding runtime
# ---------------------------------------------------------------------------


def make_assignment(coding: CodingConfig, m: int) -> Assignment:
    """Instantiate the block assignment for m coded workers."""
    if coding.scheme == "expander":
        return expander_assignment(m, coding.replication,
                                   vertex_transitive=True,
                                   seed=coding.seed)
    if coding.scheme == "frc":
        return frc_assignment(m, coding.replication)
    if coding.scheme == "uncoded":
        return uncoded_assignment(m)
    if coding.scheme == "cyclic_mds":
        return cyclic_mds_assignment(m, coding.replication)
    if coding.scheme == "bibd":
        # Solve for the design whose *machine* count is m. With
        # replication r = coding.replication: the affine plane of
        # order q = r - 1 has q^2 + q = (r-1)r machines, else a
        # symmetric design puts one machine per point (v = m, k = r).
        r = coding.replication
        if m == (r - 1) * r:
            return bibd_assignment((r - 1) ** 2, r - 1, design="affine")
        return bibd_assignment(m, r, design="symmetric")
    if coding.scheme == "random_regular":
        return random_matching_assignment(m, coding.replication,
                                          seed=coding.seed)
    raise ValueError(f"unknown scheme {coding.scheme!r} "
                     "(expander | frc | uncoded | cyclic_mds | bibd "
                     "| random_regular)")


def elastic_seed(seed: int, generation: int) -> int:
    """The seed for elastic generation g of a run seeded ``seed``.

    A pure function of (seed, generation) -- both the elastic
    re-assignment in a running driver AND a fresh driver launched on
    the survivors must derive the same seed, or the differential pin
    (elastic trajectory == fresh-run trajectory) could not hold."""
    if generation < 0:
        raise ValueError("generation must be >= 0")
    return seed + 1_000_003 * generation


def elastic_coding(coding: CodingConfig, m_new: int,
                   generation: int) -> CodingConfig:
    """The CodingConfig for generation ``generation`` over ``m_new``
    survivors.

    Scheme divisibility can break when machines die (expander needs
    d | 2m', FRC d | m'), so the replication degree degrades to the
    largest feasible d' <= d -- gracefully, the way the sharding
    rules' divisibility fallback degrades specs -- rather than
    refusing to continue. Deterministic, so the fresh-run side of the
    differential pin reconstructs the identical assignment."""
    if m_new < 1:
        raise ValueError("need at least one survivor")
    seed = elastic_seed(coding.seed, generation)
    if m_new == 1 or (coding.scheme == "expander" and m_new == 2):
        # A single survivor cannot carry a replicated code, and the
        # smallest d-regular graph scheme is the 3-edge cycle (two
        # vertices collapse to a double edge).
        return dataclasses.replace(coding, scheme="uncoded",
                                   replication=1, seed=seed)
    d = min(coding.replication, m_new)
    if coding.scheme == "expander":
        # d = 2 (the cycle) always divides 2m', so the loop bottoms
        # out at a valid graph scheme for m' >= 3.
        while d > 2 and (2 * m_new) % d:
            d -= 1
        d = max(d, 2)
    elif coding.scheme == "frc":
        while d > 1 and m_new % d:
            d -= 1
    return dataclasses.replace(coding, replication=d, seed=seed)


def elastic_reassign(runtime: "CodingRuntime", dead, *,
                     generation: int,
                     mask_source: "Optional[sw.MaskSource]" = None
                     ) -> "CodingRuntime":
    """Re-draw the code over the survivors after permanent deaths.

    ``dead`` is the dead logical machine ids *of the current runtime*
    (the driver's SurvivorMap translates original ids). Returns a
    fresh ``CodingRuntime`` over m' = m - len(dead) machines with the
    generation-derived seed: new expander assignment, new debias
    scale, empty decode cache. Training resumes from the live
    {params, opt_state} -- the block shards remap through the existing
    ``dist/sharding.block_shardings`` divisibility-fallback rules when
    the driver rebuilds its jitted step -- and the post-death
    trajectory is bit-identical to a fresh run launched on the
    survivors from the same restored state (tests/test_elastic.py).
    """
    dead = np.atleast_1d(np.asarray(dead, dtype=np.int64))
    if np.unique(dead).size != dead.size:
        raise ValueError("duplicate dead machine ids")
    if dead.size and (dead.min() < 0 or dead.max() >= runtime.m):
        raise ValueError(f"dead ids {dead.tolist()} out of range for "
                         f"m={runtime.m}")
    m_new = runtime.m - int(dead.size)
    coding = elastic_coding(runtime.coding, m_new, generation)
    return CodingRuntime(coding, m_new, debias=runtime.debias,
                         debias_trials=runtime.debias_trials,
                         cache_size=runtime.cache_size,
                         mask_source=mask_source,
                         adaptive=runtime.adaptive)


@dataclasses.dataclass
class CodingRuntime:
    """Host bridge: assignment + straggler process + per-step weights.

    One instance per run. ``step_weights()`` samples this round's alive
    mask from the configured ``core.stragglers`` model and returns the
    debiased decoding weights w (w_j = 0 on stragglers) for the train
    step, memoised by mask: under stagnant straggler processes
    (markov / adversarial) the same mask repeats for many consecutive
    rounds and decoding drops out of the step latency entirely.

    The alpha-bar debias scale is estimated once at construction --
    optimal decoding shrinks alpha below 1 on average, and the scale
    makes the expected update unbiased without per-step work. For the
    stochastic models it is one ``batched_alpha`` decode of a Bernoulli
    mask batch (``core.step_weights.debias_scale_mc``); the adversarial
    model replays a single fixed mask, so its exact scale comes from
    that mask's own alpha. Fixed decoding is already unbiased by
    construction, so the scale stays 1 there.
    """

    coding: CodingConfig
    m: int
    debias: bool = True
    debias_trials: int = 256
    cache_size: int = 4096
    mask_source: Optional[sw.MaskSource] = None
    # Per-step decoding policy (core.adaptive): None keeps the
    # pre-adaptive fixed-ahead-of-time behaviour bit-identically; a
    # policy spec ("adaptive" | "always_optimal" | "always_fixed" | a
    # DecodingPolicy) makes every round decide its decoder from the
    # online straggler estimate before the round's mask is observed.
    adaptive: Optional[object] = None

    def __post_init__(self):
        self.assignment = make_assignment(self.coding, self.m)
        self.model = sw.make_straggler_model(
            self.assignment, self.coding.straggler_model,
            self.coding.straggler_p)
        self.rng = np.random.default_rng(self.coding.seed)
        if self.mask_source is None:
            # Default: the synthetic simulation path, wrapping this
            # runtime's own (model, rng) pair so the RNG stream is
            # bit-identical to the pre-abstraction code.
            self.mask_source = sw.SampledMaskSource(self.model,
                                                   self.rng, self.m)
        elif self.mask_source.m != self.m:
            raise ValueError(
                f"mask source is over m={self.mask_source.m} machines, "
                f"runtime has m={self.m}")
        self.policy: Optional[DecodingPolicy] = None
        self.estimator: Optional[OnlineStragglerEstimator] = None
        self.last_decision: Optional[PolicyDecision] = None
        self.decision_counts: Dict[str, int] = {}
        if self.adaptive is not None:
            self.policy = make_policy(self.adaptive,
                                      p=self.coding.straggler_p)
            # The configured p seeds the estimator's prior; the
            # observed stream takes over within a few rounds.
            self.estimator = OnlineStragglerEstimator(
                self.m, prior_p=min(max(self.coding.straggler_p, 0.0),
                                    0.99))
        self.scale = 1.0
        # An adaptive runtime may decode optimally on any step
        # whatever the configured default, so it needs the optimal-
        # decode debias scale too; the scale applies only to optimal
        # decodes (Section VIII fixed weights are unbiased by
        # construction), and its value is a pure function of
        # (assignment, p, seed) -- identical to the non-adaptive
        # runtime's, which keeps always_optimal bit-identical.
        if self.debias and (self.coding.decoding == "optimal"
                            or self.policy is not None):
            if self.coding.straggler_model == "adversarial":
                # The attack mask is deterministic: the exact debias
                # factor is sqrt(n)/|alpha| of that one decode.
                _, alpha = sw.step_weights(
                    self.assignment, self.model.sample(self.rng),
                    method="optimal")
                self.scale = float(
                    np.sqrt(alpha.size) /
                    max(np.linalg.norm(alpha), 1e-30))
            else:
                # Offset the seed: bernoulli_uniforms(seed) replays the
                # exact uniform stream the training masks consume, so
                # the same seed would fit the scale in-sample on the
                # run's own first `debias_trials` masks.
                self.scale = sw.debias_scale_mc(
                    self.assignment, p=self.coding.straggler_p,
                    trials=self.debias_trials,
                    seed=self.coding.seed + 0x5EED)
        self._cache: Dict[bytes, np.ndarray] = {}
        self.decode_calls = 0
        self.steps_sampled = 0

    def skip(self, rounds: int) -> None:
        """Fast-forward the mask stream by ``rounds`` rounds without
        decoding -- the checkpoint-resume path: a restored run calls
        ``skip(start_step)`` so its subsequent masks (and hence
        weights, via the same memoised decode) are bit-identical to
        the original run's stream from that step on. For the sampled
        source this consumes exactly the RNG draws
        ``step_weights``/``weights_lookahead`` would (and advances
        stateful models like the Markov chain); observed sources
        reject it (re-observe instead of replaying RNG)."""
        if rounds < 0:
            raise ValueError("rounds must be >= 0")
        self.mask_source.skip(rounds)
        self.steps_sampled += rounds

    def weights_for(self, alive: np.ndarray, *,
                    method: Optional[str] = None,
                    p: Optional[float] = None) -> np.ndarray:
        """Memoised decode of one given (m,) alive mask -> w float32.

        The mask-agnostic half of ``step_weights``: the observed-mask
        path (heartbeat-derived masks pushed by the driver) and the
        sampled path share this cache, so stagnant failures hit the
        memo whether they were sampled or real. ``method``/``p``
        default to the configured decoding; an adaptive policy passes
        its per-step decision, and the memo key carries (method, p) so
        decisions with different decoders never alias (the debias
        scale applies only to optimal decodes)."""
        alive = np.asarray(alive, dtype=bool)
        if alive.shape != (self.m,):
            raise ValueError(f"mask must be ({self.m},), "
                             f"got {alive.shape}")
        if method is None:
            method = self.coding.decoding
        if p is None:
            p = self.coding.straggler_p
        key = (method, float(p), alive.tobytes())
        w = self._cache.get(key)
        if w is None:
            scale = self.scale if method == "optimal" else 1.0
            w, _ = sw.step_weights(
                self.assignment, alive, method=method, p=p, scale=scale)
            w = w.astype(np.float32)
            if len(self._cache) >= self.cache_size:
                # FIFO eviction: i.i.d. models at large m never repeat
                # masks, and the cache must not grow with step count.
                self._cache.pop(next(iter(self._cache)))
            self._cache[key] = w
            self.decode_calls += 1
        return w

    def _decide(self) -> PolicyDecision:
        """One adaptive decision from the estimator's past-only state
        (the protocol of ``core.adaptive.replay_policy``: decide, use,
        then observe)."""
        decision = self.policy.decide(self.estimator.estimate())
        self.last_decision = decision
        self.decision_counts[decision.method] = \
            self.decision_counts.get(decision.method, 0) + 1
        return decision

    def step_weights(self) -> Tuple[np.ndarray, np.ndarray]:
        """One round from the mask source: returns (w (m,) float32,
        alive (m,) bool)."""
        alive = self.mask_source.next_mask()
        self.steps_sampled += 1
        if self.policy is not None:
            decision = self._decide()
            w = self.weights_for(alive, method=decision.method,
                                 p=decision.p)
            self.estimator.observe(alive)
            return w, alive
        return self.weights_for(alive), alive

    def suggested_lookahead(self) -> int:
        """The policy's current prefetch-horizon suggestion (>= 1);
        1 when no policy is configured. Peeks at the estimate without
        consuming a round."""
        if self.policy is None:
            return 1
        return self.policy.decide(self.estimator.estimate()).lookahead

    def decode_batch(self, masks) -> Tuple[np.ndarray, np.ndarray]:
        """Batched (T, m) masks -> (W, alphas) through the shared
        pipeline -- the lookahead/benchmark path."""
        return sw.batched_step_weights(
            self.assignment, masks, method=self.coding.decoding,
            p=self.coding.straggler_p, scale=self.scale)

    def block_weights(self, w: np.ndarray) -> np.ndarray:
        """Machine weights -> per-block v = A @ w for the dedup step."""
        return sw.block_weights(self.assignment, w).astype(np.float32)

    def weights_lookahead(self, horizon: int
                          ) -> Tuple[np.ndarray, np.ndarray]:
        """Pre-sample the next ``horizon`` rounds and decode them in
        one ``decode_batch`` call: returns (W (horizon, m) float32,
        alive (horizon, m) bool).

        Consumes the same RNG stream as ``step_weights``, one sample
        per round, so a lookahead loop sees bit-identical masks and
        weights to a per-step loop over the same seed (pinned in
        tests/test_coding_runtime.py). The chunk is deduplicated
        against the memo cache first -- under stagnant processes the
        whole horizon is usually a single novel decode (or none).

        With an adaptive policy the rounds inside the chunk decide
        sequentially (decide from the past, decode, observe) through
        the same memoised scalar path as ``step_weights`` -- each
        round's decision may pick a different decoder, so there is no
        single-method batch to dispatch; bit-identity with the
        per-step loop is by construction.
        """
        if horizon < 1:
            raise ValueError("horizon must be >= 1")
        alive = np.stack(
            [self.mask_source.next_mask() for _ in range(horizon)])
        self.steps_sampled += horizon
        if self.policy is not None:
            rows = []
            for a in alive:
                decision = self._decide()
                rows.append(self.weights_for(a, method=decision.method,
                                             p=decision.p))
                self.estimator.observe(a)
            return np.stack(rows), alive
        keys = [(self.coding.decoding, float(self.coding.straggler_p),
                 a.tobytes()) for a in alive]
        # Gather this horizon's rows locally: FIFO eviction while
        # inserting novel decodes must not drop an entry the horizon
        # itself still references.
        w_by_key = {k: self._cache[k] for k in keys if k in self._cache}
        novel = {}   # mask bytes -> row in the batched decode
        for t, k in enumerate(keys):
            if k not in w_by_key and k not in novel:
                novel[k] = t
        if novel:
            W_new, _ = self.decode_batch(alive[sorted(novel.values())])
            self.decode_calls += len(novel)
            for k, w_new in zip(sorted(novel, key=novel.get), W_new):
                w_by_key[k] = w_new.astype(np.float32)
                if len(self._cache) >= self.cache_size:
                    self._cache.pop(next(iter(self._cache)))
                self._cache[k] = w_by_key[k]
        W = np.stack([w_by_key[k] for k in keys])
        return W, alive


class LookaheadPrefetcher:
    """``weights_lookahead`` off the main thread, bit-identically.

    The train driver's steady-state loop used to stall every
    ``horizon`` steps while ``CodingRuntime.weights_lookahead`` sampled
    and batch-decoded the next chunk on the main thread -- invisible at
    smoke m, a real bubble at very large m where one optimal decode is
    O(m) python. This wrapper runs the same calls on the driver's
    single batch-builder executor, prefetching chunk k+1 while the
    device consumes chunk k.

    Bit-identity with the synchronous path is by construction, not by
    luck: the prefetcher issues the *same* ``weights_lookahead(k)``
    calls in the same order against the same runtime, merely from the
    worker thread, and chunk sizes are capped by the remaining step
    budget exactly like the inline code was -- so RNG consumption,
    memo-cache state, and the (W, alive) stream match the per-step
    loop sample for sample (pinned in tests/test_coding_runtime.py).
    The runtime itself is only ever touched from the worker thread
    after construction; ``block_weights`` (pure, RNG-free) remains
    safe to call from the main thread.
    """

    def __init__(self, runtime: CodingRuntime, pool, horizon: int,
                 total_steps: int):
        if horizon < 1:
            raise ValueError("horizon must be >= 1")
        self.runtime = runtime
        self.pool = pool
        self.horizon = horizon
        self.remaining = total_steps
        self._chunk = None
        self._cursor = 0
        self._future = self._submit()

    def _submit(self):
        k = min(self.horizon, self.remaining)
        if k < 1:
            return None
        self.remaining -= k
        return self.pool.submit(self.runtime.weights_lookahead, k)

    def next(self) -> Tuple[np.ndarray, np.ndarray]:
        """The next round's (w (m,) float32, alive (m,) bool)."""
        if self._chunk is None or self._cursor == len(self._chunk[0]):
            if self._future is None:
                raise RuntimeError("lookahead stream exhausted")
            self._chunk = self._future.result()
            self._cursor = 0
            self._future = self._submit()   # prefetch the next chunk
        W, alive = self._chunk
        t = self._cursor
        self._cursor += 1
        return W[t], alive[t]
