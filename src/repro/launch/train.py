import os
if "XLA_FLAGS" not in os.environ:
    # Standalone CPU demo: 8 virtual devices -> mesh (data=4, model=2).
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""End-to-end coded LM training driver.

Runs REAL training (not a dry-run): synthetic LM corpus -> coded block
partitioner -> shard_map/pjit coded train step with host-side straggler
sampling + O(m) optimal decoding each step. On CPU it uses the reduced
smoke configs and a (4, 2) mesh of virtual devices; on a TPU pod the
same driver takes the full configs and the production mesh.

  python -m repro.launch.train --arch qwen1.5-4b --steps 20 \
      --straggler-p 0.2 --scheme expander --decoding optimal
"""

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.configs import CodingConfig, get_config
from repro.data.pipeline import CodedBatcher, SyntheticLM
from repro.dist import coded_train, sharding as rules
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.models import model as M
from repro.optim import optimizers as opt_mod


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--scheme", default="expander",
                    choices=("expander", "frc", "uncoded"))
    ap.add_argument("--decoding", default="optimal",
                    choices=("optimal", "fixed"))
    ap.add_argument("--straggler-model", default="bernoulli",
                    choices=("bernoulli", "markov", "adversarial"))
    ap.add_argument("--straggler-p", type=float, default=0.2)
    ap.add_argument("--replication", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--block-size", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full architecture (TPU pods)")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = cfg.smoke_variant()

    if args.production_mesh:
        mesh = make_production_mesh()
    else:
        n_dev = len(jax.devices())
        model_par = 2 if n_dev % 2 == 0 and n_dev > 1 else 1
        mesh = make_test_mesh((n_dev // model_par, model_par))

    m_workers = mesh.shape["data"] * mesh.shape.get("pod", 1)
    coding = CodingConfig(
        scheme=args.scheme, replication=args.replication,
        decoding=args.decoding, straggler_model=args.straggler_model,
        straggler_p=args.straggler_p, seed=args.seed)
    runtime = coded_train.CodingRuntime(coding, m_workers)
    n_blocks = runtime.assignment.n
    load = runtime.assignment.load
    global_batch = n_blocks * args.block_size

    source = SyntheticLM(cfg.vocab_size, args.seq_len, seed=args.seed)
    batcher = CodedBatcher(runtime.assignment, shuffle_seed=args.seed)

    key = jax.random.PRNGKey(args.seed)
    params = M.init_params(cfg, key)
    optimizer = opt_mod.get_optimizer("adamw", args.lr)
    opt_state = optimizer.init(params)
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        params = ckpt.restore(args.ckpt_dir, params)
        print(f"restored checkpoint from {args.ckpt_dir}")

    da = rules.data_axes(mesh)
    da1 = da if len(da) > 1 else da[0]
    M.set_residual_sharding(batch_axes=da1, model_axis="model")
    pspec = rules.safe_param_specs(params, mesh)
    pshard = rules.named(mesh, pspec)
    repl = rules.replicated(mesh)
    oshard = {"step": repl, "m": pshard, "v": pshard}

    train_step = coded_train.make_train_step(
        cfg, optimizer, n_microbatches=args.microbatches)

    losses = []
    with mesh:
        params = jax.device_put(params, pshard)
        opt_state = jax.device_put(opt_state, oshard)
        step_fn = None
        t0 = time.time()
        for step in range(args.steps):
            batch_np = batcher.code_batch(
                source.batch(global_batch, step))
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            bshard = rules.batch_shardings(mesh, batch)
            batch = {k: jax.device_put(v, bshard[k])
                     for k, v in batch.items()}
            w, alive = runtime.step_weights()
            wv = jax.device_put(jnp.asarray(w), repl)
            if step_fn is None:
                step_fn = jax.jit(
                    train_step,
                    in_shardings=(pshard, oshard, bshard, repl),
                    out_shardings=(pshard, oshard, None),
                    donate_argnums=(0, 1))
            params, opt_state, metrics = step_fn(params, opt_state,
                                                 batch, wv)
            # The raw coded loss is scaled by this step's straggler
            # draw (sum_i alpha_i varies); report the debiased estimate
            # loss / mean(alpha) so steps are comparable across draws.
            alpha_bar = float((runtime.assignment.A @ w).mean())
            losses.append(float(metrics["loss"]) / max(alpha_bar, 1e-3))
            if step % max(1, args.steps // 10) == 0 or \
                    step == args.steps - 1:
                print(f"step {step:4d} loss {losses[-1]:.4f} "
                      f"stragglers {int((~alive).sum())}/{m_workers} "
                      f"({time.time() - t0:.1f}s)")
    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, jax.device_get(params), step=args.steps)
        print(f"saved checkpoint to {args.ckpt_dir}")
    # The per-step coded loss is scaled by the straggler draw (w* varies
    # step to step), so compare window means, not endpoints.
    k = max(1, len(losses) // 4)
    first, last = np.mean(losses[:k]), np.mean(losses[-k:])
    assert last < first, f"loss did not decrease ({first:.3f}->{last:.3f})"
    print(json.dumps({"first_loss": losses[0], "last_loss": losses[-1],
                      "steps": args.steps, "m_workers": m_workers,
                      "scheme": args.scheme, "decoding": args.decoding}))
    return {"losses": losses}


if __name__ == "__main__":
    main()
