import os
if "XLA_FLAGS" not in os.environ:
    # Standalone CPU demo: 8 virtual devices -> mesh (data=4, model=2).
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""End-to-end coded LM training driver.

Runs REAL training (not a dry-run): synthetic LM corpus -> coded block
partitioner -> shard_map/pjit coded train step with host-side straggler
sampling + O(m) optimal decoding. On CPU it uses the reduced smoke
configs and a (4, 2) mesh of virtual devices; on a TPU pod the same
driver takes the full configs and the production mesh.

The loop is an async pipeline: shardings and the jitted step are built
once per *generation* (shapes are static until an elastic
re-assignment changes them), host batch construction is
double-buffered against device compute on a worker thread, straggler
masks are pre-sampled and decoded ``--lookahead`` rounds at a time on
that same worker thread (``coded_train.LookaheadPrefetcher``, one
chunk ahead of the device), and metrics stay on device (alpha-bar
included) until a ``--log-every`` boundary -- the host never blocks on
the device inside the steady-state loop. A failure on the worker
thread is never swallowed: the pending future re-raises on the main
loop, queued work is cancelled, and the driver exits with the original
traceback (tests/test_smoke_train.py injects one via
``REPRO_FAIL_BATCH_AT``).

Execution path: ``--dedup`` (default) runs every unique block once,
weighted by v = A @ w (~1x uncoded FLOPs); ``--no-dedup`` materialises
the replicated (m, load, ...) machine batch, the faithful simulation of
a real straggling cluster; ``--collective manual`` additionally routes
the combine through the explicit ``coded_allreduce`` shard_map psum
(replicated path only), and ``--stream-chunk N`` swaps its
materialised combine for the ``lax.scan`` streaming accumulator that
keeps only one machine chunk of gradients live per worker shard.
``--compress sign|sign_packed|int8`` composes the coding layer with
gradient compression: per-worker quantization with error feedback, the
fused quantized (or packed-sign) combine, comm-bytes-per-step in the
on-device metrics, and the residual state checkpointed alongside
opt_state so resumes stay bit-identical. ``--fsdp`` shards params and
Adam moments over the worker axes (``rules.fsdp_specs``) instead of
replicating them.

``--chaos <spec>`` flips the straggler masks from *sampled* to
*observed*: a seeded ``dist.chaos.ChaosInjector`` simulates per-step
per-machine completion timestamps (kills, delays, rack failures,
flapping -- see ``dist/chaos.py`` for the spec grammar), a
``dist.failures.HeartbeatMonitor`` derives each round's alive mask by
deadline, and ``dead_after`` consecutive missed heartbeats trigger an
elastic re-assignment: the expander is re-drawn over the m-1 survivors
(``coded_train.elastic_reassign``), block shards remap through the
sharding rules' divisibility fallback, and training resumes from the
live {params, opt_state} without a restart. Every detection and
re-assignment lands in the structured failure-event log (summary
``chaos`` key; ``--event-log FILE`` writes it as a JSON artifact).

  python -m repro.launch.train --arch qwen1.5-4b --steps 20 \
      --straggler-p 0.2 --scheme expander --decoding optimal
"""

import argparse
import json
import time
from concurrent.futures import ThreadPoolExecutor

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.configs import CodingConfig, get_config
from repro.core import compress as compress_mod
from repro.core import step_weights as sw
from repro.data.pipeline import CodedBatcher, SyntheticLM
from repro.dist import chaos as chaos_mod
from repro.dist import coded_train, failures, sharding as rules
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.models import model as M
from repro.optim import optimizers as opt_mod


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--scheme", default="expander",
                    choices=("expander", "frc", "uncoded", "cyclic_mds",
                             "bibd", "random_regular"))
    ap.add_argument("--decoding", default="optimal",
                    choices=("optimal", "fixed"))
    ap.add_argument("--adaptive", default="none",
                    choices=("none", "adaptive", "always_optimal",
                             "always_fixed"),
                    help="per-step decoding policy (core.adaptive): "
                         "estimate p-hat online from the observed mask "
                         "stream and switch optimal-vs-fixed decoding "
                         "per step ('adaptive'); the always_* anchors "
                         "pin the static behaviours ('none': the "
                         "configured --decoding, no estimator)")
    ap.add_argument("--straggler-model", default="bernoulli",
                    choices=("bernoulli", "markov", "adversarial"))
    ap.add_argument("--straggler-p", type=float, default=0.2)
    ap.add_argument("--replication", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--block-size", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--dedup", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="run each unique block once, weighted by "
                         "v = A @ w; on by default under --collective "
                         "gspmd (--no-dedup: replicate blocks onto "
                         "machines as a real cluster would)")
    ap.add_argument("--collective", default="gspmd",
                    choices=("gspmd", "manual"),
                    help="gradient combine: GSPMD-inserted psum vs the "
                         "explicit coded_allreduce shard_map (manual "
                         "implies the replicated path)")
    ap.add_argument("--compress", default="none",
                    choices=("none", "sign", "sign_packed", "int8"),
                    help="quantize per-worker gradients before the "
                         "coded combine (error feedback on; the fused "
                         "quantized_combine / packed_sign_combine "
                         "kernel consumes the payload directly)")
    ap.add_argument("--stream-chunk", type=int, default=0,
                    help="stream the manual-collective combine over "
                         "machine chunks of this size per worker shard "
                         "(0: materialise all per-machine gradients; "
                         "requires --collective manual)")
    ap.add_argument("--fsdp", action="store_true",
                    help="shard params and Adam moments over the "
                         "worker axes (rules.fsdp_specs) instead of "
                         "replicating them")
    ap.add_argument("--lookahead", type=int, default=8,
                    help="straggler rounds pre-sampled and decoded per "
                         "batched decode_batch call (ignored under "
                         "--chaos: observed masks decode per step)")
    ap.add_argument("--log-every", type=int, default=0,
                    help="steps between host metric fetches "
                         "(0: steps // 10)")
    ap.add_argument("--full-config", action="store_true",
                    help="use the full architecture (TPU pods)")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="save a full {params, opt_state} checkpoint "
                         "(plus the error-feedback residual under "
                         "--compress) every N steps (0: only at the "
                         "end); a later "
                         "run with the same flags and --ckpt-dir "
                         "resumes from the latest step bit-identically")
    ap.add_argument("--chaos", default=None, metavar="SPEC",
                    help="inject seeded virtual failures and derive "
                         "straggler masks from heartbeats instead of "
                         "sampling them; SPEC is semicolon-separated "
                         "kill:J@S / rack:J,K@S / delay:J@S-E[:X] / "
                         "flap:J@S-E[:K] events (dist/chaos.py); a "
                         "machine declared dead triggers elastic "
                         "re-assignment over the survivors")
    ap.add_argument("--dead-after", type=int, default=3,
                    help="consecutive missed heartbeats before a "
                         "machine is declared dead (chaos mode)")
    ap.add_argument("--heartbeat-deadline", type=float, default=0.5,
                    help="base per-step completion deadline in virtual "
                         "seconds (chaos mode; exponential backoff "
                         "widens it per consecutive miss)")
    ap.add_argument("--event-log", default=None, metavar="FILE",
                    help="write the structured failure-event log (the "
                         "summary's chaos object) to FILE as JSON")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.collective == "manual" and args.microbatches != 1:
        ap.error("--microbatches is only supported with "
                 "--collective gspmd")
    if args.collective == "manual" and args.dedup:
        # The manual collective reduces the per-machine gradients the
        # replicated batch produces; dedup has no machine axis.
        ap.error("--dedup is only supported with --collective gspmd")
    if args.compress != "none" and args.microbatches != 1:
        # The error-feedback residual updates once per compression
        # round, i.e. per full-batch step.
        ap.error("--compress does not compose with --microbatches")
    if args.stream_chunk and args.collective != "manual":
        ap.error("--stream-chunk requires --collective manual (the "
                 "streaming accumulator replaces the materialised "
                 "manual combine)")
    if args.chaos:
        if args.collective != "gspmd" or args.dedup is False:
            # Elastic re-assignment changes the machine count; only
            # the dedup path's block axis has the divisibility-fallback
            # shardings that absorb the new geometry.
            ap.error("--chaos requires the default gspmd dedup path")
        if args.ckpt_dir:
            ap.error("--chaos does not compose with --ckpt-dir: a "
                     "checkpoint records no failure history, so a "
                     "resumed chaos run could not replay the observed "
                     "masks bit-identically")
    elif args.event_log:
        ap.error("--event-log only applies under --chaos")

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = cfg.smoke_variant()

    if args.production_mesh:
        mesh = make_production_mesh()
    else:
        n_dev = len(jax.devices())
        model_par = 2 if n_dev % 2 == 0 and n_dev > 1 else 1
        mesh = make_test_mesh((n_dev // model_par, model_par))

    dedup = args.collective == "gspmd" and args.dedup is not False

    m_workers = mesh.shape["data"] * mesh.shape.get("pod", 1)
    coding = CodingConfig(
        scheme=args.scheme, replication=args.replication,
        decoding=args.decoding, straggler_model=args.straggler_model,
        straggler_p=args.straggler_p, seed=args.seed)
    # Chaos mode swaps the runtime's mask source from sampled to
    # observed: masks are pushed per step from the heartbeat monitor
    # instead of drawn from the straggler model.
    injector = monitor = surv = None
    adaptive = None if args.adaptive == "none" else args.adaptive
    if args.chaos:
        schedule = chaos_mod.parse_chaos_spec(args.chaos, m_workers)
        injector = chaos_mod.ChaosInjector(schedule, m_workers,
                                           seed=args.seed)
        monitor = failures.HeartbeatMonitor(
            m_workers, deadline=args.heartbeat_deadline,
            dead_after=args.dead_after)
        surv = failures.SurvivorMap(m_workers)
        runtime = coded_train.CodingRuntime(
            coding, m_workers,
            mask_source=sw.ObservedMaskSource(m_workers),
            adaptive=adaptive)
    else:
        runtime = coded_train.CodingRuntime(coding, m_workers,
                                            adaptive=adaptive)
    lookahead = max(1, args.lookahead)
    log_every = args.log_every or max(1, args.steps // 10)

    source = SyntheticLM(cfg.vocab_size, args.seq_len, seed=args.seed)

    key = jax.random.PRNGKey(args.seed)
    params = M.init_params(cfg, key)
    optimizer = opt_mod.get_optimizer("adamw", args.lr)
    opt_state = optimizer.init(params)
    # Compression layer: per-row (machine, or unique block on the
    # dedup path) error-feedback residuals ride alongside opt_state,
    # and the comm-bytes accounting compares the codec's wire payload
    # against the float32 baseline the uncompressed combine ships.
    compress = None if args.compress == "none" else args.compress
    n_blocks0 = runtime.assignment.n
    comp_rows = n_blocks0 if dedup else m_workers
    comp_state = (compress_mod.init_state(params, comp_rows)
                  if compress else None)
    codec = compress_mod.get_codec(compress) if compress else None
    comm_bytes = compress_mod.comm_bytes_per_step(codec, comp_rows,
                                                  params)
    comm_bytes_f32 = compress_mod.comm_bytes_per_step(None, comp_rows,
                                                      params)
    # Resume: checkpoints carry the full {params, opt_state} training
    # state plus their step number. Restoring and fast-forwarding the
    # host-side streams (data batches are a pure function of the step;
    # runtime.skip replays the straggler RNG) makes the resumed
    # loss/metric stream bit-identical to an uninterrupted run --
    # pinned by tests/test_checkpoint_resume.py.
    start = 0
    if args.ckpt_dir:
        # Resume from the newest checkpoint at or before --steps (a
        # later-step checkpoint must not masquerade as an earlier one).
        usable = [s for s in ckpt.saved_steps(args.ckpt_dir)
                  if s <= args.steps]
        if usable:
            # Ordered templates, newest layout first: compressed runs
            # save {params, opt_state, compress}; uncompressed the
            # composite pair; the original PR saved params only. A
            # mismatched template fails restore's validation and the
            # next is tried; a torn file (crash mid-write, truncated
            # copy) fails np.load and restore_fallback walks back to
            # the previous intact step instead of wedging the resume.
            templates = []
            if compress:
                templates.append(("compressed",
                                  {"params": params,
                                   "opt_state": opt_state,
                                   "compress": comp_state}))
            templates += [("composite", {"params": params,
                                         "opt_state": opt_state}),
                          ("params", params)]
            step0, label, state = ckpt.restore_fallback(
                args.ckpt_dir, templates, max_step=args.steps)
            if step0 != usable[-1]:
                print(f"checkpoint(s) past step {step0} in "
                      f"{args.ckpt_dir} are unreadable; fell back to "
                      f"the newest intact step")
            if label == "params":
                # Pre-composite (params-only) checkpoint layout: keep
                # the historical behavior -- warm-start the params and
                # train from step 0.
                params = state
                print(f"restored params-only checkpoint from "
                      f"{args.ckpt_dir}; training from step 0")
            else:
                params = state["params"]
                opt_state = state["opt_state"]
                if label == "compressed":
                    comp_state = state["compress"]
                elif compress:
                    # Composite checkpoint from an uncompressed run:
                    # resume training state, start compression with a
                    # fresh (zero) residual.
                    print("checkpoint has no compression state; "
                          "resuming with zero error-feedback residual")
                start = step0
                runtime.skip(start)
                print(f"restored step-{step0} {label} checkpoint from "
                      f"{args.ckpt_dir}")
        elif ckpt.saved_steps(args.ckpt_dir):
            raise SystemExit(
                f"--ckpt-dir {args.ckpt_dir} only has checkpoints past "
                f"--steps {args.steps}; refusing to relabel a "
                "later-step state")

    da = rules.data_axes(mesh)
    da1 = da if len(da) > 1 else da[0]
    M.set_residual_sharding(batch_axes=da1, model_axis="model")
    pspec = (rules.fsdp_specs if args.fsdp
             else rules.safe_param_specs)(params, mesh)
    pshard = rules.named(mesh, pspec)
    repl = rules.replicated(mesh)
    oshard = {"step": repl, "m": pshard, "v": pshard}

    # Fault-injection hook for the pipeline-hardening regression test:
    # the batch builder raises at this step (on the worker thread when
    # it is the double-buffered step), and the driver must die with
    # that traceback instead of training on or hanging.
    fail_at = int(os.environ.get("REPRO_FAIL_BATCH_AT", "-1"))

    pool = ThreadPoolExecutor(max_workers=1)
    with mesh:
        params = jax.device_put(params, pshard)
        opt_state = jax.device_put(opt_state, oshard)

        losses = []
        metrics_hist = []          # device scalars, flushed at logs
        all_events = []            # chaos: serialized FailureEvents
        reassignments = []         # chaos: elastic re-draw records
        generation = 0
        step = start
        rebuild_started = None
        t0 = time.time()

        def flush_metrics():
            # One bulk fetch of the buffered per-step scalars. The raw
            # coded loss is scaled by each step's straggler draw
            # (sum_i alpha_i varies); report the debiased estimate
            # loss / alpha_bar so steps are comparable across draws.
            for h in jax.device_get(metrics_hist):
                losses.append(float(h["loss"])
                              / max(float(h["alpha_bar"]), 1e-3))
            metrics_hist.clear()

        def save_ckpt(step: int):
            # A sync point by design (device_get), only hit at
            # checkpoint boundaries.
            state = {"params": jax.device_get(params),
                     "opt_state": jax.device_get(opt_state)}
            if compress:
                # Error-feedback residual rides along so a resumed
                # compressed run replays bit-identically.
                state["compress"] = jax.device_get(comp_state)
            ckpt.save(args.ckpt_dir, state, step=step)
            print(f"saved step-{step} checkpoint to {args.ckpt_dir}")

        try:
            # Generation loop: one iteration per coding geometry. The
            # per-generation machinery (batcher, shardings, jitted
            # step) is rebuilt whenever an elastic re-assignment
            # changes the assignment; without --chaos there is exactly
            # one generation and this reduces to the classic
            # build-once-then-loop driver.
            while step < args.steps:
                assignment = runtime.assignment
                n_blocks = assignment.n
                global_batch = n_blocks * args.block_size
                batcher = CodedBatcher(assignment,
                                       shuffle_seed=args.seed)
                emit = (batcher.unique_blocks if dedup
                        else batcher.code_batch)

                def host_batch(s, _emit=emit, _gb=global_batch):
                    if s == fail_at:
                        raise RuntimeError(
                            f"injected batch failure at step {s} "
                            "(REPRO_FAIL_BATCH_AT)")
                    return _emit(source.batch(_gb, s))

                alpha_w = coded_train.alpha_bar_weights(assignment)
                if args.collective == "manual":
                    train_step = \
                        coded_train.make_manual_collective_train_step(
                            cfg, optimizer, mesh, alpha_weights=alpha_w,
                            compress=compress,
                            streaming_chunk=args.stream_chunk or None)
                else:
                    train_step = coded_train.make_train_step(
                        cfg, optimizer,
                        n_microbatches=args.microbatches,
                        dedup=dedup,
                        norm_scale=coded_train.dedup_norm_scale(
                            assignment),
                        alpha_weights=alpha_w, compress=compress)

                # Shapes are static within a generation: build
                # shardings and the jitted step once, from the first
                # batch this generation will actually consume.
                batch_np = host_batch(step)
                bshard = (rules.block_shardings if dedup
                          else rules.batch_shardings)(mesh, batch_np)
                if compress:
                    if generation > 0:
                        # The residual rows track the block axis, which
                        # the re-assignment re-drew: restart error
                        # feedback from a zero residual.
                        comp_state = compress_mod.init_state(
                            params, n_blocks if dedup else runtime.m)
                    # Replicated is fine at smoke scale, and the
                    # compressed step's signature carries the state as
                    # a donated third argument.
                    comp_state = jax.device_put(comp_state, repl)
                    step_fn = jax.jit(
                        train_step,
                        in_shardings=(pshard, oshard, repl, bshard,
                                      repl),
                        out_shardings=(pshard, oshard, repl, None),
                        donate_argnums=(0, 1, 2))
                else:
                    step_fn = jax.jit(
                        train_step,
                        in_shardings=(pshard, oshard, bshard, repl),
                        out_shardings=(pshard, oshard, None),
                        donate_argnums=(0, 1))
                if rebuild_started is not None:
                    reassignments[-1]["rebuild_s"] = round(
                        time.time() - rebuild_started, 3)
                    rebuild_started = None

                # Straggler sampling + batched decode run on the same
                # worker thread as batch building, one chunk ahead of
                # the device (bit-identical to inline calls -- see
                # LookaheadPrefetcher). Observed masks (chaos) decode
                # per step instead: the mask is not knowable ahead of
                # the heartbeats.
                lookahead_w = None
                if not args.chaos:
                    lookahead_w = coded_train.LookaheadPrefetcher(
                        runtime, pool, lookahead, args.steps - step)
                pending = None
                reassign_dead = None

                while step < args.steps:
                    if pending is not None:
                        # Re-raises any worker-thread exception here,
                        # on the main loop, with its traceback.
                        batch_np = pending.result()
                    if step + 1 < args.steps:
                        # Double buffer: the worker thread builds
                        # step+1's batch while the device runs step's
                        # compute.
                        pending = pool.submit(host_batch, step + 1)
                    batch = {k: jax.device_put(jnp.asarray(v),
                                               bshard[k])
                             for k, v in batch_np.items()}
                    if args.chaos:
                        times = injector.completion_times(step)
                        observed = monitor.observe(step, times)
                        runtime.mask_source.push(
                            surv.localize(observed))
                        w, alive = runtime.step_weights()
                    else:
                        w, alive = lookahead_w.next()
                    wv = runtime.block_weights(w) if dedup else w
                    wv = jax.device_put(jnp.asarray(wv, jnp.float32),
                                        repl)
                    if compress:
                        params, opt_state, comp_state, metrics = \
                            step_fn(params, opt_state, comp_state,
                                    batch, wv)
                    else:
                        params, opt_state, metrics = step_fn(
                            params, opt_state, batch, wv)
                    metrics_hist.append(metrics)
                    if step % log_every == 0 or \
                            step == args.steps - 1:
                        # The only host<->device syncs in the loop:
                        # one bulk fetch per log interval keeps the
                        # metrics buffer bounded by log_every on
                        # arbitrarily long runs.
                        flush_metrics()
                        print(f"step {step:4d} loss "
                              f"{losses[-1]:.4f} stragglers "
                              f"{int((~alive).sum())}/{runtime.m} "
                              f"({time.time() - t0:.1f}s)")
                    if args.ckpt_dir and args.ckpt_every and \
                            (step + 1) % args.ckpt_every == 0 and \
                            step + 1 < args.steps:
                        save_ckpt(step + 1)
                    step += 1
                    if args.chaos:
                        new_events = monitor.drain_events()
                        for ev in new_events:
                            all_events.append(ev.to_json())
                            print(f"step {ev.step}: machine "
                                  f"{ev.machine} {ev.kind} "
                                  f"{ev.detail}")
                        dead_new = [ev.machine for ev in new_events
                                    if ev.kind == "dead"]
                        if dead_new:
                            reassign_dead = dead_new
                            break

                if reassign_dead:
                    # Elastic re-assignment: re-draw the code over the
                    # survivors and rebuild the generation machinery;
                    # {params, opt_state} stay live on device. The
                    # step where death was declared already decoded
                    # around the dead machine (a miss zeroes its
                    # weight), so no step is recomputed.
                    if surv.alive_count - len(reassign_dead) < 1:
                        raise SystemExit(
                            f"step {step}: all machines dead, cannot "
                            "re-assign")
                    flush_metrics()
                    rebuild_started = time.time()
                    local = [int(np.where(surv.survivors == d)[0][0])
                             for d in reassign_dead]
                    surv.remove(reassign_dead)
                    generation += 1
                    runtime = coded_train.elastic_reassign(
                        runtime, local, generation=generation,
                        mask_source=sw.ObservedMaskSource(
                            surv.alive_count))
                    pending = None  # old-geometry batch: discard
                    info = {"step": int(step),
                            "generation": int(generation),
                            "dead": [int(d) for d in reassign_dead],
                            "survivors": surv.survivors.tolist(),
                            "m": surv.alive_count,
                            "scheme": runtime.coding.scheme,
                            "replication":
                                int(runtime.coding.replication),
                            "n_blocks": int(runtime.assignment.n),
                            "rebuild_s": None}
                    reassignments.append(info)
                    all_events.append(
                        {"step": int(step), "kind": "reassign",
                         "machine": -1,
                         "detail": {k: v for k, v in info.items()
                                    if k != "step"}})
                    print(f"step {step}: elastic re-assignment over "
                          f"m={surv.alive_count} survivors "
                          f"(generation {generation}, d="
                          f"{runtime.coding.replication})")

            flush_metrics()
            if args.ckpt_dir:
                save_ckpt(args.steps)
        finally:
            # Pipeline hardening: whatever killed the loop (injected
            # batch failure, jit error, KeyboardInterrupt), cancel the
            # queued worker tasks and join the in-flight one so the
            # driver exits promptly with the original traceback
            # instead of idling behind orphaned host work.
            pool.shutdown(wait=True, cancel_futures=True)
    # The per-step coded loss is scaled by the straggler draw (w* varies
    # step to step), so compare window means, not endpoints. A resumed
    # run only sees its own (possibly short) tail of the stream, so the
    # decrease assertion stays with uninterrupted runs.
    if losses and start == 0:
        k = max(1, len(losses) // 4)
        first, last = np.mean(losses[:k]), np.mean(losses[-k:])
        assert last < first, \
            f"loss did not decrease ({first:.3f}->{last:.3f})"
    chaos_summary = None
    if args.chaos:
        detect = monitor.steps_to_detect()
        chaos_summary = {
            "spec": args.chaos,
            "events": all_events,
            "reassignments": reassignments,
            "dead_machines": monitor.dead_machines.tolist(),
            "steps_to_detect": {str(k): int(v)
                                for k, v in detect.items()},
            "degraded_steps": int(sum(detect.values())),
            "m_final": surv.alive_count,
            "generations": generation + 1,
        }
        if args.event_log:
            with open(args.event_log, "w") as f:
                json.dump(chaos_summary, f, indent=1)
            print(f"wrote failure-event log to {args.event_log}")
    summary = {"first_loss": losses[0] if losses else None,
               "last_loss": losses[-1] if losses else None,
               "losses": losses, "start_step": start,
               "steps": args.steps, "m_workers": m_workers,
               "scheme": args.scheme, "decoding": args.decoding,
               "path": "dedup" if dedup else "replicated",
               "collective": args.collective,
               "compress": args.compress,
               "stream_chunk": args.stream_chunk,
               "fsdp": bool(args.fsdp),
               "comm_bytes_per_step": comm_bytes,
               "comm_bytes_per_step_float32": comm_bytes_f32,
               "decode_calls": runtime.decode_calls,
               "chaos": chaos_summary}
    if runtime.policy is not None:
        est = runtime.estimator.estimate()
        summary["adaptive"] = {
            "policy": args.adaptive,
            "p_hat": est.p_hat,
            "persistence_hat": est.persistence_hat,
            "decision_counts": dict(runtime.decision_counts),
        }
    print(json.dumps(summary))
    return summary


if __name__ == "__main__":
    main()
