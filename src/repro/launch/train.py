import os
if "XLA_FLAGS" not in os.environ:
    # Standalone CPU demo: 8 virtual devices -> mesh (data=4, model=2).
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""End-to-end coded LM training driver.

Runs REAL training (not a dry-run): synthetic LM corpus -> coded block
partitioner -> shard_map/pjit coded train step with host-side straggler
sampling + O(m) optimal decoding. On CPU it uses the reduced smoke
configs and a (4, 2) mesh of virtual devices; on a TPU pod the same
driver takes the full configs and the production mesh.

The loop is an async pipeline: shardings and the jitted step are built
once up front (shapes are static across steps), host batch construction
is double-buffered against device compute on a worker thread, straggler
masks are pre-sampled and decoded ``--lookahead`` rounds at a time on
that same worker thread (``coded_train.LookaheadPrefetcher``, one chunk
ahead of the device), and metrics stay
on device (alpha-bar included) until a ``--log-every`` boundary -- the
host never blocks on the device inside the steady-state loop.

Execution path: ``--dedup`` (default) runs every unique block once,
weighted by v = A @ w (~1x uncoded FLOPs); ``--no-dedup`` materialises
the replicated (m, load, ...) machine batch, the faithful simulation of
a real straggling cluster; ``--collective manual`` additionally routes
the combine through the explicit ``coded_allreduce`` shard_map psum
(replicated path only), and ``--stream-chunk N`` swaps its
materialised combine for the ``lax.scan`` streaming accumulator that
keeps only one machine chunk of gradients live per worker shard.
``--compress sign|sign_packed|int8`` composes the coding layer with
gradient compression: per-worker quantization with error feedback, the
fused quantized (or packed-sign) combine, comm-bytes-per-step in the
on-device metrics, and the residual state checkpointed alongside
opt_state so resumes stay bit-identical. ``--fsdp`` shards params and
Adam moments over the worker axes (``rules.fsdp_specs``) instead of
replicating them.

  python -m repro.launch.train --arch qwen1.5-4b --steps 20 \
      --straggler-p 0.2 --scheme expander --decoding optimal
"""

import argparse
import json
import time
from concurrent.futures import ThreadPoolExecutor

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.configs import CodingConfig, get_config
from repro.core import compress as compress_mod
from repro.data.pipeline import CodedBatcher, SyntheticLM
from repro.dist import coded_train, sharding as rules
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.models import model as M
from repro.optim import optimizers as opt_mod


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--scheme", default="expander",
                    choices=("expander", "frc", "uncoded"))
    ap.add_argument("--decoding", default="optimal",
                    choices=("optimal", "fixed"))
    ap.add_argument("--straggler-model", default="bernoulli",
                    choices=("bernoulli", "markov", "adversarial"))
    ap.add_argument("--straggler-p", type=float, default=0.2)
    ap.add_argument("--replication", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--block-size", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--dedup", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="run each unique block once, weighted by "
                         "v = A @ w; on by default under --collective "
                         "gspmd (--no-dedup: replicate blocks onto "
                         "machines as a real cluster would)")
    ap.add_argument("--collective", default="gspmd",
                    choices=("gspmd", "manual"),
                    help="gradient combine: GSPMD-inserted psum vs the "
                         "explicit coded_allreduce shard_map (manual "
                         "implies the replicated path)")
    ap.add_argument("--compress", default="none",
                    choices=("none", "sign", "sign_packed", "int8"),
                    help="quantize per-worker gradients before the "
                         "coded combine (error feedback on; the fused "
                         "quantized_combine / packed_sign_combine "
                         "kernel consumes the payload directly)")
    ap.add_argument("--stream-chunk", type=int, default=0,
                    help="stream the manual-collective combine over "
                         "machine chunks of this size per worker shard "
                         "(0: materialise all per-machine gradients; "
                         "requires --collective manual)")
    ap.add_argument("--fsdp", action="store_true",
                    help="shard params and Adam moments over the "
                         "worker axes (rules.fsdp_specs) instead of "
                         "replicating them")
    ap.add_argument("--lookahead", type=int, default=8,
                    help="straggler rounds pre-sampled and decoded per "
                         "batched decode_batch call")
    ap.add_argument("--log-every", type=int, default=0,
                    help="steps between host metric fetches "
                         "(0: steps // 10)")
    ap.add_argument("--full-config", action="store_true",
                    help="use the full architecture (TPU pods)")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="save a full {params, opt_state} checkpoint "
                         "(plus the error-feedback residual under "
                         "--compress) every N steps (0: only at the "
                         "end); a later "
                         "run with the same flags and --ckpt-dir "
                         "resumes from the latest step bit-identically")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.collective == "manual" and args.microbatches != 1:
        ap.error("--microbatches is only supported with "
                 "--collective gspmd")
    if args.collective == "manual" and args.dedup:
        # The manual collective reduces the per-machine gradients the
        # replicated batch produces; dedup has no machine axis.
        ap.error("--dedup is only supported with --collective gspmd")
    if args.compress != "none" and args.microbatches != 1:
        # The error-feedback residual updates once per compression
        # round, i.e. per full-batch step.
        ap.error("--compress does not compose with --microbatches")
    if args.stream_chunk and args.collective != "manual":
        ap.error("--stream-chunk requires --collective manual (the "
                 "streaming accumulator replaces the materialised "
                 "manual combine)")

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = cfg.smoke_variant()

    if args.production_mesh:
        mesh = make_production_mesh()
    else:
        n_dev = len(jax.devices())
        model_par = 2 if n_dev % 2 == 0 and n_dev > 1 else 1
        mesh = make_test_mesh((n_dev // model_par, model_par))

    dedup = args.collective == "gspmd" and args.dedup is not False

    m_workers = mesh.shape["data"] * mesh.shape.get("pod", 1)
    coding = CodingConfig(
        scheme=args.scheme, replication=args.replication,
        decoding=args.decoding, straggler_model=args.straggler_model,
        straggler_p=args.straggler_p, seed=args.seed)
    runtime = coded_train.CodingRuntime(coding, m_workers)
    assignment = runtime.assignment
    n_blocks = assignment.n
    global_batch = n_blocks * args.block_size
    lookahead = max(1, args.lookahead)
    log_every = args.log_every or max(1, args.steps // 10)

    source = SyntheticLM(cfg.vocab_size, args.seq_len, seed=args.seed)
    batcher = CodedBatcher(assignment, shuffle_seed=args.seed)
    emit = batcher.unique_blocks if dedup else batcher.code_batch

    def host_batch(step: int):
        return emit(source.batch(global_batch, step))

    key = jax.random.PRNGKey(args.seed)
    params = M.init_params(cfg, key)
    optimizer = opt_mod.get_optimizer("adamw", args.lr)
    opt_state = optimizer.init(params)
    # Compression layer: per-row (machine, or unique block on the
    # dedup path) error-feedback residuals ride alongside opt_state,
    # and the comm-bytes accounting compares the codec's wire payload
    # against the float32 baseline the uncompressed combine ships.
    compress = None if args.compress == "none" else args.compress
    comp_rows = n_blocks if dedup else m_workers
    comp_state = (compress_mod.init_state(params, comp_rows)
                  if compress else None)
    codec = compress_mod.get_codec(compress) if compress else None
    comm_bytes = compress_mod.comm_bytes_per_step(codec, comp_rows,
                                                  params)
    comm_bytes_f32 = compress_mod.comm_bytes_per_step(None, comp_rows,
                                                      params)
    # Resume: checkpoints carry the full {params, opt_state} training
    # state plus their step number. Restoring and fast-forwarding the
    # host-side streams (data batches are a pure function of the step;
    # runtime.skip replays the straggler RNG) makes the resumed
    # loss/metric stream bit-identical to an uninterrupted run --
    # pinned by tests/test_checkpoint_resume.py.
    start = 0
    if args.ckpt_dir:
        # Resume from the newest checkpoint at or before --steps (a
        # later-step checkpoint must not masquerade as an earlier one).
        usable = [s for s in ckpt.saved_steps(args.ckpt_dir)
                  if s <= args.steps]
        if usable:
            step0 = usable[-1]
            # Ordered templates, newest layout first: compressed runs
            # save {params, opt_state, compress}; uncompressed the
            # composite pair; the original PR saved params only. A
            # mismatched template fails restore's validation and the
            # next is tried (ckpt.restore_any).
            templates = []
            if compress:
                templates.append(("compressed",
                                  {"params": params,
                                   "opt_state": opt_state,
                                   "compress": comp_state}))
            templates += [("composite", {"params": params,
                                         "opt_state": opt_state}),
                          ("params", params)]
            label, state = ckpt.restore_any(args.ckpt_dir, templates,
                                            step=step0)
            if label == "params":
                # Pre-composite (params-only) checkpoint layout: keep
                # the historical behavior -- warm-start the params and
                # train from step 0.
                params = state
                print(f"restored params-only checkpoint from "
                      f"{args.ckpt_dir}; training from step 0")
            else:
                params = state["params"]
                opt_state = state["opt_state"]
                if label == "compressed":
                    comp_state = state["compress"]
                elif compress:
                    # Composite checkpoint from an uncompressed run:
                    # resume training state, start compression with a
                    # fresh (zero) residual.
                    print("checkpoint has no compression state; "
                          "resuming with zero error-feedback residual")
                start = step0
                runtime.skip(start)
                print(f"restored step-{step0} {label} checkpoint from "
                      f"{args.ckpt_dir}")
        elif ckpt.saved_steps(args.ckpt_dir):
            raise SystemExit(
                f"--ckpt-dir {args.ckpt_dir} only has checkpoints past "
                f"--steps {args.steps}; refusing to relabel a "
                "later-step state")

    da = rules.data_axes(mesh)
    da1 = da if len(da) > 1 else da[0]
    M.set_residual_sharding(batch_axes=da1, model_axis="model")
    pspec = (rules.fsdp_specs if args.fsdp
             else rules.safe_param_specs)(params, mesh)
    pshard = rules.named(mesh, pspec)
    repl = rules.replicated(mesh)
    oshard = {"step": repl, "m": pshard, "v": pshard}

    alpha_w = coded_train.alpha_bar_weights(assignment)
    if args.collective == "manual":
        train_step = coded_train.make_manual_collective_train_step(
            cfg, optimizer, mesh, alpha_weights=alpha_w,
            compress=compress,
            streaming_chunk=args.stream_chunk or None)
    else:
        train_step = coded_train.make_train_step(
            cfg, optimizer, n_microbatches=args.microbatches,
            dedup=dedup,
            norm_scale=coded_train.dedup_norm_scale(assignment),
            alpha_weights=alpha_w, compress=compress)

    with mesh, ThreadPoolExecutor(max_workers=1) as pool:
        params = jax.device_put(params, pshard)
        opt_state = jax.device_put(opt_state, oshard)
        # Shapes are static across steps: build shardings and the
        # jitted step once, from the first batch this run will
        # actually consume (step `start` when resuming).
        batch_np = host_batch(start)
        bshard = (rules.block_shardings if dedup
                  else rules.batch_shardings)(mesh, batch_np)
        if compress:
            # The residual rows follow the gradient rows: replicated
            # is fine at smoke scale, and the compressed step's
            # signature carries the state as a donated third argument.
            comp_state = jax.device_put(comp_state, repl)
            step_fn = jax.jit(
                train_step,
                in_shardings=(pshard, oshard, repl, bshard, repl),
                out_shardings=(pshard, oshard, repl, None),
                donate_argnums=(0, 1, 2))
        else:
            step_fn = jax.jit(
                train_step,
                in_shardings=(pshard, oshard, bshard, repl),
                out_shardings=(pshard, oshard, None),
                donate_argnums=(0, 1))

        losses = []
        metrics_hist = []          # device scalars, flushed at logs
        # Straggler sampling + batched decode run on the same worker
        # thread as batch building, one chunk ahead of the device
        # (bit-identical to the old inline calls -- see
        # LookaheadPrefetcher).
        lookahead_w = coded_train.LookaheadPrefetcher(
            runtime, pool, lookahead, args.steps - start)
        pending = None
        t0 = time.time()

        def flush_metrics():
            # One bulk fetch of the buffered per-step scalars. The raw
            # coded loss is scaled by each step's straggler draw
            # (sum_i alpha_i varies); report the debiased estimate
            # loss / alpha_bar so steps are comparable across draws.
            for h in jax.device_get(metrics_hist):
                losses.append(float(h["loss"])
                              / max(float(h["alpha_bar"]), 1e-3))
            metrics_hist.clear()

        def save_ckpt(step: int):
            # A sync point by design (device_get), only hit at
            # checkpoint boundaries.
            state = {"params": jax.device_get(params),
                     "opt_state": jax.device_get(opt_state)}
            if compress:
                # Error-feedback residual rides along so a resumed
                # compressed run replays bit-identically.
                state["compress"] = jax.device_get(comp_state)
            ckpt.save(args.ckpt_dir, state, step=step)
            print(f"saved step-{step} checkpoint to {args.ckpt_dir}")

        for step in range(start, args.steps):
            if pending is not None:
                batch_np = pending.result()
            if step + 1 < args.steps:
                # Double buffer: the worker thread builds step+1's
                # batch while the device runs step's compute.
                pending = pool.submit(host_batch, step + 1)
            batch = {k: jax.device_put(jnp.asarray(v), bshard[k])
                     for k, v in batch_np.items()}
            w, alive = lookahead_w.next()
            wv = runtime.block_weights(w) if dedup else w
            wv = jax.device_put(jnp.asarray(wv, jnp.float32), repl)
            if compress:
                params, opt_state, comp_state, metrics = step_fn(
                    params, opt_state, comp_state, batch, wv)
            else:
                params, opt_state, metrics = step_fn(params, opt_state,
                                                     batch, wv)
            metrics_hist.append(metrics)
            if step % log_every == 0 or step == args.steps - 1:
                # The only host<->device syncs in the loop: one bulk
                # fetch per log interval keeps the metrics buffer
                # bounded by log_every on arbitrarily long runs.
                flush_metrics()
                print(f"step {step:4d} loss {losses[-1]:.4f} "
                      f"stragglers {int((~alive).sum())}/{m_workers} "
                      f"({time.time() - t0:.1f}s)")
            if args.ckpt_dir and args.ckpt_every and \
                    (step + 1) % args.ckpt_every == 0 and \
                    step + 1 < args.steps:
                save_ckpt(step + 1)
        flush_metrics()
        if args.ckpt_dir:
            save_ckpt(args.steps)
    # The per-step coded loss is scaled by the straggler draw (w* varies
    # step to step), so compare window means, not endpoints. A resumed
    # run only sees its own (possibly short) tail of the stream, so the
    # decrease assertion stays with uninterrupted runs.
    if losses and start == 0:
        k = max(1, len(losses) // 4)
        first, last = np.mean(losses[:k]), np.mean(losses[-k:])
        assert last < first, \
            f"loss did not decrease ({first:.3f}->{last:.3f})"
    print(json.dumps({"first_loss": losses[0] if losses else None,
                      "last_loss": losses[-1] if losses else None,
                      "losses": losses, "start_step": start,
                      "steps": args.steps, "m_workers": m_workers,
                      "scheme": args.scheme, "decoding": args.decoding,
                      "path": "dedup" if dedup else "replicated",
                      "collective": args.collective,
                      "compress": args.compress,
                      "stream_chunk": args.stream_chunk,
                      "fsdp": bool(args.fsdp),
                      "comm_bytes_per_step": comm_bytes,
                      "comm_bytes_per_step_float32": comm_bytes_f32,
                      "decode_calls": runtime.decode_calls}))
    return {"losses": losses}


if __name__ == "__main__":
    main()
