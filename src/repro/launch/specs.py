"""ShapeDtypeStruct input specs for every (architecture x input shape),
plus their shardings -- the dry-run's contract. No device allocation
happens here (the shannon/kernels pattern: weak-type-correct stand-ins).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import (ModelConfig, ShapeSpec, CodingConfig,
                                TRAIN_4K, PREFILL_32K, DECODE_32K,
                                LONG_500K)
from repro.dist import sharding as rules
from repro.models import model as M
from repro.optim import optimizers as opt_mod
from .mesh import num_coded_workers

LONG_WINDOW = 8192  # sliding window used for long_500k serving


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape),
                                jnp.dtype(dtype))


def long_500k_supported(cfg: ModelConfig) -> Tuple[bool, str]:
    """Which archs run the 500k decode, and why/why not (DESIGN.md
    #Arch-applicability)."""
    if cfg.arch_type == "audio":
        return False, ("enc-dec with a bounded source window does not "
                       "define a 500k-token decoder cache; skipped")
    if cfg.arch_type in ("ssm", "hybrid"):
        return True, "O(1)-state recurrence"
    return True, f"sliding-window attention (window={LONG_WINDOW})"


def decode_supported(cfg: ModelConfig) -> bool:
    return True  # all assigned archs are decoders or enc-dec


@dataclasses.dataclass
class StepSpec:
    """Everything the dry-run needs for one (arch, shape, mesh)."""

    kind: str                       # train | prefill | decode
    args: tuple                     # ShapeDtypeStructs
    in_shardings: tuple
    out_shardings: object           # None = let GSPMD choose
    window: Optional[int] = None
    donate: tuple = ()


def _coded_geometry(mesh: Mesh, coding: CodingConfig,
                    global_batch: int) -> Tuple[int, int, int]:
    m = num_coded_workers(mesh)
    d = coding.replication
    n_blocks = 2 * m // d
    if global_batch % n_blocks:
        raise ValueError(f"global batch {global_batch} % n_blocks "
                         f"{n_blocks} != 0")
    return m, n_blocks, global_batch // n_blocks


def train_batch_specs(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                      coding: CodingConfig):
    """(batch sds dict, batch sharding dict) for the coded train step."""
    m, n_blocks, bs = _coded_geometry(mesh, coding, shape.global_batch)
    load = 2  # graph schemes: two blocks per machine
    S = shape.seq_len
    P_len = cfg.prefix_len
    S_text = S - P_len if cfg.arch_type in ("vlm", "audio") else S
    da = rules.data_axes(mesh)
    da = da if len(da) > 1 else da[0]

    def bspec(ndim):
        return NamedSharding(mesh, P(*([da] + [None] * (ndim - 1))))

    batch = {
        "tokens": sds((m, load, bs, S_text), jnp.int32),
        "labels": sds((m, load, bs, S_text), jnp.int32),
        "block_weight": sds((m, load), jnp.float32),
    }
    shardings = {
        "tokens": bspec(4),
        "labels": bspec(4),
        "block_weight": bspec(2),
    }
    if cfg.arch_type == "vlm":
        batch["prefix"] = sds((m, load, bs, P_len, cfg.d_model),
                              jnp.dtype(cfg.dtype))
        shardings["prefix"] = bspec(5)
    if cfg.arch_type == "audio":
        batch["src"] = sds((m, load, bs, P_len, cfg.d_model),
                           jnp.dtype(cfg.dtype))
        shardings["src"] = bspec(5)
    return batch, shardings


def make_step_spec(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                   coding: Optional[CodingConfig] = None,
                   optimizer_name: str = "adamw",
                   fsdp: bool = False) -> StepSpec:
    """Build the StepSpec for one (arch, shape) on a mesh.

    ``fsdp=True`` swaps the replicated-over-workers param placement for
    ``rules.fsdp_specs``: params and the Adam moments additionally
    shard one dim over the (pod, data) worker axes, which is what lets
    the 33B+ configs fit per-device HBM (the dry-run records both
    placements' ``param_bytes_per_device``).
    """
    coding = coding or CodingConfig()
    params_shapes = jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    param_spec = (rules.fsdp_specs if fsdp
                  else rules.safe_param_specs)(params_shapes, mesh)
    param_shard = rules.named(mesh, param_spec)
    da = rules.data_axes(mesh)
    da1 = da if len(da) > 1 else da[0]

    if shape.kind == "train":
        optimizer = opt_mod.get_optimizer(optimizer_name, 1e-4)
        opt_shapes = jax.eval_shape(optimizer.init, params_shapes)
        # Adam moments share the param sharding; step counter replicates.
        if optimizer_name == "adamw":
            opt_shard = {"step": NamedSharding(mesh, P()),
                         "m": param_shard, "v": param_shard}
        else:
            opt_shard = jax.tree.map(
                lambda _: NamedSharding(mesh, P()), opt_shapes)
        batch, batch_shard = train_batch_specs(cfg, shape, mesh, coding)
        mworkers = num_coded_workers(mesh)
        wstar = sds((mworkers,), jnp.float32)
        return StepSpec(
            kind="train",
            args=(params_shapes, opt_shapes, batch, wstar),
            in_shardings=(param_shard, opt_shard, batch_shard,
                          NamedSharding(mesh, P())),
            out_shardings=(param_shard, opt_shard, None),
        )

    if shape.kind == "prefill":
        B, S = shape.global_batch, shape.seq_len
        P_len = cfg.prefix_len
        S_text = S - P_len if cfg.arch_type in ("vlm", "audio") else S
        batch = {"tokens": sds((B, S_text), jnp.int32)}
        bshard = {"tokens": NamedSharding(mesh, P(da1, None))}
        if cfg.arch_type == "vlm":
            batch["prefix"] = sds((B, P_len, cfg.d_model),
                                  jnp.dtype(cfg.dtype))
            bshard["prefix"] = NamedSharding(mesh, P(da1, None, None))
        if cfg.arch_type == "audio":
            batch["src"] = sds((B, P_len, cfg.d_model),
                               jnp.dtype(cfg.dtype))
            bshard["src"] = NamedSharding(mesh, P(da1, None, None))
        return StepSpec(kind="prefill", args=(params_shapes, batch),
                        in_shardings=(param_shard, bshard),
                        out_shardings=None)

    # decode
    B, S = shape.global_batch, shape.seq_len
    window = None
    if shape.name == "long_500k":
        ok, _why = long_500k_supported(cfg)
        if not ok:
            raise ValueError(f"{cfg.name} does not support long_500k")
        window = LONG_WINDOW
    kv_len = min(S, window or cfg.sliding_window or S)
    src_len = cfg.prefix_len if cfg.arch_type == "audio" else 0
    cache_shapes = jax.eval_shape(
        lambda: M.init_decode_cache(
            cfg.with_overrides(sliding_window=window)
            if window else cfg, B, kv_len, pos=S - 1, src_len=src_len))
    batch_repl = B < np.prod([mesh.shape[a] for a in da])
    cache_spec = rules.cache_specs(cache_shapes, mesh,
                                   batch_replicated=batch_repl)
    cache_shard = rules.named(mesh, cache_spec)
    tok = sds((B,), jnp.int32)
    tok_shard = NamedSharding(mesh, P() if batch_repl else P(da1))
    return StepSpec(kind="decode", args=(params_shapes, tok,
                                         cache_shapes),
                    in_shardings=(param_shard, tok_shard, cache_shard),
                    out_shardings=(None, cache_shard),
                    window=window)
