import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes, record memory/cost/collective analysis.

MUST be run as a script / module (``python -m repro.launch.dryrun``):
the XLA_FLAGS line above executes before any jax import, giving 512
placeholder CPU devices for the 2x16x16 production mesh.

Usage:
  python -m repro.launch.dryrun --arch qwen1.5-4b --shape train_4k
  python -m repro.launch.dryrun --all --multi-pod both --out experiments/dryrun
"""

import argparse
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import (ALL_SHAPES, ARCH_IDS, CodingConfig, get_config)
from repro.dist import coded_train
from repro.dist import sharding as rules
from repro.launch import hlo_analysis
from repro.launch import roofline as rl_mod
from repro.launch import specs as specs_mod
from repro.launch.mesh import make_production_mesh
from repro.optim import optimizers as opt_mod


def build_step(cfg, shape, mesh, coding, fsdp=False):
    from repro.models import model as M
    # Sequence/tensor-sharded residual checkpoints (see EXPERIMENTS.md
    # #Perf iteration 1); REPRO_RESIDUAL_SHARDING=0 reproduces the
    # unconstrained baseline.
    mode = os.environ.get("REPRO_RESIDUAL_SHARDING", "dmodel")
    if mode != "0":
        da = ("pod", "data") if "pod" in mesh.axis_names else "data"
        M.set_residual_sharding(batch_axes=da, model_axis="model",
                                mode=mode,
                                model_size=mesh.shape["model"])
    else:
        M.set_residual_sharding()
    spec = specs_mod.make_step_spec(cfg, shape, mesh, coding, fsdp=fsdp)
    if spec.kind == "train":
        optimizer = opt_mod.get_optimizer("adamw", 1e-4)
        # k=16 keeps every assigned config (incl. the 33B dense ones)
        # under the 16 GB v5e HBM budget; the collective term is
        # k-invariant (EXPERIMENTS.md #Perf iteration 3).
        n_micro = int(os.environ.get("REPRO_MICROBATCHES", "16"))
        fn = coded_train.make_train_step(cfg, optimizer,
                                         n_microbatches=n_micro)
    elif spec.kind == "prefill":
        fn = coded_train.make_prefill_step(cfg)
    else:
        fn = coded_train.make_serve_step(cfg, window=spec.window)
    return fn, spec


def param_bytes_per_device(spec, mesh) -> int:
    """Per-device parameter bytes of a StepSpec's placement (metadata
    only -- the FSDP-vs-replicated comparison the dry-run reports)."""
    return rules.bytes_per_device(spec.args[0], spec.in_shardings[0],
                                  mesh)


def specs_one(arch: str, shape_name: str, *, multi_pod: bool,
              fsdp: bool, verbose: bool = True) -> dict:
    """Spec-only dry-run: build the StepSpec (no lower/compile) and
    report the per-device parameter placement bytes. Cheap enough to
    run for every arch under both placements; the FSDP acceptance check
    in tests/test_system.py parses the DRYRUN_SPECS_JSON line."""
    cfg = get_config(arch)
    shape = {s.name: s for s in ALL_SHAPES}[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    coding = CodingConfig(replication=4)
    spec = specs_mod.make_step_spec(cfg, shape, mesh, coding, fsdp=fsdp)
    result = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "fsdp": fsdp, "status": "ok", "kind": spec.kind,
        "param_bytes_per_device": param_bytes_per_device(spec, mesh),
    }
    if verbose:
        print("DRYRUN_SPECS_JSON:" + json.dumps(result))
        sys.stdout.flush()
    return result


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool,
               fsdp: bool = False, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = {s.name: s for s in ALL_SHAPES}[shape_name]
    if shape.name == "long_500k":
        ok, why = specs_mod.long_500k_supported(cfg)
        if not ok:
            return {"arch": arch, "shape": shape_name,
                    "multi_pod": multi_pod, "status": "skipped",
                    "reason": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    coding = CodingConfig(replication=4)
    fn, spec = build_step(cfg, shape, mesh, coding, fsdp=fsdp)
    t0 = time.time()
    with mesh:
        jitted = jax.jit(fn, in_shardings=spec.in_shardings,
                         out_shardings=spec.out_shardings)
        lowered = jitted.lower(*spec.args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # jax < 0.5 returns [dict]
        cost = cost[0] if cost else {}
    stats = hlo_analysis.analyze(compiled.as_text())
    n_chips = mesh.devices.size
    model = rl_mod.model_flops(cfg, shape,
                               replication=coding.replication)
    rl = rl_mod.roofline_report(stats, n_chips, model)
    result = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "fsdp": fsdp,
        "status": "ok",
        "n_chips": int(n_chips),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "generated_code_bytes": int(mem.generated_code_size_in_bytes),
            "param_bytes_per_device": param_bytes_per_device(spec, mesh),
        },
        "model": model,
        "roofline": rl,
        "xla_cost_analysis_uncorrected": {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        },
    }
    if verbose:
        mb = 1024 ** 2
        print(f"[{arch} x {shape_name} x "
              f"{'2x16x16' if multi_pod else '16x16'}] "
              f"lower {t_lower:.0f}s compile {t_compile:.0f}s | "
              f"args {mem.argument_size_in_bytes/mb:.0f}MB "
              f"temp {mem.temp_size_in_bytes/mb:.0f}MB | "
              f"Tc {rl['t_compute_s']*1e3:.1f}ms Tm "
              f"{rl['t_memory_s']*1e3:.1f}ms Tx "
              f"{rl['t_collective_s']*1e3:.1f}ms -> {rl['dominant']} | "
              f"useful {rl['useful_flops_ratio']:.2f}")
        sys.stdout.flush()
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--fsdp", action="store_true",
                    help="shard params/opt-state over the worker axes "
                         "(rules.fsdp_specs) instead of replicating")
    ap.add_argument("--specs-only", action="store_true",
                    help="build StepSpecs and report per-device param "
                         "bytes without lowering/compiling")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = ARCH_IDS if args.all or args.arch is None else (args.arch,)
    shapes = [s.name for s in ALL_SHAPES] if args.all or \
        args.shape is None else [args.shape]
    pods = {"single": (False,), "multi": (True,),
            "both": (False, True)}[args.multi_pod]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                try:
                    if args.specs_only:
                        results.append(specs_one(arch, shape,
                                                 multi_pod=mp,
                                                 fsdp=args.fsdp))
                    else:
                        results.append(dryrun_one(arch, shape,
                                                  multi_pod=mp,
                                                  fsdp=args.fsdp))
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    results.append({"arch": arch, "shape": shape,
                                    "multi_pod": mp, "status": "error",
                                    "error": str(e)[:2000]})
                if args.out:
                    os.makedirs(args.out, exist_ok=True)
                    tag = f"{'multi' if mp else 'single'}"
                    fn = os.path.join(
                        args.out, f"{arch}__{shape}__{tag}.json")
                    with open(fn, "w") as f:
                        json.dump(results[-1], f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"dry-run: {n_ok} ok, {n_skip} skipped, {n_err} errors "
          f"of {len(results)}")
    if n_err:
        sys.exit(1)


if __name__ == "__main__":
    main()
