"""Roofline bookkeeping: model FLOPs (6*N*D), hardware constants, and
the three-term report assembled from the loop-corrected HLO analysis.

Hardware: TPU v5e -- 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

from typing import Dict

import jax
import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import model as M

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def param_count(cfg: ModelConfig) -> int:
    shapes = jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    return int(sum(int(np.prod(l.shape))
                   for l in jax.tree.leaves(shapes)))


def _expert_param_count(cfg: ModelConfig) -> int:
    if not cfg.n_experts:
        return 0
    per_layer = 3 * cfg.n_experts * cfg.d_model * cfg.expert_d_ff
    return cfg.n_layers * per_layer


def active_param_count(cfg: ModelConfig) -> int:
    """Params touched per token: total minus the inactive routed-expert
    fraction (MoE)."""
    total = param_count(cfg)
    if not cfg.n_experts:
        return total
    expert = _expert_param_count(cfg)
    active_frac = cfg.top_k / cfg.n_experts
    return int(total - expert * (1.0 - active_frac))


def model_flops(cfg: ModelConfig, shape: ShapeSpec, *,
                replication: float = 1.0) -> Dict[str, float]:
    """MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D (fwd-only),
    where D counts the *unreplicated* dataset tokens of the step;
    ``replicated`` additionally reports the gradient-coding d-fold work
    (the useful-work ratio shows the coding overhead explicitly)."""
    n_act = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        base = 6.0 * n_act * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        base = 2.0 * n_act * tokens
    else:  # decode: one token per request
        tokens = shape.global_batch
        base = 2.0 * n_act * tokens
    return {
        "n_params": float(param_count(cfg)),
        "n_active_params": float(n_act),
        "tokens": float(tokens),
        "model_flops": base,
        "model_flops_replicated": base * (replication
                                          if shape.kind == "train"
                                          else 1.0),
    }


def roofline_report(hlo_stats: Dict, n_chips: int,
                    model: Dict[str, float]) -> Dict[str, float]:
    """Three roofline terms. ``hlo_stats`` is per-partition (SPMD HLO is
    one partition's program), so terms are already per-chip."""
    flops = hlo_stats["flops"]
    dot_bytes = hlo_stats["dot_bytes"]
    cbytes = hlo_stats["collective_bytes"]
    t_compute = flops / PEAK_FLOPS
    t_memory = dot_bytes / HBM_BW
    t_collective = cbytes / ICI_BW
    dom = max((("compute", t_compute), ("memory", t_memory),
               ("collective", t_collective)), key=lambda kv: kv[1])
    total_hlo_flops = flops * n_chips
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "dominant": dom[0],
        "hlo_flops_per_chip": flops,
        "hlo_flops_total": total_hlo_flops,
        "dot_bytes_per_chip": dot_bytes,
        "collective_bytes_per_chip": cbytes,
        "useful_flops_ratio": (model["model_flops"] / total_hlo_flops
                               if total_hlo_flops else 0.0),
        "collectives": hlo_stats["collectives"],
    }
