import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""Batched serving driver: prefill a batch of prompts, then decode
tokens autoregressively with the per-architecture cache (KV / SSM state
/ xLSTM state). CPU demo uses smoke configs; the same driver drives the
production mesh on TPU.

  python -m repro.launch.serve --arch xlstm-1.3b --batch 4 --new-tokens 16
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model as M


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = cfg.smoke_variant()

    key = jax.random.PRNGKey(args.seed)
    params = M.init_params(cfg, key)
    B = args.batch
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, args.prompt_len)), jnp.int32)

    kw = {}
    if cfg.arch_type == "vlm":
        kw["prefix"] = jnp.asarray(
            rng.normal(size=(B, cfg.prefix_len, cfg.d_model)) * 0.02,
            jnp.dtype(cfg.dtype))
    src = None
    if cfg.arch_type == "audio":
        src = jnp.asarray(
            rng.normal(size=(B, cfg.prefix_len, cfg.d_model)) * 0.02,
            jnp.dtype(cfg.dtype))
        kw["src"] = src

    # Prefill: run the full forward; then replay the prompt through the
    # decode path to build the cache (cache-building prefill fused into
    # one pass is a serving optimisation; the decode path is the
    # correctness reference and works for every arch family).
    t0 = time.time()
    last_logits = M.prefill(params, prompts, cfg, **kw)
    print(f"prefill[{args.arch}] batch={B} len={args.prompt_len} "
          f"({time.time() - t0:.2f}s)")

    cache = M.init_decode_cache(
        cfg, B, args.max_len,
        src_len=cfg.prefix_len if cfg.arch_type == "audio" else 0)
    if cfg.arch_type == "audio":
        cache["enc"] = M.encode(params, src, cfg)

    step = jax.jit(lambda p, t, c: M.decode_step(p, t, c, cfg))
    # replay prompt tokens to populate the cache
    for i in range(args.prompt_len):
        logits, cache = step(params, prompts[:, i], cache)

    out_tokens = []
    t0 = time.time()
    tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1).astype(jnp.int32)
    for i in range(args.new_tokens):
        out_tokens.append(np.asarray(tok))
        logits, cache = step(params, tok, cache)
        tok = jnp.argmax(logits[:, :cfg.vocab_size],
                         axis=-1).astype(jnp.int32)
    dt = time.time() - t0
    gen = np.stack(out_tokens, axis=1)
    print(f"decoded {args.new_tokens} tokens x {B} reqs in {dt:.2f}s "
          f"({B * args.new_tokens / dt:.1f} tok/s)")
    print("sample:", gen[0][:12].tolist())
    assert not np.isnan(np.asarray(logits)).any()
    return {"tokens": gen}


if __name__ == "__main__":
    main()
