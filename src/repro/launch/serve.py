import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""Serving driver: the CLI over the continuous-batching coded engine.

Builds a ``repro.serve.ServeEngine`` -- admission queue, fixed-slot
cache pool, iteration-level prefill/decode interleave, and (with
``--scheme expander``) d-replicated coded prefill with optimal-decode
combine weights and a synthetic per-replica latency model -- then
drains ``--requests`` synthetic prompts through it and prints a JSON
summary line (tokens/s, synthetic TTFT p50/p99, retries).

  python -m repro.launch.serve --arch qwen1.5-4b --requests 12 \
      --scheme expander --straggler-p 0.2

``--check`` re-serves the same requests through the sequential-
batching reference loop and asserts bit-identical token streams (and,
at ``--straggler-p 0``, that the coded stream equals the uncoded
single-replica stream). The vlm/audio families need per-request
prefix/src side channels the pool does not carry; they take the
legacy static-batch path automatically.
"""

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import CodingConfig, get_config
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.models import model as M
from repro import serve as S


def _build_requests(args, cfg):
    rng = np.random.default_rng(args.seed)
    reqs = []
    for i in range(args.requests):
        # vary lengths (bounded by --prompt-spread) so the interleave
        # actually schedules prefill against decode
        plen = args.prompt_len - (i % (args.prompt_spread + 1))
        plen = max(1, plen)
        reqs.append(S.Request(
            uid=i, prompt=rng.integers(0, cfg.vocab_size, plen),
            max_new_tokens=args.max_new_tokens))
    return reqs


def _static_main(args, cfg):
    """Legacy one-shot batched path (vlm/audio: per-request prefix/src
    side channels)."""
    key = jax.random.PRNGKey(args.seed)
    params = M.init_params(cfg, key)
    B = args.slots
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, args.prompt_len)), jnp.int32)

    kw = {}
    if cfg.arch_type == "vlm":
        kw["prefix"] = jnp.asarray(
            rng.normal(size=(B, cfg.prefix_len, cfg.d_model)) * 0.02,
            jnp.dtype(cfg.dtype))
    src = None
    if cfg.arch_type == "audio":
        src = jnp.asarray(
            rng.normal(size=(B, cfg.prefix_len, cfg.d_model)) * 0.02,
            jnp.dtype(cfg.dtype))
        kw["src"] = src

    M.prefill(params, prompts, cfg, **kw)
    cache = M.init_decode_cache(
        cfg, B, args.max_len,
        src_len=cfg.prefix_len if cfg.arch_type == "audio" else 0)
    if cfg.arch_type == "audio":
        cache["enc"] = M.encode(params, src, cfg)

    step = jax.jit(lambda p, t, c: M.decode_step(p, t, c, cfg))
    logits = None
    for i in range(args.prompt_len):
        logits, cache = step(params, prompts[:, i], cache)
    out_tokens = []
    t0 = time.perf_counter()
    tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1).astype(jnp.int32)
    for _ in range(args.max_new_tokens):
        out_tokens.append(np.asarray(tok))
        logits, cache = step(params, tok, cache)
        tok = jnp.argmax(logits[:, :cfg.vocab_size],
                         axis=-1).astype(jnp.int32)
    dt = time.perf_counter() - t0
    gen = np.stack(out_tokens, axis=1)
    assert not np.isnan(np.asarray(logits)).any()
    summary = {"path": "static", "arch": args.arch,
               "requests": B, "new_tokens": int(gen.size),
               "tokens_per_s": gen.size / max(dt, 1e-9),
               "sample": gen[0][:12].tolist()}
    print(json.dumps(summary))
    return {"tokens": gen, "summary": summary}


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4,
                    help="pool width: requests decoded concurrently")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--prompt-spread", type=int, default=3,
                    help="prompt lengths vary in [len-spread, len]")
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=64,
                    help="decode-cache capacity per slot")
    ap.add_argument("--scheme", default="expander",
                    choices=("expander", "uncoded"),
                    help="expander: d-replicated coded prefill; "
                         "uncoded: single replica per shard")
    ap.add_argument("--replicas", type=int, default=8,
                    help="replica slices m for the latency model")
    ap.add_argument("--replication", type=int, default=2)
    ap.add_argument("--decoding", default="optimal",
                    choices=("optimal", "fixed"))
    ap.add_argument("--straggler-model", default="bernoulli",
                    choices=("bernoulli", "markov", "adversarial"))
    ap.add_argument("--straggler-p", type=float, default=0.1)
    ap.add_argument("--base-ms", type=float, default=2.0)
    ap.add_argument("--deadline-ms", type=float, default=6.0)
    ap.add_argument("--straggle-ms", type=float, default=60.0)
    ap.add_argument("--log-every", type=int, default=16,
                    help="iterations between host token fetches")
    ap.add_argument("--check", action="store_true",
                    help="pin the engine streams against the "
                         "sequential-batching reference loop")
    ap.add_argument("--no-mesh", action="store_true")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = cfg.smoke_variant()

    # Validate the generation budget against the cache capacity (and
    # any config max_seq_len) BEFORE touching the device -- the old
    # driver discovered overflow mid-generation.
    try:
        S.validate_budget(cfg, args.prompt_len, args.max_new_tokens,
                          args.max_len)
    except ValueError as e:
        ap.error(str(e))

    if cfg.arch_type in ("vlm", "audio"):
        return _static_main(args, cfg)

    if args.production_mesh:
        mesh = make_production_mesh()
    elif args.no_mesh or len(jax.devices()) == 1:
        mesh = None
    else:
        n_dev = len(jax.devices())
        model_par = 2 if n_dev % 2 == 0 and n_dev > 1 else 1
        mesh = make_test_mesh((n_dev // model_par, model_par))

    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    reqs = _build_requests(args, cfg)
    coding = CodingConfig(
        scheme=args.scheme, replication=args.replication,
        decoding=args.decoding, straggler_model=args.straggler_model,
        straggler_p=args.straggler_p, seed=args.seed)
    latency = S.ReplicaLatencyModel(
        m=args.replicas, base_ms=args.base_ms,
        deadline_ms=args.deadline_ms, straggle_ms=args.straggle_ms)

    engine = S.ServeEngine(
        cfg, params, n_slots=args.slots, max_len=args.max_len,
        mesh=mesh, coding=coding, m_replicas=args.replicas,
        latency=latency, log_every=args.log_every)
    for r in reqs:
        engine.submit(r)
    summary = engine.run()
    results = engine.results()

    check_passed = None
    if args.check:
        ref = S.sequential_serve(params, cfg, reqs,
                                 n_slots=args.slots,
                                 max_len=args.max_len)
        check_passed = all(np.array_equal(results[r.uid], ref[r.uid])
                           for r in reqs)
        assert check_passed, \
            "engine streams diverged from the sequential reference"

    summary.update(path="engine", arch=args.arch, scheme=args.scheme,
                   m_replicas=args.replicas,
                   replication=args.replication,
                   straggler_model=args.straggler_model,
                   straggler_p=args.straggler_p,
                   mesh=(list(mesh.shape.values())
                         if mesh is not None else None),
                   check_passed=check_passed,
                   sample=results[0][:12].tolist())
    print(json.dumps(summary))
    return {"results": results, "summary": summary}


if __name__ == "__main__":
    main()
