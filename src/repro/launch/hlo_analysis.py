"""Loop-aware HLO cost analysis.

XLA's built-in ``compiled.cost_analysis()`` counts a while-loop body
*once*, ignoring its trip count -- useless for scanned-layer /
microbatched programs (verified: a 10-iteration scan of a 512^3 matmul
reports 1x the FLOPs). This module re-derives the dominant roofline
terms from the optimized HLO text:

  * splits the module into computations and builds per-computation
    symbol tables (instruction name -> shape),
  * recovers each while loop's trip count from its
    ``backend_config={"known_trip_count":{"n":...}}`` (fallback: the
    largest integer constant in the condition computation),
  * propagates call-graph multipliers (while bodies multiply by trip
    count; fusions/calls/conditional branches by 1),
  * per computation counts: matmul FLOPs (dot ops: 2 * |out| *
    contracted extent), dot operand/result bytes (HBM-traffic proxy for
    the MXU-dominant ops), and collective bytes (output shapes of
    all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute).

Elementwise FLOPs are ignored (matmuls dominate all assigned archs) and
the byte proxy undercounts pure-VPU traffic; both caveats are recorded
in EXPERIMENTS.md. Collective bytes are exact up to trip counts.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "s4": 1, "u4": 1,
}

_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_WHILE_REFS = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CALL_REFS = re.compile(r"(?:calls=|to_apply=)%?([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                   "all-to-all", "collective-permute")


def _parse_shapes(text: str):
    """All (dtype, dims list) found in a type string."""
    out = []
    for dt, dims in _SHAPE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(x) for x in dims.split(",") if x]))
    return out


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _parse_shapes(text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str     # result type (may be a tuple)
    op: str           # opcode token
    rest: str         # remainder of the line


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    shapes: Dict[str, str]  # instr name -> result type string


_OPCODE = re.compile(r"^((?:\([^)]*\)|[\w\[\]\{\},\d]+)*?)\s*"
                     r"([a-z][\w\-]*)\(")


def split_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s:
            continue
        # Header: "%name (params...) -> type {" -- distinguished from an
        # instruction by having no '=' before the first '(' (parameter
        # lists may contain /*index=N*/ comments, so checking the whole
        # prefix fails).
        first_paren = s.find("(")
        is_header = (s.endswith("{") and "->" in s and first_paren > 0
                     and "=" not in s[:first_paren])
        if is_header:
            name = s.split()[0].lstrip("%")
            if name == "ENTRY":
                name = s.split()[1].lstrip("%")
            cur = Computation(name=name, instrs=[], shapes={})
            comps[name] = cur
            continue
        if s == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR.match(s)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # rhs = "<type> <opcode>(...)..." where type may contain parens
        om = re.search(r"([a-z][\w\-]*)\(", rhs)
        if not om:
            continue
        type_str = rhs[:om.start()].strip()
        op = om.group(1)
        cur.instrs.append(Instr(name=name, type_str=type_str, op=op,
                                rest=rhs[om.start():]))
        cur.shapes[name] = type_str
    return comps


def _entry_name(hlo: str) -> Optional[str]:
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo, re.M)
    return m.group(1) if m else None


def computation_multipliers(hlo: str):
    comps = split_computations(hlo)
    entry = _entry_name(hlo)
    if entry is None or entry not in comps:
        entry = next((n for n in comps if "main" in n),
                     list(comps)[-1] if comps else None)
    mult: Dict[str, int] = {}

    def visit(name: str, k: int):
        if name not in comps or k == 0:
            return
        mult[name] = mult.get(name, 0) + k
        for ins in comps[name].instrs:
            if ins.op == "while":
                wm = _WHILE_REFS.search(ins.rest)
                tm = _TRIP.search(ins.rest)
                trips = int(tm.group(1)) if tm else 1
                if not tm and wm and wm.group(1) in comps:
                    best = 1
                    for ci in comps[wm.group(1)].instrs:
                        for c in re.finditer(r"constant\((\d+)\)",
                                             ci.rest):
                            best = max(best, int(c.group(1)))
                    trips = best
                if wm:
                    visit(wm.group(1), k * trips)
                    visit(wm.group(2), k * trips)
                continue
            for cm in _CALL_REFS.finditer(ins.rest):
                visit(cm.group(1), k)
            bm = _BRANCHES.search(ins.rest)
            if bm:
                for b in bm.group(1).split(","):
                    visit(b.strip().lstrip("%"), k)

    if entry:
        visit(entry, 1)
    return mult, comps


_OPERANDS = re.compile(r"%([\w\.\-]+)")
_LHS_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _dot_stats(ins: Instr, shapes: Dict[str, str]):
    """(flops, bytes) for a dot instruction."""
    out_shapes = _parse_shapes(ins.type_str)
    out_elems = 0
    out_bytes = 0
    for dt, dims in out_shapes:
        n = 1
        for d in dims:
            n *= d
        out_elems += n
        out_bytes += n * _DTYPE_BYTES[dt]
    paren = ins.rest[ins.rest.index("("):]
    arg_part = paren.split(")")[0]
    operand_names = _OPERANDS.findall(arg_part)
    contract = 1
    in_bytes = 0
    if operand_names:
        lhs_type = shapes.get(operand_names[0], "")
        lhs_shapes = _parse_shapes(lhs_type)
        cm = _LHS_CONTRACT.search(ins.rest)
        if cm and lhs_shapes:
            dims = lhs_shapes[0][1]
            for d in (int(x) for x in cm.group(1).split(",") if x):
                if d < len(dims):
                    contract *= dims[d]
        for on in operand_names[:2]:
            in_bytes += _shape_bytes(shapes.get(on, ""))
    return 2 * out_elems * contract, out_bytes + in_bytes


def analyze(hlo: str) -> Dict[str, object]:
    """Loop-corrected {flops, dot_bytes, collective_bytes, collectives,
    n_while, max_trip}."""
    mult, comps = computation_multipliers(hlo)
    flops = 0
    dot_bytes = 0
    coll: Dict[str, int] = {}
    n_while = 0
    max_trip = 1
    for name, comp in comps.items():
        k = mult.get(name, 0)
        if k == 0:
            continue
        for ins in comp.instrs:
            if ins.op == "dot":
                f, b = _dot_stats(ins, comp.shapes)
                flops += k * f
                dot_bytes += k * b
            elif ins.op.rstrip("-start") in _COLLECTIVE_OPS or \
                    any(ins.op == c or ins.op == c + "-start"
                        for c in _COLLECTIVE_OPS):
                base = ins.op[:-6] if ins.op.endswith("-start") else ins.op
                nbytes = _shape_bytes(ins.type_str)
                coll[base] = coll.get(base, 0) + k * nbytes
            elif ins.op == "while":
                n_while += 1
                tm = _TRIP.search(ins.rest)
                if tm:
                    max_trip = max(max_trip, int(tm.group(1)))
    return {
        "flops": float(flops),
        "dot_bytes": float(dot_bytes),
        "collective_bytes": float(sum(coll.values())),
        "collectives": {k_: float(v) for k_, v in coll.items()},
        "n_while": n_while,
        "max_trip": max_trip,
    }
