"""Production mesh definitions.

``make_production_mesh`` is a function (not a module-level constant) so
importing this module never touches jax device state; the dry-run sets
XLA_FLAGS before any jax import to get 512 placeholder devices.
"""

from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    # jax >= 0.5 takes axis_types; 0.4.x meshes are implicitly Auto.
    if hasattr(jax.sharding, "AxisType"):  # pragma: no cover
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips. Multi-pod: a leading
    pod=2 axis = 512 chips. Coded gradient workers live on the
    (pod, data) axes; tensor parallelism on the model axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(shape=(1, 1), axes=("data", "model")):
    """Tiny mesh over whatever devices exist (CPU tests)."""
    return _make_mesh(shape, axes)


def num_coded_workers(mesh) -> int:
    m = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        m *= mesh.shape["pod"]
    return m
