"""Optimizers as pure pytree transforms (no external deps).

API mirrors optax: ``init(params) -> state``, ``update(grads, state,
params) -> (updates, state)``; ``apply_updates`` adds them. Schedules
are plain callables step -> lr.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params) -> (updates, state)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params,
                        updates)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def sgd(lr, momentum: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        state = {"step": jnp.zeros((), jnp.int32)}
        if momentum:
            state["mu"] = jax.tree.map(
                lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return state

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr_t = lr_fn(step)
        if momentum:
            mu = jax.tree.map(
                lambda m, g: momentum * m + g.astype(jnp.float32),
                state["mu"], grads)
            updates = jax.tree.map(lambda m: -lr_t * m, mu)
            return updates, {"step": step, "mu": mu}
        updates = jax.tree.map(lambda g: -lr_t * g.astype(jnp.float32),
                               grads)
        return updates, {"step": step}

    return Optimizer(init, update)


def adamw(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = lr_fn(step)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) *
                         g.astype(jnp.float32), state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) *
                         jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m_, v_, p):
            u = -lr_t * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u
        updates = jax.tree.map(upd, m, v, params)
        return updates, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else \
            jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0, 1)
        cos = peak_lr * (floor + (1 - floor) * 0.5 *
                         (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return fn


def get_optimizer(name: str, lr, **kw) -> Optimizer:
    if name == "sgd":
        return sgd(lr, **kw)
    if name == "adamw":
        return adamw(lr, **kw)
    raise ValueError(f"unknown optimizer {name!r}")
