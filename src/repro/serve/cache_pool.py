"""Fixed-slot device cache pool: per-request state paging.

The pool is a single pytree from ``models.model.init_decode_cache``
with ``n_slots`` as its batch dimension -- KV tensors (with their
per-row write positions) for the attention families, SSM / xLSTM
recurrent state for the others. A request "page" is one batch row
across every leaf; admission zero-resets that row in place through one
jitted, buffer-donating masked select, so slots are reused without any
allocation or host round trip. Which dim is the slot axis comes from
``dist.sharding.cache_batch_dim`` -- the same rule ``cache_specs``
uses to shard the pool over the mesh's data axes, so paging and
sharding agree by construction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.dist import sharding as rules
from repro.models import model as M


@functools.partial(jax.jit, donate_argnums=(0,))
def _zero_slots(cache, mask):
    """Zero the masked batch rows of every cache leaf, in place."""
    def reset(path, leaf):
        keys = tuple(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path)
        bd = rules.cache_batch_dim(keys)
        shape = [1] * leaf.ndim
        shape[bd] = leaf.shape[bd]
        m = mask.reshape(shape)
        return jnp.where(m, jnp.zeros((), leaf.dtype), leaf)

    return jax.tree_util.tree_map_with_path(reset, cache)


class CachePool:
    """n_slots request pages over one ``init_decode_cache`` pytree."""

    def __init__(self, cfg, n_slots: int, max_len: int, *, mesh=None,
                 src_len: int = 0):
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        cache = M.init_decode_cache(cfg, n_slots, max_len,
                                    src_len=src_len)
        if mesh is not None:
            shard = rules.named(mesh, rules.cache_specs(cache, mesh))
            cache = jax.device_put(cache, shard)
        self.cache = cache

    def reset_slots(self, mask) -> None:
        """Zero the slots where ``mask`` (n_slots,) bool is True."""
        self.cache = _zero_slots(self.cache, jnp.asarray(mask))
