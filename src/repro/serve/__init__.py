"""Continuous-batching coded serving: bounded-p99 prefill via the
paper's replicate-and-decode machinery.

The engine (``engine.ServeEngine``) packs per-request decode state --
KV, SSM, or xLSTM caches -- into a fixed slot pool
(``cache_pool.CachePool``, sharded by the same ``dist.sharding`` rules
as training caches) and advances every slot one token per jitted step:
prefill is prompt replay interleaved token-for-token with decode
(``scheduler.ContinuousScheduler``), so a long prompt can never starve
a decoding request. The host loop is async in the ``launch/train``
style: token buffers stay on device and are fetched + scattered into
per-request streams on a worker thread at log boundaries.

The replica-as-straggler model
------------------------------
Serving tail latency is a straggler problem: replicate each prefill
shard d=2 times across mesh replica slices with
``core.assignment.expander_assignment`` (shards are the expander's
vertices, replica slices its edges), model per-replica latency with
the existing ``core.stragglers`` processes -- a "straggler" is now a
replica answering after the scheduler's deadline -- and combine
whichever replicas arrive first with the weights w from the paper's
optimal O(m) decoder (``coded.CodedPrefillLayer``). Since replicas of
a shard compute *identical* outputs, the combine degenerates to
scaling the shard's logits by its own alpha_i = (A w)_i; a shard with
no usable weight (both replicas late, alpha_i ~ 0, see
``core.step_weights.served_blocks``) pays one deadline and retries.
p50 stays at the single-replica latency; p99 is bounded by the
straggler model (one deadline + retries at probability ~ p^d) instead
of by the slowest device, which is what the uncoded d=1 baseline waits
for (``latency.ReplicaLatencyModel``, ``latency.simulate_shard_ttft``).

The differential pin
--------------------
Per the repo convention, the fast path names its oracle: when no
straggler fires (p=0) every alpha_i is exactly 1.0 and the coded-serve
token stream is **bit-identical** to the single-replica serve stream;
independently, the continuous-batching engine's per-request streams
are bit-identical to ``reference.sequential_serve`` -- a simple
static-batching loop over the same jitted pool step -- under any
admission order. Both pins live in tests/test_serve_engine.py and run
as inline acceptance checks in ``benchmarks/serve_bench.py``
(BENCH_serve.json). MoE's expert-choice routing couples batch rows and
is the documented exception to the bit-identity guarantee.
"""

from .cache_pool import CachePool
from .coded import CodedPrefillLayer, ShardService, UncodedPrefillLayer
from .engine import ServeEngine, pool_step, validate_budget
from .latency import (ReplicaLatencyModel, percentile_row,
                      simulate_shard_ttft)
from .reference import sequential_serve
from .scheduler import (ContinuousScheduler, IterationPlan, Request,
                        SequentialScheduler)

__all__ = [
    "CachePool", "CodedPrefillLayer", "ContinuousScheduler",
    "IterationPlan", "ReplicaLatencyModel", "Request", "ServeEngine",
    "SequentialScheduler", "ShardService", "UncodedPrefillLayer",
    "percentile_row", "pool_step", "sequential_serve",
    "simulate_shard_ttft", "validate_budget",
]
