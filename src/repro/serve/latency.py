"""Synthetic per-replica latency: the serving face of the straggler
process.

A replica that straggles this round (alive == False in the mask drawn
from ``core.stragglers``) does not fail -- it answers ``straggle_ms``
late, long after the scheduler's per-round ``deadline_ms``. Replicas
that do not straggle answer in ``base_ms`` plus an exponential jitter
tail, clipped to the deadline so "alive" and "arrived by the deadline"
are the same event. All times are synthetic milliseconds: the model
prices *scheduling decisions* (wait vs combine vs retry), it does not
time device compute -- measured tokens/s comes from the engine's real
wall clock.

``simulate_shard_ttft`` is the closed-loop quantile machine behind
``benchmarks/serve_bench.py``: given a pre-decoded weight stream
(``CodingRuntime.weights_lookahead``) and the matching latency draws,
it plays the engine's per-shard service rule over thousands of rounds
and returns paired coded / uncoded time-to-first-token samples --
coded serving takes the *fastest arrived* replica of each shard and
pays one deadline per retry round when both replicas straggle
(probability ~ p^d), while the uncoded baseline has nothing to combine
and waits its single replica out (p99 == the slowest device).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.core import step_weights as sw
from repro.core.assignment import Assignment


@dataclasses.dataclass
class ReplicaLatencyModel:
    """Latency shaper over an (m,) alive mask.

    ``latencies(alive, rng)`` -> (m,) ms; arrived replicas land in
    [base_ms, deadline_ms), stragglers at base + straggle_ms.
    """

    m: int
    base_ms: float = 2.0
    jitter_ms: float = 0.5
    straggle_ms: float = 60.0
    deadline_ms: float = 6.0

    def __post_init__(self):
        if not (self.base_ms < self.deadline_ms < self.straggle_ms):
            raise ValueError(
                "need base_ms < deadline_ms < straggle_ms, got "
                f"({self.base_ms}, {self.deadline_ms}, "
                f"{self.straggle_ms})")

    def latencies(self, alive: np.ndarray,
                  rng: np.random.Generator) -> np.ndarray:
        alive = np.asarray(alive, bool)
        lat = self.base_ms + rng.exponential(self.jitter_ms,
                                             size=alive.shape)
        # Arrived == before the deadline, by construction: the jitter
        # tail is clipped just under it.
        lat = np.minimum(lat, self.deadline_ms * (1 - 1e-6))
        return np.where(alive, lat, lat + self.straggle_ms)


def simulate_shard_ttft(assignment: Assignment, W: np.ndarray,
                        alive: np.ndarray, lat: np.ndarray, *,
                        deadline_ms: float, straggle_ms: float,
                        eps: float = 1e-3, max_retries: int = 16
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """(coded_ttft (T, n), uncoded_ttft (T, m)) over T rounds.

    Coded rule per shard i of round t: if alpha_i = (A w_t)_i > eps,
    TTFT is the fastest arrived replica holding i; otherwise pay one
    deadline and retry on round t+1's draw (rows are reused
    cyclically). Shards still unserved after ``max_retries`` wait the
    stragglers out -- the adversarial model pins the same replicas
    every round, and waiting is then the only exit.

    Uncoded rule: shard i lives only on machine i; its TTFT is that
    machine's latency, straggle and all. Same ``lat`` matrix, so the
    comparison is paired draw for draw.
    """
    T, m = alive.shape
    n = assignment.n
    served = sw.served_blocks(assignment, W, eps)          # (T, n)
    lat_arrived = np.where(alive, lat, np.inf)             # (T, m)
    # min over each shard's replica support, per round
    shard_lat = np.stack(
        [lat_arrived[:, assignment.machines_of_block(i)].min(axis=1)
         for i in range(n)], axis=1)                       # (T, n)

    ttft = np.zeros((T, n))
    pending = np.ones((T, n), bool)
    for depth in range(max_retries + 1):
        rows = (np.arange(T) + depth) % T
        hit = pending & served[rows]
        ttft[hit] += shard_lat[rows][hit]
        pending &= ~served[rows]
        if not pending.any():
            break
        ttft[pending] += deadline_ms
    ttft[pending] += straggle_ms                           # wait it out

    if m == n:
        uncoded = lat                                      # (T, m)
    else:
        # replication changes n; draw an uncoded fleet from the same
        # latency columns (machine i serves shard i)
        uncoded = lat[:, :m]
    return ttft, uncoded


def percentile_row(scheme: str, model: str, p: float,
                   samples: np.ndarray) -> dict:
    """One BENCH_serve.json latency row."""
    flat = np.asarray(samples, float).ravel()
    return {"scheme": scheme, "straggler_model": model, "p": p,
            "p50_ms": float(np.percentile(flat, 50)),
            "p99_ms": float(np.percentile(flat, 99)),
            "mean_ms": float(flat.mean())}
