"""Coded prefill service: the paper's replicate-and-decode machinery
applied to admission rounds.

Each admission round, the layer samples an alive mask from the
configured ``core.stragglers`` process (a straggler here is a replica
slice answering after the deadline), decodes it with the optimal O(m)
decoder into machine weights w (w_j = 0 on stragglers), and serves
every shard whose combine weight alpha_i = (A w)_i is usable
(``core.step_weights.served_blocks``). A shard both of whose replicas
straggled pays one deadline and retries on a fresh round. The alpha
that served a request's shard is what the engine multiplies into that
request's first-token logits -- the debiased combine of "whichever
replicas arrive first". Decodes are memoised by mask, the same trick
``CodingRuntime`` uses for stagnant straggler processes.

``UncodedPrefillLayer`` is the d=1 baseline with the same interface:
one replica per shard, nothing to combine, so a straggling replica is
waited out at full ``straggle_ms``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs import CodingConfig
from repro.core import step_weights as sw
from repro.dist import coded_train

from .latency import ReplicaLatencyModel


@dataclasses.dataclass(frozen=True)
class ShardService:
    """How one request's prefill shard got served."""
    shard: int
    alpha: float        # combine weight applied to first-token logits
    ttft_ms: float      # synthetic shard service latency
    retries: int


class CodedPrefillLayer:
    """d-replicated prefill shards over an expander assignment."""

    def __init__(self, coding: CodingConfig, m_replicas: int,
                 latency: Optional[ReplicaLatencyModel] = None, *,
                 eps: float = 1e-3, max_retries: int = 16):
        self.coding = coding
        self.assignment = coded_train.make_assignment(coding, m_replicas)
        self.model = sw.make_straggler_model(
            self.assignment, coding.straggler_model, coding.straggler_p)
        self.rng = np.random.default_rng(coding.seed)
        self.latency = latency or ReplicaLatencyModel(m=m_replicas)
        self.eps = eps
        self.max_retries = max_retries
        self._memo: Dict[bytes, Tuple[np.ndarray, np.ndarray]] = {}
        self.rounds = 0
        self.decode_calls = 0
        self._next_shard = 0

    def assign_shards(self, k: int) -> List[int]:
        """Round-robin the next k requests over the n prefill shards."""
        n = self.assignment.n
        out = [(self._next_shard + i) % n for i in range(k)]
        self._next_shard = (self._next_shard + k) % n
        return out

    def _round(self):
        alive = self.model.sample(self.rng)
        self.rounds += 1
        key = alive.tobytes()
        hit = self._memo.get(key)
        if hit is None:
            # Serving combines *identical* replica outputs, so the
            # alpha-bar debias scale (a training-expectation device)
            # stays off: scale=1 keeps alpha == 1 exactly when every
            # replica arrives, which is what makes the p=0 coded
            # stream bit-identical to the single-replica stream.
            hit = sw.step_weights(
                self.assignment, alive, method=self.coding.decoding,
                p=self.coding.straggler_p)
            self._memo[key] = hit
            self.decode_calls += 1
        w, alpha = hit
        lat = self.latency.latencies(alive, self.rng)
        return alive, alpha, np.where(alive, lat, np.inf)

    def serve_shards(self, shards: List[int]) -> List[ShardService]:
        """Serve one admission group's shards; the group shares each
        round's mask (they face the same replica fleet at the same
        moment), retries consume fresh rounds."""
        results: List[Optional[ShardService]] = [None] * len(shards)
        remaining = set(range(len(shards)))
        waited_ms = 0.0
        for r in range(self.max_retries + 1):
            _, alpha, lat_arrived = self._round()
            for idx in sorted(remaining):
                i = shards[idx]
                if alpha[i] > self.eps:
                    support = self.assignment.machines_of_block(i)
                    t = float(lat_arrived[support].min())
                    results[idx] = ShardService(
                        i, float(alpha[i]), waited_ms + t, r)
            remaining -= {i for i, s in enumerate(results)
                          if s is not None}
            if not remaining:
                return results
            waited_ms += self.latency.deadline_ms
        for idx in remaining:
            # Every replica of this shard straggles round after round
            # (the adversarial attack): wait them out. All replicas
            # present => the full-alive decode, alpha == 1.
            results[idx] = ShardService(
                shards[idx], 1.0, waited_ms + self.latency.straggle_ms,
                self.max_retries + 1)
        return results


class UncodedPrefillLayer:
    """d=1 baseline: shard i lives only on replica i."""

    def __init__(self, coding: CodingConfig, m_replicas: int,
                 latency: Optional[ReplicaLatencyModel] = None):
        self.assignment = coded_train.make_assignment(
            dataclasses.replace(coding, scheme="uncoded"), m_replicas)
        self.model = sw.make_straggler_model(
            self.assignment, coding.straggler_model, coding.straggler_p)
        self.rng = np.random.default_rng(coding.seed)
        self.latency = latency or ReplicaLatencyModel(m=m_replicas)
        self.rounds = 0
        self.decode_calls = 0
        self._next_shard = 0

    def assign_shards(self, k: int) -> List[int]:
        n = self.assignment.n
        out = [(self._next_shard + i) % n for i in range(k)]
        self._next_shard = (self._next_shard + k) % n
        return out

    def serve_shards(self, shards: List[int]) -> List[ShardService]:
        alive = self.model.sample(self.rng)
        self.rounds += 1
        lat = self.latency.latencies(alive, self.rng)
        return [ShardService(i, 1.0, float(lat[i]), 0) for i in shards]
