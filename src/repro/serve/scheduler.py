"""Iteration-level scheduling for the serving engine.

The engine advances EVERY pool row by one token per device step: rows
still in their prefill phase consume the next prompt token, rows in
the decode phase consume their previously generated token. Prefill is
therefore not a separate long-running kernel that could starve decode
-- the interleave is total, one token of everything per iteration
(Orca-style continuous batching), and a long prompt only occupies its
own row.

``ContinuousScheduler`` admits from the queue whenever a slot frees;
``SequentialScheduler`` is the static-batching discipline (admit a
full batch, drain it completely, admit the next) that the independent
oracle in ``reference.sequential_serve`` also implements. Scheduling
must change *when* tokens appear, never *what* they are -- pinned in
tests/test_serve_engine.py.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import List, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Request:
    uid: int
    prompt: np.ndarray            # (P,) int32 token ids
    max_new_tokens: int

    def __post_init__(self):
        p = np.asarray(self.prompt, np.int32)
        if p.ndim != 1 or p.size == 0:
            raise ValueError("prompt must be a non-empty 1-D token "
                             f"array, got shape {p.shape}")
        object.__setattr__(self, "prompt", p)
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")


@dataclasses.dataclass
class _Slot:
    uid: int
    prompt: np.ndarray
    max_new: int
    consumed: int = 0             # prompt tokens consumed so far
    emitted: int = 0              # generated tokens recorded so far


@dataclasses.dataclass
class IterationPlan:
    """One device step's worth of host decisions."""
    admitted: List[Tuple[int, Request]]       # (slot, request)
    forced_tok: np.ndarray                    # (B,) int32 prompt feed
    use_forced: np.ndarray                    # (B,) bool
    emits: List[Tuple[int, int, bool]]        # (slot, uid, is_first)
    finished: List[int]                       # uids done this iteration


class ContinuousScheduler:
    """Admit whenever a slot is free (bounded by ``max_admit``)."""

    def __init__(self, n_slots: int, max_admit: Optional[int] = None):
        if n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        self.n_slots = n_slots
        self.max_admit = max_admit or n_slots
        self.queue: collections.deque = collections.deque()
        self.slots: List[Optional[_Slot]] = [None] * n_slots
        self.iterations = 0
        self.admitted_total = 0

    def submit(self, request: Request) -> None:
        self.queue.append(request)

    def has_work(self) -> bool:
        return bool(self.queue) or any(
            s is not None for s in self.slots)

    def _admissions(self) -> List[Tuple[int, Request]]:
        out = []
        for b in range(self.n_slots):
            if len(out) >= self.max_admit or not self.queue:
                break
            if self.slots[b] is None:
                out.append((b, self.queue.popleft()))
        return out

    def plan(self) -> IterationPlan:
        admitted = self._admissions()
        for b, req in admitted:
            self.slots[b] = _Slot(req.uid, req.prompt,
                                  req.max_new_tokens)
            self.admitted_total += 1
        forced = np.zeros(self.n_slots, np.int32)
        use_forced = np.zeros(self.n_slots, bool)
        emits, finished = [], []
        for b, s in enumerate(self.slots):
            if s is None:
                continue
            P = s.prompt.shape[0]
            if s.consumed < P:
                forced[b] = s.prompt[s.consumed]
                use_forced[b] = True
                s.consumed += 1
                if s.consumed == P:
                    # the last prompt token's output is the first
                    # generated token
                    emits.append((b, s.uid, True))
                    s.emitted = 1
            else:
                emits.append((b, s.uid, False))
                s.emitted += 1
            if s.consumed == P and s.emitted >= s.max_new:
                finished.append(s.uid)
                self.slots[b] = None    # reusable from next iteration
        self.iterations += 1
        return IterationPlan(admitted, forced, use_forced, emits,
                             finished)


class SequentialScheduler(ContinuousScheduler):
    """Static batching: admit only into an entirely idle pool."""

    def _admissions(self) -> List[Tuple[int, Request]]:
        if any(s is not None for s in self.slots):
            return []
        return super()._admissions()
