"""Sequential-batching reference loop: the scheduler's oracle.

Deliberately naive -- admit the next ``n_slots`` requests as a static
batch, replay every prompt through the decode path, greedy-decode the
whole group to completion (finished rows keep stepping harmlessly),
then move to the next group, syncing tokens to the host every
iteration. It shares exactly one thing with the engine: the jitted
``engine.pool_step`` computation, so any divergence between this loop
and the engine's streams is a scheduling/paging bug, never a numerics
difference. tests/test_serve_engine.py pins the two bit-for-bit.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.models import model as M

from .engine import pool_step
from .scheduler import Request


def sequential_serve(params, cfg, requests: List[Request], *,
                     n_slots: int, max_len: int,
                     window: Optional[int] = None
                     ) -> Dict[int, np.ndarray]:
    window = window if window is not None else cfg.sliding_window
    step_fn = pool_step(cfg, window)
    ones = jnp.ones(n_slots, jnp.float32)
    out: Dict[int, list] = {r.uid: [] for r in requests}
    for g0 in range(0, len(requests), n_slots):
        group = requests[g0:g0 + n_slots]
        cache = M.init_decode_cache(cfg, n_slots, max_len)
        prev = jnp.zeros(n_slots, jnp.int32)
        consumed = [0] * len(group)
        emitted = [0] * len(group)
        while any(e < r.max_new_tokens
                  for e, r in zip(emitted, group)):
            forced = np.zeros(n_slots, np.int32)
            use = np.zeros(n_slots, bool)
            emits = []
            for i, req in enumerate(group):
                P = req.prompt.shape[0]
                if consumed[i] < P:
                    forced[i] = req.prompt[consumed[i]]
                    use[i] = True
                    consumed[i] += 1
                    if consumed[i] == P:
                        emits.append(i)
                        emitted[i] = 1
                elif emitted[i] < req.max_new_tokens:
                    emits.append(i)
                    emitted[i] += 1
            prev, cache = step_fn(params, cache, prev,
                                  jnp.asarray(forced),
                                  jnp.asarray(use), ones)
            toks = np.asarray(prev)
            for i in emits:
                out[group[i].uid].append(int(toks[i]))
    return {uid: np.asarray(v, np.int32) for uid, v in out.items()}
