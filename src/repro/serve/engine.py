"""Continuous-batching serving engine over the coded serve steps.

One jitted *pool step* at fixed width n_slots wraps
``dist.coded_train.make_serve_step``: every iteration each row either
consumes a forced prompt token (prefill replay) or its previously
generated token (decode), the row's logits are scaled by its combine
weight alpha (1.0 except at a coded first token), and greedy argmax
produces the next token -- all without a host sync. The host loop is
async in the ``launch/train`` style: plans are pure host bookkeeping,
generated-token device buffers accumulate and are fetched + scattered
into per-request streams on a worker thread once per ``log_every``
iterations (double-buffered detokenize), so the device pipeline never
waits on the host in steady state.

Rows are independent through every decode kernel (per-row KV write
positions, per-row SSM/xLSTM state), which is what makes scheduling
invisible in the output: the same jitted step at the same pool width
produces bit-identical per-request token streams under any admission
order. The MoE family is the one exception -- expert-choice routing
couples batch rows -- so it serves fine but sits outside the
bit-identity pins.
"""

from __future__ import annotations

import functools
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import CodingConfig, ModelConfig
from repro.dist import coded_train, sharding as rules

from .cache_pool import CachePool
from .coded import CodedPrefillLayer, UncodedPrefillLayer
from .latency import ReplicaLatencyModel
from .scheduler import ContinuousScheduler, Request


def validate_budget(cfg: ModelConfig, prompt_len: int,
                    max_new_tokens: int, max_len: int, *,
                    window: Optional[int] = None) -> None:
    """Reject a generation budget the decode cache cannot hold, up
    front -- the historical driver only failed (or silently wrote past
    the KV capacity) mid-generation.

    Windowed attention wraps its cache, so only the full-attention
    capacity check applies there; any declared config ``max_seq_len``
    caps both.
    """
    if prompt_len < 1:
        raise ValueError("prompt_len must be >= 1")
    if max_new_tokens < 1:
        raise ValueError("max_new_tokens must be >= 1")
    total = prompt_len + max_new_tokens
    w = window if window is not None else cfg.sliding_window
    if w is None and total > max_len:
        raise ValueError(
            f"prompt_len + max_new_tokens = {total} overflows the "
            f"decode cache (--max-len {max_len}) for full causal "
            f"attention; raise --max-len or shorten the request")
    max_seq = getattr(cfg, "max_seq_len", None)
    if max_seq and total > max_seq:
        raise ValueError(
            f"prompt_len + max_new_tokens = {total} exceeds the "
            f"config's max_seq_len {max_seq}")


@functools.lru_cache(maxsize=8)
def pool_step(cfg: ModelConfig, window: Optional[int]):
    """The jitted fixed-width pool step, shared (via the cache key) by
    the engine, the sequential reference loop, and the tests so all of
    them run the identical compiled computation."""
    serve_step = coded_train.make_serve_step(cfg, window=window)
    V = cfg.vocab_size

    def step(params, cache, prev_tok, forced_tok, use_forced, alpha):
        tok = jnp.where(use_forced, forced_tok, prev_tok)
        logits, cache = serve_step(params, tok, cache)
        scores = alpha[:, None] * logits[:, :V]
        nxt = jnp.argmax(scores, axis=-1).astype(jnp.int32)
        return nxt, cache

    return jax.jit(step, donate_argnums=(1,))


class ServeEngine:
    """Admission queue + cache pool + coded prefill + async host loop."""

    def __init__(self, cfg: ModelConfig, params, *, n_slots: int = 8,
                 max_len: int = 128, mesh=None,
                 coding: Optional[CodingConfig] = None,
                 m_replicas: int = 8,
                 latency: Optional[ReplicaLatencyModel] = None,
                 scheduler: Optional[ContinuousScheduler] = None,
                 log_every: int = 16):
        if cfg.arch_type in ("vlm", "audio"):
            raise ValueError(
                f"arch_type {cfg.arch_type!r} needs a per-request "
                "prefix/src side channel; use the static batch path in "
                "launch/serve.py")
        self.cfg = cfg
        self.window = cfg.sliding_window
        self.pool = CachePool(cfg, n_slots, max_len, mesh=mesh)
        self.scheduler = scheduler or ContinuousScheduler(n_slots)
        if self.scheduler.n_slots != n_slots:
            raise ValueError("scheduler width != n_slots")
        self.step_fn = pool_step(cfg, self.window)
        if mesh is not None:
            params = jax.device_put(
                params,
                rules.named(mesh, rules.safe_param_specs(params, mesh)))
        self.params = params
        self.log_every = max(1, log_every)
        if coding is not None and coding.scheme != "uncoded":
            self.prefill = CodedPrefillLayer(coding, m_replicas, latency)
        elif coding is not None:
            self.prefill = UncodedPrefillLayer(coding, m_replicas,
                                               latency)
        else:
            self.prefill = None
        self.records: Dict[int, dict] = {}
        self._tok = jnp.zeros(n_slots, jnp.int32)
        self._alpha_pending = np.ones(n_slots, np.float32)

    def submit(self, request: Request) -> None:
        validate_budget(self.cfg, int(request.prompt.shape[0]),
                        request.max_new_tokens, self.pool.max_len,
                        window=self.window)
        if request.uid in self.records:
            raise ValueError(f"duplicate request uid {request.uid}")
        self.records[request.uid] = {
            "tokens": [], "shard": None, "alpha": 1.0,
            "ttft_ms": None, "retries": 0,
            "enqueued_iter": self.scheduler.iterations,
            "admitted_iter": None, "done_iter": None}
        self.scheduler.submit(request)

    def _admit(self, admitted) -> None:
        mask = np.zeros(self.pool.n_slots, bool)
        for b, _ in admitted:
            mask[b] = True
        self.pool.reset_slots(mask)
        it = self.scheduler.iterations
        services = None
        if self.prefill is not None:
            shards = self.prefill.assign_shards(len(admitted))
            services = self.prefill.serve_shards(shards)
        for k, (b, req) in enumerate(admitted):
            rec = self.records[req.uid]
            rec["admitted_iter"] = it
            if services is not None:
                svc = services[k]
                rec.update(shard=svc.shard, alpha=svc.alpha,
                           ttft_ms=svc.ttft_ms, retries=svc.retries)
                self._alpha_pending[b] = svc.alpha
            else:
                self._alpha_pending[b] = 1.0

    def _flush(self, buf) -> None:
        toks = jax.device_get([t for t, _ in buf])
        for tok, emits in zip(toks, buf):
            for b, uid, _ in emits[1]:
                self.records[uid]["tokens"].append(int(tok[b]))

    def run(self) -> dict:
        """Drain the queue; returns a summary dict (per-request tokens
        via ``results()``)."""
        sched = self.scheduler
        B = self.pool.n_slots
        t0 = time.perf_counter()
        iters0 = sched.iterations
        buf: List = []
        pending = None
        with ThreadPoolExecutor(max_workers=1) as host:
            while sched.has_work():
                plan = sched.plan()
                if plan.admitted:
                    self._admit(plan.admitted)
                alpha = np.ones(B, np.float32)
                for b, uid, is_first in plan.emits:
                    if is_first:
                        alpha[b] = self._alpha_pending[b]
                for uid in plan.finished:
                    self.records[uid]["done_iter"] = sched.iterations
                self._tok, self.pool.cache = self.step_fn(
                    self.params, self.pool.cache, self._tok,
                    jnp.asarray(plan.forced_tok),
                    jnp.asarray(plan.use_forced), jnp.asarray(alpha))
                if plan.emits:
                    buf.append((self._tok, tuple(plan.emits)))
                if len(buf) >= self.log_every:
                    # double buffer: fetch+scatter the previous chunk
                    # on the host thread while the device runs on
                    if pending is not None:
                        pending.result()
                    pending = host.submit(self._flush, buf)
                    buf = []
            if pending is not None:
                pending.result()
            self._flush(buf)
        dt = time.perf_counter() - t0
        new_tokens = sum(len(r["tokens"]) for r in self.records.values())
        ttfts = [r["ttft_ms"] for r in self.records.values()
                 if r["ttft_ms"] is not None]
        summary = {
            "requests": len(self.records),
            "new_tokens": new_tokens,
            "tokens_per_s": new_tokens / max(dt, 1e-9),
            "iterations": sched.iterations - iters0,
            "admissions": sched.admitted_total,
            "retries": sum(r["retries"]
                           for r in self.records.values()),
            "decode_calls": (self.prefill.decode_calls
                             if self.prefill is not None else 0),
        }
        if ttfts:
            summary["ttft_p50_ms"] = float(np.percentile(ttfts, 50))
            summary["ttft_p99_ms"] = float(np.percentile(ttfts, 99))
        return summary

    def results(self) -> Dict[int, np.ndarray]:
        return {uid: np.asarray(r["tokens"], np.int32)
                for uid, r in self.records.items()}
