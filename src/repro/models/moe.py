"""Mixture-of-Experts layer: shared experts + routed experts.

TPU-native dispatch: *expert-choice* routing (each expert selects its
top-C tokens), which keeps all tensors dense and statically shaped --
dispatch is two einsums over a (B, E, C) gather, no (B, S, E, C) one-hot
is ever materialised (the GShard dispatch tensor would be terabytes at
our shapes). Aggregate FLOPs match top-k token routing with
C = S * top_k / E, which is what we set, so roofline numbers are
faithful to the cited MoE configs. A reference top-k *token-choice*
router (dense over experts) is provided for smoke-scale numerical
parity checks and documented as the semantic baseline.

An auxiliary load-balance loss (Switch-style) is returned for the
token-choice path; expert choice is load-balanced by construction.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .layers import init_linear, init_mlp, linear, mlp


def init_moe(key, d_model: int, expert_d_ff: int, n_experts: int,
             n_shared: int, shared_d_ff: int, dtype: str = "float32"):
    kr, ke, ks = jax.random.split(key, 3)
    scale = d_model ** -0.5
    p = {
        "router": init_linear(kr, d_model, n_experts, dtype=dtype),
        # Stacked expert SwiGLU weights: (E, d_model, ff) / (E, ff, d_model)
        "w_gate": jax.random.normal(
            ke, (n_experts, d_model, expert_d_ff),
            jnp.dtype(dtype)) * scale,
        "w_up": jax.random.normal(
            jax.random.fold_in(ke, 1), (n_experts, d_model, expert_d_ff),
            jnp.dtype(dtype)) * scale,
        "w_down": jax.random.normal(
            jax.random.fold_in(ke, 2), (n_experts, expert_d_ff, d_model),
            jnp.dtype(dtype)) * (expert_d_ff ** -0.5),
    }
    if n_shared:
        p["shared"] = init_mlp(ks, d_model, shared_d_ff, dtype=dtype)
    return p


def moe_expert_choice(p, x, *, top_k: int, capacity_factor: float = 1.0
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Expert-choice MoE forward.

    x: (B, S, D). Returns (y, aux_loss). Capacity per expert
    C = ceil(S * top_k / E * capacity_factor).
    """
    B, S, D = x.shape
    E = p["router"]["w"].shape[1]
    C = max(1, int(S * top_k * capacity_factor) // E)

    logits = linear(p["router"], x).astype(jnp.float32)   # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    # Each expert picks its top-C tokens.
    gates, idx = jax.lax.top_k(probs.transpose(0, 2, 1), C)  # (B, E, C)
    # Gather tokens: (B, E, C, D)
    xg = jnp.take_along_axis(
        x[:, None], idx[..., None].astype(jnp.int32), axis=2)
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xg, p["w_gate"].astype(
        x.dtype))) * jnp.einsum("becd,edf->becf", xg,
                                p["w_up"].astype(x.dtype))
    yo = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(x.dtype))
    yo = yo * gates[..., None].astype(x.dtype)
    # Scatter-add back to token positions.
    y = jnp.zeros_like(x)
    bidx = jnp.arange(B)[:, None, None]
    y = y.at[bidx, idx].add(yo)
    if "shared" in p:
        y = y + mlp(p["shared"], x)
    return y, jnp.zeros((), jnp.float32)


def moe_token_choice_dense(p, x, *, top_k: int
                           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Reference top-k token-choice router with *dense* expert compute
    (every expert runs on every token; combine masks to top-k). Exact
    semantics of the cited configs; O(E) compute, smoke-scale only."""
    B, S, D = x.shape
    E = p["router"]["w"].shape[1]
    logits = linear(p["router"], x).astype(jnp.float32)    # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, top_k)
    gates = jnp.zeros_like(probs)
    gates = jnp.take_along_axis(
        gates, top_idx, axis=-1)  # placeholder to keep shapes clear
    mask = jax.nn.one_hot(top_idx, E).sum(-2)              # (B, S, E)
    combine = (probs * mask)
    combine = combine / jnp.maximum(combine.sum(-1, keepdims=True), 1e-9)
    h = jax.nn.silu(jnp.einsum("bsd,edf->besf", x, p["w_gate"].astype(
        x.dtype))) * jnp.einsum("bsd,edf->besf", x,
                                p["w_up"].astype(x.dtype))
    yo = jnp.einsum("besf,efd->besd", h, p["w_down"].astype(x.dtype))
    y = jnp.einsum("bse,besd->bsd", combine.astype(x.dtype), yo)
    if "shared" in p:
        y = y + mlp(p["shared"], x)
    # Switch-style load balance loss.
    frac_tokens = mask.mean(axis=(0, 1))                   # (E,)
    frac_probs = probs.mean(axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * frac_probs)
    return y, aux
