"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

TPU adaptation notes (see DESIGN.md): the mLSTM is implemented in its
chunked-parallel form -- a decay-gated linear attention with per-head
matrix state (P x P), structurally the same chunking as the Mamba2 SSD
block so both map onto the MXU. Gating uses log-sigmoid forget gates and
sigmoid input gates (the exponential-gate stabiliser of the paper is
replaced by the bounded sigmoid parameterisation; the max-stabilised
exponential gate has no closed chunked form that avoids materialising
per-step running maxima, and on TPU the bounded form is the standard
numerically-safe choice). The sLSTM keeps per-unit scalar cells c, n
with diagonal gating and drops the hidden-to-hidden recurrence matrix R
so the cell admits a parallel associative scan; this is noted as a
deviation (the R-matrix form is strictly sequential, which would defeat
the 500k-token decode target).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .layers import init_linear, linear


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(key, d_model: int, n_heads: int, dtype: str = "float32"):
    P = d_model // n_heads
    ks = jax.random.split(key, 6)
    return {
        "wq": init_linear(ks[0], d_model, d_model, dtype=dtype),
        "wk": init_linear(ks[1], d_model, d_model, dtype=dtype),
        "wv": init_linear(ks[2], d_model, d_model, dtype=dtype),
        "w_gates": init_linear(ks[3], d_model, 2 * n_heads, dtype=dtype),
        "wo": init_linear(ks[4], d_model, d_model, dtype=dtype),
    }


def mlstm_forward(p, u, *, n_heads: int, chunk: int = 256) -> jnp.ndarray:
    """u: (B, S, D) -> (B, S, D). Chunked decay-gated linear attention."""
    B, S, D = u.shape
    P = D // n_heads
    H = n_heads
    q = linear(p["wq"], u).reshape(B, S, H, P)
    k = linear(p["wk"], u).reshape(B, S, H, P) * (P ** -0.5)
    v = linear(p["wv"], u).reshape(B, S, H, P)
    gates = linear(p["w_gates"], u).astype(jnp.float32)
    i_gate = jax.nn.sigmoid(gates[..., :H])                # (B,S,H)
    log_f = jax.nn.log_sigmoid(gates[..., H:])             # (B,S,H) <= 0

    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        i_gate = jnp.pad(i_gate, ((0, 0), (0, pad), (0, 0)))
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
    Sp = q.shape[1]
    nc = Sp // Q

    qc = q.reshape(B, nc, Q, H, P).astype(jnp.float32)
    kc = k.reshape(B, nc, Q, H, P).astype(jnp.float32)
    vc = v.reshape(B, nc, Q, H, P).astype(jnp.float32)
    ic = i_gate.reshape(B, nc, Q, H)
    lfc = log_f.reshape(B, nc, Q, H)

    cum = jnp.cumsum(lfc, axis=2)
    total = cum[:, :, -1, :]

    # intra-chunk
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # (B,nc,Q,Q,H)
    ii = jnp.arange(Q)
    causal = ii[:, None] >= ii[None, :]
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    qk = jnp.einsum("bcihp,bcjhp->bcijh", qc, kc)
    scores = qk * decay * ic[:, :, None, :, :]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, vc)

    # chunk states: C (B,nc,H,P,P), n (B,nc,H,P)
    w_end = jnp.exp(total[:, :, None, :] - cum) * ic        # (B,nc,Q,H)
    stateC = jnp.einsum("bcjh,bcjhp,bcjhr->bchpr", w_end, kc, vc)
    stateN = jnp.einsum("bcjh,bcjhp->bchp", w_end, kc)

    def chunk_step(carry, inp):
        Cp, Np = carry
        sC, sN, tot = inp
        dec = jnp.exp(tot)[..., None, None]
        C_new = dec * Cp + sC
        N_new = dec[..., 0] * Np + sN
        return (C_new, N_new), (Cp, Np)

    C0 = jnp.zeros((B, H, P, P), jnp.float32)
    N0 = jnp.zeros((B, H, P), jnp.float32)
    _, (C_in, N_in) = jax.lax.scan(
        chunk_step, (C0, N0),
        (jnp.moveaxis(stateC, 1, 0), jnp.moveaxis(stateN, 1, 0),
         jnp.moveaxis(total, 1, 0)))
    C_in = jnp.moveaxis(C_in, 0, 1)
    N_in = jnp.moveaxis(N_in, 0, 1)

    dec_i = jnp.exp(cum)                                    # (B,nc,Q,H)
    y_inter = jnp.einsum("bcihp,bcih,bchpr->bcihr", qc, dec_i, C_in)
    n_inter = jnp.einsum("bcihp,bcih,bchp->bcih", qc, dec_i, N_in)

    # intra normalizer: q_i . (sum_j decay i_j k_j) == scores summed over j
    qn_intra = scores.sum(axis=3)                           # (B,nc,Q,H)
    denom = jnp.maximum(jnp.abs(qn_intra + n_inter), 1.0)[..., None]
    y = (y_intra + y_inter) / denom
    y = y.reshape(B, Sp, D)[:, :S].astype(u.dtype)
    return linear(p["wo"], y)


def mlstm_decode(p, u, state, *, n_heads: int) -> Tuple[jnp.ndarray, dict]:
    """u: (B, 1, D); state = {"C": (B,H,P,P), "n": (B,H,P)} fp32."""
    B, _, D = u.shape
    H, P = n_heads, D // n_heads
    q = linear(p["wq"], u).reshape(B, H, P).astype(jnp.float32)
    k = (linear(p["wk"], u).reshape(B, H, P) * (P ** -0.5)).astype(
        jnp.float32)
    v = linear(p["wv"], u).reshape(B, H, P).astype(jnp.float32)
    gates = linear(p["w_gates"], u).astype(jnp.float32)[:, 0]
    i_g = jax.nn.sigmoid(gates[..., :H])
    f_g = jax.nn.sigmoid(gates[..., H:])
    C = f_g[..., None, None] * state["C"] + i_g[..., None, None] * \
        jnp.einsum("bhp,bhr->bhpr", k, v)
    n = f_g[..., None] * state["n"] + i_g[..., None] * k
    num = jnp.einsum("bhp,bhpr->bhr", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", q, n)), 1.0)
    y = (num / den[..., None]).reshape(B, 1, D).astype(u.dtype)
    return linear(p["wo"], y), {"C": C, "n": n}


def init_mlstm_state(batch: int, d_model: int, n_heads: int):
    P = d_model // n_heads
    return {"C": jnp.zeros((batch, n_heads, P, P), jnp.float32),
            "n": jnp.zeros((batch, n_heads, P), jnp.float32)}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(key, d_model: int, dtype: str = "float32"):
    ks = jax.random.split(key, 2)
    return {
        # z, i, f, o per hidden unit
        "w_in": init_linear(ks[0], d_model, 4 * d_model, dtype=dtype),
        "wo": init_linear(ks[1], d_model, d_model, dtype=dtype),
    }


def slstm_forward(p, u) -> jnp.ndarray:
    """u: (B, S, D). Parallel associative scan over the diagonal cell."""
    B, S, D = u.shape
    zifo = linear(p["w_in"], u).astype(jnp.float32)
    z, i, f, o = jnp.split(zifo, 4, axis=-1)
    z = jnp.tanh(z)
    i = jax.nn.sigmoid(i)
    f = jax.nn.sigmoid(f)
    o = jax.nn.sigmoid(o)

    def combine(a, b):
        # recurrences c_t = f c + i z and n_t = f n + i share decay f
        (fa, ca, na), (fb, cb, nb) = a, b
        return (fa * fb, fb * ca + cb, fb * na + nb)

    c, n = jax.lax.associative_scan(
        combine, (f, i * z, i), axis=1)[1:]
    h = o * c / jnp.maximum(n, 1e-6)
    return linear(p["wo"], h.astype(u.dtype))


def slstm_decode(p, u, state) -> Tuple[jnp.ndarray, dict]:
    """state = {"c": (B, D), "n": (B, D)} fp32."""
    zifo = linear(p["w_in"], u).astype(jnp.float32)[:, 0]
    z, i, f, o = jnp.split(zifo, 4, axis=-1)
    z = jnp.tanh(z)
    i = jax.nn.sigmoid(i)
    f = jax.nn.sigmoid(f)
    o = jax.nn.sigmoid(o)
    c = f * state["c"] + i * z
    n = f * state["n"] + i
    h = (o * c / jnp.maximum(n, 1e-6))[:, None].astype(u.dtype)
    return linear(p["wo"], h), {"c": c, "n": n}


def init_slstm_state(batch: int, d_model: int):
    return {"c": jnp.zeros((batch, d_model), jnp.float32),
            "n": jnp.zeros((batch, d_model), jnp.float32)}
