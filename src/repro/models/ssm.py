"""Mamba2-style selective state space (SSD) block.

Chunked SSD algorithm (Dao & Gu 2024), TPU-adapted: the sequence is
split into chunks of length Q; within-chunk interactions are a masked
(decay-weighted) quadratic form that maps onto the MXU, and cross-chunk
interactions are a short ``lax.scan`` over per-chunk states
(B, H, N, P). Memory is O(S*Q) per head instead of O(S^2), and the scan
has S/Q steps, keeping the HLO small.

Scalar-per-head decay a_t = exp(dt_t * A_h) as in Mamba2; B/C projections
shared across heads (single group). Decode carries the state
(B, H, N, P) plus a rolling conv window.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .layers import init_linear, linear


def init_ssm(key, d_model: int, d_state: int, *, expand: int = 2,
             head_p: int = 64, conv_k: int = 4, dtype: str = "float32"):
    d_inner = expand * d_model
    n_heads = d_inner // head_p
    ks = jax.random.split(key, 5)
    return {
        # x/z projection kept separate from the small B/C/dt head so the
        # big output splits on a shard-aligned boundary (d_inner | 16);
        # a fused [x,z,B,C,dt] projection would split a model-sharded
        # dim at misaligned offsets and force a full all-gather per
        # layer (observed: 1.8e12 B/step in the zamba2 dry-run).
        "xz_proj": init_linear(ks[0], d_model, 2 * d_inner, dtype=dtype),
        "bcdt_proj": init_linear(ks[3], d_model, 2 * d_state + n_heads,
                                 dtype=dtype),
        "conv_w": jax.random.normal(
            ks[1], (conv_k, d_inner), jnp.dtype(dtype)) * (conv_k ** -0.5),
        "A_log": jnp.zeros((n_heads,), jnp.dtype(dtype)),
        "dt_bias": jnp.zeros((n_heads,), jnp.dtype(dtype)),
        "D": jnp.ones((n_heads,), jnp.dtype(dtype)),
        "out_proj": init_linear(ks[2], d_inner, d_model, dtype=dtype),
    }


def _project(p, u, d_inner, d_state):
    xz = linear(p["xz_proj"], u)
    x, z = jnp.split(xz, 2, axis=-1)
    bcdt = linear(p["bcdt_proj"], u)
    Bs, Cs, dt = jnp.split(bcdt, [d_state, 2 * d_state], axis=-1)
    return x, z, Bs, Cs, dt


def ssm_forward(p, u, *, d_state: int, expand: int = 2, head_p: int = 64,
                chunk: int = 256) -> jnp.ndarray:
    """u: (B, S, D) -> (B, S, D). Chunked SSD."""
    B, S, D = u.shape
    d_inner = expand * D
    H = d_inner // head_p
    x, z, Bs, Cs, dt = _project(p, u, d_inner, d_state)

    # Causal depthwise conv on x.
    K = p["conv_w"].shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    x = sum(xp[:, i:i + S] * p["conv_w"][i].astype(x.dtype)
            for i in range(K))
    x = jax.nn.silu(x)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))              # (H,)
    # per-step log decay: (B, S, H), <= 0
    la = dt * A[None, None, :]

    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        Bs = jnp.pad(Bs, ((0, 0), (0, pad), (0, 0)))
        Cs = jnp.pad(Cs, ((0, 0), (0, pad), (0, 0)))
        la = jnp.pad(la, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    Sp = x.shape[1]
    nc = Sp // Q

    xh = x.reshape(B, nc, Q, H, head_p).astype(jnp.float32)
    Bc = Bs.reshape(B, nc, Q, d_state).astype(jnp.float32)
    Cc = Cs.reshape(B, nc, Q, d_state).astype(jnp.float32)
    lac = la.reshape(B, nc, Q, H)
    dtc = dt.reshape(B, nc, Q, H)

    cum = jnp.cumsum(lac, axis=2)                    # (B,nc,Q,H)
    total = cum[:, :, -1, :]                         # (B,nc,H)

    # --- intra-chunk (quadratic, MXU-friendly) ---
    # decay(i,j) = exp(cum_i - cum_j) for i >= j
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,Q,Q,H)
    ii = jnp.arange(Q)
    causal = (ii[:, None] >= ii[None, :])
    decay = jnp.where(causal[None, None, :, :, None],
                      jnp.exp(seg), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)            # (B,nc,Q,Q)
    scores = cb[..., None] * decay                        # (B,nc,Q,Q,H)
    xdt = xh * dtc[..., None]                             # dt-weighted input
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, xdt)

    # --- chunk states and cross-chunk recurrence ---
    # state contribution of step j: exp(total - cum_j) * dt_j B_j x_j
    w_end = jnp.exp(total[:, :, None, :] - cum)           # (B,nc,Q,H)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchnp",
                        Bc, w_end * dtc, xh)              # (B,nc,H,N,P)

    def chunk_step(h_prev, inp):
        st, tot = inp  # (B,H,N,P), (B,H)
        h_new = jnp.exp(tot)[..., None, None] * h_prev + st
        return h_new, h_prev  # emit state *entering* the chunk

    h0 = jnp.zeros((B, H, d_state, head_p), jnp.float32)
    _, h_in = jax.lax.scan(
        chunk_step, h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(total, 1, 0)))
    h_in = jnp.moveaxis(h_in, 0, 1)                       # (B,nc,H,N,P)

    # inter-chunk output: y_i += exp(cum_i) * C_i . h_in
    y_inter = jnp.einsum("bcin,bcih,bchnp->bcihp",
                         Cc, jnp.exp(cum), h_in)

    y = (y_intra + y_inter).reshape(B, Sp, H, head_p)
    y = y + xh.reshape(B, Sp, H, head_p) * p["D"].astype(
        jnp.float32)[None, None, :, None]
    y = y[:, :S].reshape(B, S, d_inner).astype(u.dtype)
    y = y * jax.nn.silu(z)
    return linear(p["out_proj"], y)


def ssm_decode(p, u, state, *, d_state: int, expand: int = 2,
               head_p: int = 64) -> Tuple[jnp.ndarray, dict]:
    """Single-step recurrence. u: (B, 1, D).

    state = {"h": (B, H, N, P) fp32, "conv": (B, K-1, d_inner)}.
    """
    B, _, D = u.shape
    d_inner = expand * D
    H = d_inner // head_p
    x, z, Bs, Cs, dt = _project(p, u, d_inner, d_state)

    K = p["conv_w"].shape[0]
    win = jnp.concatenate([state["conv"], x], axis=1)      # (B, K, d_inner)
    x = sum(win[:, i:i + 1] * p["conv_w"][i].astype(x.dtype)
            for i in range(K))
    x = jax.nn.silu(x)
    new_conv = win[:, 1:]

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))[:, 0]  # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(dt * A[None, :])                           # (B,H)

    xh = x[:, 0].reshape(B, H, head_p).astype(jnp.float32)
    Bv = Bs[:, 0].astype(jnp.float32)                      # (B,N)
    Cv = Cs[:, 0].astype(jnp.float32)
    h = a[..., None, None] * state["h"] + jnp.einsum(
        "bn,bh,bhp->bhnp", Bv, dt, xh)
    y = jnp.einsum("bn,bhnp->bhp", Cv, h)
    y = y + xh * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B, 1, d_inner).astype(u.dtype)
    y = y * jax.nn.silu(z)
    return linear(p["out_proj"], y), {"h": h, "conv": new_conv}


def init_ssm_state(batch: int, d_model: int, d_state: int, *,
                   expand: int = 2, head_p: int = 64, conv_k: int = 4):
    d_inner = expand * d_model
    H = d_inner // head_p
    return {
        "h": jnp.zeros((batch, H, d_state, head_p), jnp.float32),
        "conv": jnp.zeros((batch, conv_k - 1, d_inner), jnp.float32),
    }
