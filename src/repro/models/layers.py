"""Shared building blocks: norms, linear, rotary, SwiGLU MLP.

Functional style: every layer is an ``init_*`` returning a param pytree
plus an ``apply`` that takes (params, inputs). Params are plain nested
dicts of jnp arrays so pjit sharding rules can pattern-match on paths.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.rmsnorm import ops as rmsnorm_ops


def _dtype(name: str):
    return jnp.dtype(name)


def init_linear(key, d_in: int, d_out: int, *, bias: bool = False,
                dtype: str = "float32", scale: Optional[float] = None):
    scale = scale if scale is not None else d_in ** -0.5
    p = {"w": jax.random.normal(key, (d_in, d_out), _dtype(dtype)) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), _dtype(dtype))
    return p


def linear(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def init_rmsnorm(d: int, dtype: str = "float32"):
    return {"scale": jnp.ones((d,), _dtype(dtype))}


def rmsnorm(p, x, eps: float = 1e-6):
    return rmsnorm_ops.rmsnorm(x, p["scale"], eps=eps)


def init_embedding(key, vocab: int, d: int, dtype: str = "float32"):
    return {"table": jax.random.normal(key, (vocab, d), _dtype(dtype)) * 0.02}


def embed(p, ids):
    return jnp.take(p["table"], ids, axis=0)


def unembed(p, x):
    """Tied unembedding: logits = x @ table^T (fp32 logits)."""
    return (x.astype(jnp.float32)
            @ p["table"].astype(jnp.float32).T)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)                 # (Dh/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..,S,Dh/2)
    cos = jnp.cos(angles)[..., None, :]                        # (..,S,1,Dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, dtype: str = "float32"):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": init_linear(k1, d_model, d_ff, dtype=dtype),
        "wi_up": init_linear(k2, d_model, d_ff, dtype=dtype),
        "wo": init_linear(k3, d_ff, d_model, dtype=dtype),
    }


def mlp(p, x):
    h = jax.nn.silu(linear(p["wi_gate"], x)) * linear(p["wi_up"], x)
    return linear(p["wo"], h)
