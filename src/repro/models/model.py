"""Unified model assembly for all assigned architecture families.

Families (dispatch on ``config.arch_type``):

- dense / vlm : decoder-only GQA transformer (vlm consumes a stubbed
  patch-embedding prefix).
- moe         : same backbone with MoE FFN (shared + routed experts).
- hybrid      : Mamba2 (SSD) backbone with a single *shared* attention
  block applied at ``attn_positions`` (Zamba2).
- ssm         : xLSTM -- super-blocks of ``slstm_ratio`` mLSTM + 1 sLSTM.
- audio       : encoder-decoder; encoder consumes stubbed frame
  embeddings, decoder is causal with cross-attention (Seamless).

All layer stacks run under ``lax.scan`` over stacked per-layer params
with ``jax.checkpoint`` on the block body, so the lowered HLO is
layer-count independent and activations are rematerialised.

Public API: init_params, forward, train_loss, prefill, decode_step,
init_decode_cache.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import attention as attn

# Activation-sharding hook (set by the distributed launcher): the
# residual stream (batch, seq, d_model) is constrained at scan-carry
# boundaries to (batch -> data axes, d_model -> model axis) so saved
# activations are both data- and tensor-sharded. PartitionSpec None
# means *replicated*, so the batch axes must be carried explicitly --
# constraining only the last dim silently replicates the batch across
# the data axes (observed: 1.8e12 B/step of gathers).
_RESIDUAL_AXES = None  # (batch_axes, model_axis, mode, model_size)


def set_residual_sharding(batch_axes=None, model_axis=None,
                          mode: str = "dmodel", model_size: int = 1):
    """batch_axes: mesh axis (or tuple) for dim 0; model_axis: mesh axis
    for the constrained dim. mode: 'dmodel' shards the last (d_model)
    dim; 'seq' shards the sequence dim (Megatron-style sequence
    parallelism -- the MLP then needs *no* activation collective and
    attention gathers only the small GQA K/V), falling back to 'dmodel'
    when the seq dim does not divide model_size (e.g. decode, S=1).
    Pass no args to disable."""
    global _RESIDUAL_AXES
    if batch_axes is None and model_axis is None:
        _RESIDUAL_AXES = None
    else:
        _RESIDUAL_AXES = (batch_axes, model_axis, mode, model_size)


def _constrain(x):
    if _RESIDUAL_AXES is None:
        return x
    from jax.sharding import PartitionSpec as P
    batch_axes, model_axis, mode, msize = _RESIDUAL_AXES
    dims = [batch_axes] + [None] * (x.ndim - 1)
    if (mode == "seq" and x.ndim >= 3
            and x.shape[1] % max(msize, 1) == 0 and x.shape[1] >= msize):
        dims[1] = model_axis
    elif x.shape[-1] % max(msize, 1) == 0:
        dims[-1] = model_axis
    spec = P(*dims)
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:  # outside a mesh context
        return x
from . import moe as moe_mod
from . import ssm as ssm_mod
from . import xlstm as xlstm_mod
from .layers import (embed, init_embedding, init_mlp, init_rmsnorm, linear,
                     init_linear, mlp, rmsnorm)

# ---------------------------------------------------------------------------
# Parameter initialisation
# ---------------------------------------------------------------------------


def _init_attn_block(key, cfg: ModelConfig, *, cross: bool = False):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p = {
        "ln_attn": init_rmsnorm(cfg.d_model, cfg.param_dtype),
        "attn": attn.init_attention(
            k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
            qkv_bias=cfg.qkv_bias, dtype=cfg.param_dtype),
        "ln_mlp": init_rmsnorm(cfg.d_model, cfg.param_dtype),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.param_dtype),
    }
    if cross:
        p["ln_cross"] = init_rmsnorm(cfg.d_model, cfg.param_dtype)
        p["cross"] = attn.init_attention(
            k3, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
            dtype=cfg.param_dtype)
    return p


def _init_moe_block(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {
        "ln_attn": init_rmsnorm(cfg.d_model, cfg.param_dtype),
        "attn": attn.init_attention(
            k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
            qkv_bias=cfg.qkv_bias, dtype=cfg.param_dtype),
        "ln_mlp": init_rmsnorm(cfg.d_model, cfg.param_dtype),
        "moe": moe_mod.init_moe(
            k2, cfg.d_model, cfg.expert_d_ff, cfg.n_experts,
            cfg.n_shared_experts,
            cfg.expert_d_ff * max(cfg.n_shared_experts, 1),
            cfg.param_dtype),
    }


def _init_ssm_block(key, cfg: ModelConfig):
    return {
        "ln": init_rmsnorm(cfg.d_model, cfg.param_dtype),
        "ssm": ssm_mod.init_ssm(
            key, cfg.d_model, cfg.ssm_state, expand=cfg.ssm_expand,
            conv_k=cfg.ssm_conv, dtype=cfg.param_dtype),
    }


def _init_mlstm_block(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {
        "ln": init_rmsnorm(cfg.d_model, cfg.param_dtype),
        "mlstm": xlstm_mod.init_mlstm(k1, cfg.d_model, cfg.n_heads,
                                      cfg.param_dtype),
        "ln_mlp": init_rmsnorm(cfg.d_model, cfg.param_dtype),
        "mlp": init_mlp(k2, cfg.d_model, 2 * cfg.d_model, cfg.param_dtype),
    }


def _init_slstm_block(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {
        "ln": init_rmsnorm(cfg.d_model, cfg.param_dtype),
        "slstm": xlstm_mod.init_slstm(k1, cfg.d_model, cfg.param_dtype),
        "ln_mlp": init_rmsnorm(cfg.d_model, cfg.param_dtype),
        "mlp": init_mlp(k2, cfg.d_model, 2 * cfg.d_model, cfg.param_dtype),
    }


def _stack(init_fn, key, n: int):
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def init_params(cfg: ModelConfig, key) -> dict:
    ke, kb, kh, kf = jax.random.split(key, 4)
    vocab = cfg.padded_vocab()
    params = {
        "embed": init_embedding(ke, vocab, cfg.d_model, cfg.param_dtype),
        "final_norm": init_rmsnorm(cfg.d_model, cfg.param_dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_linear(kf, cfg.d_model, vocab,
                                        dtype=cfg.param_dtype)
    at = cfg.arch_type
    if at in ("dense", "vlm"):
        params["blocks"] = _stack(
            lambda k: _init_attn_block(k, cfg), kb, cfg.n_layers)
    elif at == "moe":
        params["blocks"] = _stack(
            lambda k: _init_moe_block(k, cfg), kb, cfg.n_layers)
    elif at == "hybrid":
        params["blocks"] = _stack(
            lambda k: _init_ssm_block(k, cfg), kb, cfg.n_layers)
        params["shared_attn"] = _init_attn_block(kh, cfg)
    elif at == "ssm":
        r = cfg.slstm_ratio
        n_super = cfg.n_layers // (r + 1)
        params["mlstm_blocks"] = _stack(
            lambda k: _init_mlstm_block(k, cfg), kb, n_super * r)
        params["slstm_blocks"] = _stack(
            lambda k: _init_slstm_block(k, cfg), kh, n_super)
    elif at == "audio":
        params["encoder"] = _stack(
            lambda k: _init_attn_block(k, cfg), kh, cfg.n_encoder_layers)
        params["enc_norm"] = init_rmsnorm(cfg.d_model, cfg.param_dtype)
        params["blocks"] = _stack(
            lambda k: _init_attn_block(k, cfg, cross=True), kb,
            cfg.n_layers)
    else:
        raise ValueError(f"unknown arch_type {at!r}")
    return params


# ---------------------------------------------------------------------------
# Block application (full-sequence)
# ---------------------------------------------------------------------------


def _attn_kw(cfg: ModelConfig, window):
    return dict(n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
                window=window)


def _attn_block(p, x, cfg: ModelConfig, *, window, causal=True,
                cross_kv=None):
    h = attn.attention_forward(
        p["attn"], rmsnorm(p["ln_attn"], x, cfg.norm_eps),
        causal=causal, **_attn_kw(cfg, window))
    x = x + h
    if cross_kv is not None:
        h = attn.attention_forward(
            p["cross"], rmsnorm(p["ln_cross"], x, cfg.norm_eps),
            kv=cross_kv, **_attn_kw(cfg, None))
        x = x + h
    x = x + mlp(p["mlp"], rmsnorm(p["ln_mlp"], x, cfg.norm_eps))
    return x


def _moe_block(p, x, cfg: ModelConfig, *, window):
    h = attn.attention_forward(
        p["attn"], rmsnorm(p["ln_attn"], x, cfg.norm_eps),
        causal=True, **_attn_kw(cfg, window))
    x = x + h
    y, aux = moe_mod.moe_expert_choice(
        p["moe"], rmsnorm(p["ln_mlp"], x, cfg.norm_eps), top_k=cfg.top_k)
    return x + y


def _ssm_block(p, x, cfg: ModelConfig):
    return x + ssm_mod.ssm_forward(
        p["ssm"], rmsnorm(p["ln"], x, cfg.norm_eps),
        d_state=cfg.ssm_state, expand=cfg.ssm_expand)


def _mlstm_block(p, x, cfg: ModelConfig):
    x = x + xlstm_mod.mlstm_forward(
        p["mlstm"], rmsnorm(p["ln"], x, cfg.norm_eps), n_heads=cfg.n_heads)
    return x + mlp(p["mlp"], rmsnorm(p["ln_mlp"], x, cfg.norm_eps))


def _slstm_block(p, x, cfg: ModelConfig):
    x = x + xlstm_mod.slstm_forward(
        p["slstm"], rmsnorm(p["ln"], x, cfg.norm_eps))
    return x + mlp(p["mlp"], rmsnorm(p["ln_mlp"], x, cfg.norm_eps))


def _scan_blocks(blocks, x, body):
    """lax.scan over stacked layer params with remat on the body; the
    carry (the saved residual) is sharding-constrained so per-layer
    checkpoints don't replicate over the model axis."""
    def step(carry, layer_params):
        return _constrain(body(layer_params, carry)), None
    step = jax.checkpoint(step)
    x, _ = jax.lax.scan(step, _constrain(x), blocks)
    return x


def _backbone(params, x, cfg: ModelConfig, *, window, src=None):
    """Apply the layer stack to embedded inputs x (B, S, D)."""
    at = cfg.arch_type
    if at in ("dense", "vlm"):
        x = _scan_blocks(params["blocks"], x,
                         lambda p, h: _attn_block(p, h, cfg, window=window))
    elif at == "moe":
        x = _scan_blocks(params["blocks"], x,
                         lambda p, h: _moe_block(p, h, cfg, window=window))
    elif at == "hybrid":
        positions = sorted(cfg.attn_positions)
        bounds = [0] + list(positions) + [cfg.n_layers]
        for seg in range(len(bounds) - 1):
            lo, hi = bounds[seg], bounds[seg + 1]
            if hi > lo:
                sub = jax.tree.map(lambda a: a[lo:hi], params["blocks"])
                x = _scan_blocks(sub, x,
                                 lambda p, h: _ssm_block(p, h, cfg))
            if seg < len(bounds) - 2:  # shared attention insertion
                x = _attn_block(params["shared_attn"], x, cfg,
                                window=window)
    elif at == "ssm":
        r = cfg.slstm_ratio
        n_super = cfg.n_layers // (r + 1)
        mshape = jax.tree.map(
            lambda a: a.reshape((n_super, r) + a.shape[1:]),
            params["mlstm_blocks"])

        def super_block(carry, layer_params):
            mp, sp = layer_params
            h = _scan_blocks(mp, carry,
                             lambda p, hh: _mlstm_block(p, hh, cfg))
            h = _slstm_block(sp, h, cfg)
            return h, None
        x, _ = jax.lax.scan(jax.checkpoint(super_block), x,
                            (mshape, params["slstm_blocks"]))
    elif at == "audio":
        x = _scan_blocks(
            params["blocks"], x,
            lambda p, h: _attn_block(p, h, cfg, window=window,
                                     cross_kv=src))
    else:
        raise ValueError(at)
    return x


def encode(params, src_embeds, cfg: ModelConfig):
    """Audio/enc-dec encoder: bidirectional attention over frame
    embeddings (B, Ssrc, D)."""
    x = src_embeds.astype(jnp.dtype(cfg.dtype))
    x = _scan_blocks(params["encoder"], x,
                     lambda p, h: _attn_block(p, h, cfg, window=None,
                                              causal=False))
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _lm_head(params, x, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.tie_embeddings:
        return x.astype(jnp.float32) @ params["embed"]["table"].astype(
            jnp.float32).T
    return x.astype(jnp.float32) @ params["lm_head"]["w"].astype(
        jnp.float32)


def forward_hidden(params, tokens, cfg: ModelConfig, *,
                   prefix: Optional[jnp.ndarray] = None,
                   src: Optional[jnp.ndarray] = None,
                   window: Optional[int] = None) -> jnp.ndarray:
    """Final-norm hidden states (B, S_total, D)."""
    window = window if window is not None else cfg.sliding_window
    dt = jnp.dtype(cfg.dtype)
    x = embed(params["embed"], tokens).astype(dt)
    if prefix is not None:
        x = jnp.concatenate([prefix.astype(dt), x], axis=1)
    enc = encode(params, src, cfg) if src is not None else None
    x = _backbone(params, x, cfg, window=window, src=enc)
    return rmsnorm(params["final_norm"], x, cfg.norm_eps)


def forward(params, tokens, cfg: ModelConfig, *,
            prefix: Optional[jnp.ndarray] = None,
            src: Optional[jnp.ndarray] = None,
            window: Optional[int] = None) -> jnp.ndarray:
    """Full-sequence logits.

    tokens: (B, St) int32. prefix: (B, P, D) stub embeddings prepended
    (vlm). src: (B, Ssrc, D) stub frame embeddings (audio enc-dec).
    window: overrides cfg.sliding_window when not None.
    Returns fp32 logits (B, S_total, V_pad).
    """
    x = forward_hidden(params, tokens, cfg, prefix=prefix, src=src,
                       window=window)
    return _lm_head(params, x, cfg)


def train_loss(params, batch, cfg: ModelConfig, *,
               per_example: bool = False) -> jnp.ndarray:
    """Summed next-token cross entropy over real (non-pad) label
    positions. Sum (not mean) so per-block losses add like the paper's
    f = sum_i f_i; the caller normalises by the global token count.
    ``per_example`` returns per-sequence sums (B,) for the coded
    per-block combine."""
    logits = forward(params, batch["tokens"], cfg,
                     prefix=batch.get("prefix"), src=batch.get("src"))
    labels = batch["labels"]
    if batch.get("prefix") is not None:
        logits = logits[:, batch["prefix"].shape[1]:]
    # mask padded vocab entries out of the softmax (iota mask instead of
    # a scatter: cheaper under a vocab-sharded layout)
    vocab = cfg.padded_vocab()
    if vocab != cfg.vocab_size:
        vmask = jnp.arange(vocab) < cfg.vocab_size
        logits = jnp.where(vmask, logits, -1e30)
    # ll = logits[label] - logsumexp(logits): avoids a second (B, S, V)
    # log-softmax intermediate.
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None],
                                 axis=-1)[..., 0]
    ll = picked - lse
    mask = (labels >= 0).astype(jnp.float32)
    loss = -(ll * mask)
    if per_example:
        return loss.sum(axis=-1)
    return loss.sum()


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode with caches
# ---------------------------------------------------------------------------


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int, *,
                      pos: int = 0, src_len: int = 0) -> dict:
    """Cache pytree for decode_step. ``max_len`` is the KV capacity
    (window size for sliding-window archs). ``pos`` pre-fills the cache
    position (dry-run decodes at a full cache)."""
    at = cfg.arch_type
    dt = cfg.dtype
    if at in ("dense", "vlm", "moe"):
        kv_len = min(max_len, cfg.sliding_window or max_len)
        cache = jax.vmap(
            lambda _: attn.init_cache(batch, kv_len, cfg.n_kv_heads,
                                      cfg.head_dim, dt, pos=pos)
        )(jnp.arange(cfg.n_layers))
        return {"layers": cache}
    if at == "hybrid":
        ssm_states = jax.vmap(
            lambda _: ssm_mod.init_ssm_state(
                batch, cfg.d_model, cfg.ssm_state, expand=cfg.ssm_expand,
                conv_k=cfg.ssm_conv))(jnp.arange(cfg.n_layers))
        kv_len = min(max_len, cfg.sliding_window or max_len)
        n_attn = len(cfg.attn_positions)
        attn_cache = jax.vmap(
            lambda _: attn.init_cache(batch, kv_len, cfg.n_kv_heads,
                                      cfg.head_dim, dt, pos=pos)
        )(jnp.arange(max(n_attn, 1)))
        return {"ssm": ssm_states, "attn": attn_cache}
    if at == "ssm":
        r = cfg.slstm_ratio
        n_super = cfg.n_layers // (r + 1)
        m_states = jax.vmap(
            lambda _: xlstm_mod.init_mlstm_state(batch, cfg.d_model,
                                                 cfg.n_heads)
        )(jnp.arange(n_super * r))
        s_states = jax.vmap(
            lambda _: xlstm_mod.init_slstm_state(batch, cfg.d_model)
        )(jnp.arange(n_super))
        return {"mlstm": m_states, "slstm": s_states}
    if at == "audio":
        kv_len = min(max_len, cfg.sliding_window or max_len)
        cache = jax.vmap(
            lambda _: attn.init_cache(batch, kv_len, cfg.n_kv_heads,
                                      cfg.head_dim, dt, pos=pos)
        )(jnp.arange(cfg.n_layers))
        return {"layers": cache,
                "enc": jnp.zeros((batch, src_len, cfg.d_model),
                                 jnp.dtype(dt))}
    raise ValueError(at)


def _attn_block_decode(p, x, cache, cfg: ModelConfig, *, window,
                       enc=None):
    h, cache = attn.attention_decode(
        p["attn"], rmsnorm(p["ln_attn"], x, cfg.norm_eps), cache,
        **_attn_kw(cfg, window))
    x = x + h
    if enc is not None:
        B = x.shape[0]
        # cross attention over the (precomputed) encoder output
        h = attn.attention_forward(
            p["cross"], rmsnorm(p["ln_cross"], x, cfg.norm_eps),
            kv=enc, causal=False, **_attn_kw(cfg, None))
        x = x + h
    x = x + mlp(p["mlp"], rmsnorm(p["ln_mlp"], x, cfg.norm_eps))
    return x, cache


def _moe_block_decode(p, x, cache, cfg: ModelConfig, *, window):
    h, cache = attn.attention_decode(
        p["attn"], rmsnorm(p["ln_attn"], x, cfg.norm_eps), cache,
        **_attn_kw(cfg, window))
    x = x + h
    y, _ = moe_mod.moe_expert_choice(
        p["moe"], rmsnorm(p["ln_mlp"], x, cfg.norm_eps),
        top_k=cfg.top_k, capacity_factor=float(cfg.n_experts) /
        max(cfg.top_k, 1))
    return x + y, cache


def decode_step(params, token, cache, cfg: ModelConfig, *,
                window: Optional[int] = None):
    """One decode step. token: (B,) int32. Returns (logits (B, V_pad),
    new cache)."""
    window = window if window is not None else cfg.sliding_window
    dt = jnp.dtype(cfg.dtype)
    x = embed(params["embed"], token[:, None]).astype(dt)
    at = cfg.arch_type

    if at in ("dense", "vlm", "moe", "audio"):
        enc = cache.get("enc") if at == "audio" else None
        body = _moe_block_decode if at == "moe" else functools.partial(
            _attn_block_decode, enc=enc) if at == "audio" else \
            _attn_block_decode

        def step(carry, inp):
            layer_p, layer_c = inp
            if at == "moe":
                h, c = _moe_block_decode(layer_p, carry, layer_c, cfg,
                                         window=window)
            elif at == "audio":
                h, c = _attn_block_decode(layer_p, carry, layer_c, cfg,
                                          window=window, enc=enc)
            else:
                h, c = _attn_block_decode(layer_p, carry, layer_c, cfg,
                                          window=window)
            return h, c
        x, new_layers = jax.lax.scan(step, x,
                                     (params["blocks"], cache["layers"]))
        new_cache = dict(cache)
        new_cache["layers"] = new_layers
    elif at == "hybrid":
        positions = sorted(cfg.attn_positions)

        def ssm_step(carry, inp):
            layer_p, layer_s = inp
            h = carry
            y, s = ssm_mod.ssm_decode(
                layer_p["ssm"], rmsnorm(layer_p["ln"], h, cfg.norm_eps),
                layer_s, d_state=cfg.ssm_state, expand=cfg.ssm_expand)
            return h + y, s

        bounds = [0] + list(positions) + [cfg.n_layers]
        new_ssm = []
        new_attn = []
        for seg in range(len(bounds) - 1):
            lo, hi = bounds[seg], bounds[seg + 1]
            if hi > lo:
                sub_p = jax.tree.map(lambda a: a[lo:hi], params["blocks"])
                sub_s = jax.tree.map(lambda a: a[lo:hi], cache["ssm"])
                x, s = jax.lax.scan(ssm_step, x, (sub_p, sub_s))
                new_ssm.append(s)
            if seg < len(bounds) - 2:
                layer_c = jax.tree.map(lambda a: a[seg], cache["attn"])
                x, c = _attn_block_decode(params["shared_attn"], x,
                                          layer_c, cfg, window=window)
                new_attn.append(c)
        new_cache = {
            "ssm": jax.tree.map(lambda *xs: jnp.concatenate(xs), *new_ssm)
            if len(new_ssm) > 1 else new_ssm[0],
            "attn": jax.tree.map(lambda *xs: jnp.stack(xs), *new_attn)
            if new_attn else cache["attn"],
        }
    elif at == "ssm":
        r = cfg.slstm_ratio
        n_super = cfg.n_layers // (r + 1)

        def m_step(carry, inp):
            layer_p, layer_s = inp
            h = carry
            y, s = xlstm_mod.mlstm_decode(
                layer_p["mlstm"],
                rmsnorm(layer_p["ln"], h, cfg.norm_eps), layer_s,
                n_heads=cfg.n_heads)
            h = h + y
            h = h + mlp(layer_p["mlp"],
                        rmsnorm(layer_p["ln_mlp"], h, cfg.norm_eps))
            return h, s

        mshape_p = jax.tree.map(
            lambda a: a.reshape((n_super, r) + a.shape[1:]),
            params["mlstm_blocks"])
        mshape_s = jax.tree.map(
            lambda a: a.reshape((n_super, r) + a.shape[1:]),
            cache["mlstm"])

        def super_step(carry, inp):
            (mp, ms), (sp, ss) = inp[0], inp[1]
            h, new_ms = jax.lax.scan(m_step, carry, (mp, ms))
            y, new_ss = xlstm_mod.slstm_decode(
                sp["slstm"], rmsnorm(sp["ln"], h, cfg.norm_eps), ss)
            h = h + y
            h = h + mlp(sp["mlp"], rmsnorm(sp["ln_mlp"], h, cfg.norm_eps))
            return h, (new_ms, new_ss)

        x, (new_m, new_s) = jax.lax.scan(
            super_step, x,
            ((mshape_p, mshape_s), (params["slstm_blocks"],
                                    cache["slstm"])))
        new_cache = {
            "mlstm": jax.tree.map(
                lambda a: a.reshape((n_super * r,) + a.shape[2:]), new_m),
            "slstm": new_s,
        }
    else:
        raise ValueError(at)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x.astype(jnp.float32) @ params["embed"]["table"].astype(
            jnp.float32).T
    else:
        logits = x.astype(jnp.float32) @ params["lm_head"]["w"].astype(
            jnp.float32)
    return logits[:, 0], new_cache


def prefill(params, tokens, cfg: ModelConfig, *,
            prefix: Optional[jnp.ndarray] = None,
            src: Optional[jnp.ndarray] = None,
            window: Optional[int] = None):
    """Prefill: full forward returning last-position logits (the KV
    cache materialisation is exercised by decode; prefill benchmarks the
    forward compute). The LM head runs on the last position only --
    a (B, S, V) logits tensor at 32k would dominate memory for nothing."""
    x = forward_hidden(params, tokens, cfg, prefix=prefix, src=src,
                       window=window)
    return _lm_head(params, x[:, -1:], cfg)[:, 0]
