"""Attention: GQA with RoPE, blockwise (flash-style) training/prefill
path, and a cached single-token decode path backed by the flash-decode
Pallas kernel.

The training path is a pure-jnp online-softmax over KV blocks driven by
``lax.scan`` so the HLO stays small and the (S x S) score matrix is
never materialised -- mandatory for prefill_32k. Causal and
sliding-window masks are applied per (q-block, kv-block) tile.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import ops as decode_ops
from .layers import init_linear, linear, apply_rope

NEG_INF = -1e30


def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, *, qkv_bias: bool = False,
                   dtype: str = "float32"):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": init_linear(kq, d_model, n_heads * head_dim, bias=qkv_bias,
                          dtype=dtype),
        "wk": init_linear(kk, d_model, n_kv_heads * head_dim,
                          bias=qkv_bias, dtype=dtype),
        "wv": init_linear(kv, d_model, n_kv_heads * head_dim,
                          bias=qkv_bias, dtype=dtype),
        "wo": init_linear(ko, n_heads * head_dim, d_model, dtype=dtype),
    }


def _block_mask(q_pos, k_pos, *, causal: bool, window: Optional[int]):
    """(bq, bk) boolean mask tile from absolute positions."""
    qp = q_pos[:, None]
    kp = k_pos[None, :]
    mask = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        mask &= kp <= qp
    if window is not None:
        mask &= kp > qp - window
    return mask


def blockwise_attention(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        q_offset: int = 0,
                        block_q: int = 512, block_k: int = 512):
    """Online-softmax attention.

    q: (B, Sq, H, Dh); k, v: (B, Sk, KVH, Dh). H % KVH == 0.
    ``q_offset``: absolute position of q[0] (for cross-chunk prefill).
    Returns (B, Sq, H, Dh) in q.dtype.
    """
    B, Sq, H, Dh = q.shape
    Sk, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    scale = Dh ** -0.5

    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    # Pad sequence dims to block multiples (masked out below).
    pq = (-Sq) % block_q
    pk = (-Sk) % block_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq, nk = q.shape[1] // block_q, k.shape[1] // block_k

    # Keep tiles in the input dtype (bf16 on TPU) and accumulate the
    # dots in fp32 via preferred_element_type: halves the HBM/ICI bytes
    # of every attention tile vs f32 operands (EXPERIMENTS.md #Perf).
    qf = q.reshape(B, nq, block_q, KVH, G, Dh)
    kf = k.reshape(B, nk, block_k, KVH, Dh)
    vf = v.reshape(B, nk, block_k, KVH, Dh)

    def q_block(carry_q):
        qi, qb = carry_q          # qb: (B, block_q, KVH, G, Dh)
        q_pos = q_offset + qi * block_q + jnp.arange(block_q)

        def kv_step(carry, kb_idx):
            m, l, acc = carry
            kb = jax.lax.dynamic_index_in_dim(kf, kb_idx, 1, False)
            vb = jax.lax.dynamic_index_in_dim(vf, kb_idx, 1, False)
            k_pos = kb_idx * block_k + jnp.arange(block_k)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            mask = _block_mask(q_pos, k_pos, causal=causal, window=window)
            mask &= (k_pos < Sk)[None, :]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = corr * l + p.sum(-1)
            acc_new = corr[..., None] * acc + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(qb.dtype), vb,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KVH, G, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KVH, G, block_q), jnp.float32)
        a0 = jnp.zeros((B, KVH, G, block_q, Dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out  # (B, KVH, G, block_q, Dh)

    outs = jax.lax.map(q_block, (jnp.arange(nq),
                                 jnp.moveaxis(qf, 1, 0)))
    # outs: (nq, B, KVH, G, block_q, Dh) -> (B, nq*block_q, H, Dh)
    out = jnp.moveaxis(outs, 0, 1).transpose(0, 1, 4, 2, 3, 5)
    out = out.reshape(B, nq * block_q, H, Dh)[:, :Sq]
    return out.astype(q.dtype)


def attention_forward(p, x, *, n_heads: int, n_kv_heads: int,
                      head_dim: int, rope_theta: float,
                      causal: bool = True,
                      window: Optional[int] = None,
                      positions: Optional[jnp.ndarray] = None,
                      kv: Optional[jnp.ndarray] = None,
                      block_q: int = 512, block_k: int = 512):
    """Full-sequence attention (train / prefill / encoder).

    ``kv``: optional cross-attention source (B, Ssrc, D); when given,
    K/V come from it and masks are disabled unless causal is set.
    """
    B, S, _ = x.shape
    src = x if kv is None else kv
    q = linear(p["wq"], x).reshape(B, S, n_heads, head_dim)
    k = linear(p["wk"], src).reshape(B, src.shape[1], n_kv_heads, head_dim)
    v = linear(p["wv"], src).reshape(B, src.shape[1], n_kv_heads, head_dim)
    if positions is None:
        positions = jnp.arange(S)[None, :]
    if kv is None:  # self-attention: RoPE on both
        q = apply_rope(q, jnp.broadcast_to(positions, (B, S)), rope_theta)
        k = apply_rope(k, jnp.broadcast_to(
            jnp.arange(src.shape[1])[None, :], (B, src.shape[1])),
            rope_theta)
    out = blockwise_attention(q, k, v, causal=causal and kv is None,
                              window=window, block_q=block_q,
                              block_k=block_k)
    return linear(p["wo"], out.reshape(B, S, n_heads * head_dim))


def attention_decode(p, x, cache, *, n_heads: int, n_kv_heads: int,
                     head_dim: int, rope_theta: float,
                     window: Optional[int] = None):
    """Single-token decode with KV cache.

    x: (B, 1, D). cache: {"k","v": (B, S, KVH, Dh), "pos": (B,) int32}.
    Writes the new K/V at position pos (mod window size for
    sliding-window caches) and attends over the valid prefix.
    Returns (out (B, 1, D), new_cache).
    """
    B = x.shape[0]
    S_cache = cache["k"].shape[1]
    pos = cache["pos"]  # (B,)
    q = linear(p["wq"], x).reshape(B, 1, n_heads, head_dim)
    k_new = linear(p["wk"], x).reshape(B, 1, n_kv_heads, head_dim)
    v_new = linear(p["wv"], x).reshape(B, 1, n_kv_heads, head_dim)
    q = apply_rope(q, pos[:, None], rope_theta)
    k_new = apply_rope(k_new, pos[:, None], rope_theta)

    slot = pos % S_cache if window is not None else pos
    # Scatter the new entry into the cache (per-batch dynamic slot).
    onehot = jax.nn.one_hot(slot, S_cache, dtype=cache["k"].dtype)
    k_cache = cache["k"] * (1 - onehot)[:, :, None, None] + \
        onehot[:, :, None, None] * k_new.astype(cache["k"].dtype)
    v_cache = cache["v"] * (1 - onehot)[:, :, None, None] + \
        onehot[:, :, None, None] * v_new.astype(cache["v"].dtype)

    lengths = jnp.minimum(pos + 1, S_cache).astype(jnp.int32)
    out = decode_ops.decode_attention(q[:, 0], k_cache, v_cache, lengths)
    out = linear(p["wo"], out.reshape(B, 1, n_heads * head_dim))
    new_cache = {"k": k_cache, "v": v_cache, "pos": pos + 1}
    return out, new_cache


def init_cache(batch: int, max_len: int, n_kv_heads: int, head_dim: int,
               dtype: str = "bfloat16", *, pos: int = 0):
    return {
        "k": jnp.zeros((batch, max_len, n_kv_heads, head_dim),
                       jnp.dtype(dtype)),
        "v": jnp.zeros((batch, max_len, n_kv_heads, head_dim),
                       jnp.dtype(dtype)),
        "pos": jnp.full((batch,), pos, jnp.int32),
    }
