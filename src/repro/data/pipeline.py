"""Data pipeline: synthetic corpora + the coded block partitioner.

The partitioner is where the paper's assignment matrix meets the batch:
a global batch of sequences is split into n data blocks, the blocks are
shuffled by the per-run permutation rho (Algorithm 2's unbiasedness
trick), and each of the m coded workers receives the concatenation of
its assigned blocks (two, for graph schemes). The emitted ``coded
batch`` has a leading machine axis of size m that the distributed
runtime shards over the (pod, data) mesh axes; ``unique_blocks`` is
the deduplicated view of the same partition (one row per block, no
replication) for the mesh-reproduction train path.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.core.assignment import Assignment


@dataclasses.dataclass
class SyntheticLM:
    """Deterministic synthetic token stream (zipf-ish unigram mixture +
    a copy motif so the loss is learnable)."""

    vocab_size: int
    seq_len: int
    seed: int = 0

    def batch(self, global_batch: int, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(self.seed + 7919 * step)
        V = self.vocab_size
        ranks = np.arange(1, V + 1)
        probs = 1.0 / ranks
        probs /= probs.sum()
        toks = rng.choice(V, size=(global_batch, self.seq_len + 1),
                          p=probs)
        # copy motif: second half repeats the first half for 1/4 of rows
        k = global_batch // 4
        half = (self.seq_len + 1) // 2
        toks[:k, half:2 * half] = toks[:k, :half]
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }


@dataclasses.dataclass
class CodedBatcher:
    """Maps a global batch -> per-machine replicated blocks.

    ``assignment``: block-level matrix (n x m). The global batch size
    must be divisible by n; block i is rows [i*bs : (i+1)*bs] after the
    rho shuffle. Output tensors have shape (m, load, block_rows, ...)
    where load = max blocks/machine (graph schemes: exactly 2).
    """

    assignment: Assignment
    shuffle_seed: Optional[int] = 0

    def __post_init__(self):
        n, m = self.assignment.n, self.assignment.m
        load = self.assignment.load
        # machine -> its block ids, padded to `load` by repeating the
        # first block with weight 0 (mask) for irregular assignments.
        ids = np.zeros((m, load), dtype=np.int64)
        mask = np.zeros((m, load), dtype=np.float32)
        for j in range(m):
            bs = self.assignment.blocks_of_machine(j)
            ids[j, :len(bs)] = bs
            mask[j, :len(bs)] = 1.0
            if len(bs) < load:
                ids[j, len(bs):] = bs[0] if len(bs) else 0
        self.block_ids = ids
        self.block_mask = mask
        if self.shuffle_seed is not None:
            rng = np.random.default_rng(self.shuffle_seed)
            self.rho = rng.permutation(n)
        else:
            self.rho = np.arange(n)

    def code_batch(self, batch: Dict[str, np.ndarray]
                   ) -> Dict[str, np.ndarray]:
        n = self.assignment.n
        out = {}
        for k, v in batch.items():
            gb = v.shape[0]
            if gb % n:
                raise ValueError(f"global batch {gb} not divisible by "
                                 f"n={n} blocks")
            bs = gb // n
            blocks = v.reshape((n, bs) + v.shape[1:])
            blocks = blocks[self.rho]          # rho shuffle
            out[k] = blocks[self.block_ids]    # (m, load, bs, ...)
        out["block_weight"] = self.block_mask  # (m, load)
        return out

    def unique_blocks(self, batch: Dict[str, np.ndarray]
                      ) -> Dict[str, np.ndarray]:
        """Dedup emitter: global batch -> (n, block_rows, ...) unique
        blocks after the rho shuffle -- the same data ``code_batch``
        replicates onto machines, emitted once per block. Row i here is
        the data block the assignment's block id i carries, so the
        per-block weights ``v = A @ w``
        (``core.step_weights.block_weights``) line up by construction
        and ``sum_i v_i grad L_i`` equals the replicated machine
        combine without the d-fold recompute.
        """
        n = self.assignment.n
        out = {}
        for k, v in batch.items():
            gb = v.shape[0]
            if gb % n:
                raise ValueError(f"global batch {gb} not divisible by "
                                 f"n={n} blocks")
            bs = gb // n
            out[k] = v.reshape((n, bs) + v.shape[1:])[self.rho]
        return out


@dataclasses.dataclass
class SyntheticRegression:
    """The paper's Section VIII least-squares data, streamed in blocks."""

    N: int
    k: int
    noise: float
    seed: int = 0

    def generate(self):
        rng = np.random.default_rng(self.seed)
        X = rng.normal(size=(self.N, self.k)) / np.sqrt(self.k)
        theta = rng.normal(size=self.k)
        Y = X @ theta + self.noise * rng.normal(size=self.N)
        return X, Y, theta


def data_iterator(source: SyntheticLM, batcher: Optional[CodedBatcher],
                  global_batch: int, steps: int
                  ) -> Iterator[Dict[str, np.ndarray]]:
    for step in range(steps):
        b = source.batch(global_batch, step)
        yield batcher.code_batch(b) if batcher is not None else b
