"""Minimal dependency-free checkpointing: pytree <-> .npz with a JSON
treedef sidecar. Atomic writes (tmp + rename), step-numbered directory
layout, latest-step discovery."""

from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> Tuple[list, list]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path) for path, _ in flat]
    vals = [np.asarray(v) for _, v in flat]
    return keys, vals


def save(path: str, tree: Any, step: Optional[int] = None) -> str:
    """Save pytree to ``path`` (directory). Returns the file written.

    Both files are written atomically (tmp + rename), and the .json
    sidecar lands BEFORE the .npz: checkpoint discovery
    (``saved_steps``/``latest_step``) keys off the .npz, so a kill at
    any point leaves either no discoverable checkpoint or a complete
    one -- never an .npz whose sidecar is missing or torn. That is
    what lets the train driver's crash-resume trust whatever
    ``saved_steps`` reports.
    """
    os.makedirs(path, exist_ok=True)
    name = f"ckpt_{step:08d}" if step is not None else "ckpt"
    keys, vals = _flatten_with_paths(tree)
    fd, tmpj = tempfile.mkstemp(dir=path, suffix=".tmp.json")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump({"keys": keys, "step": step}, f)
        os.replace(tmpj, os.path.join(path, name + ".json"))
    finally:
        if os.path.exists(tmpj):
            os.remove(tmpj)
    # np.savez appends ".npz" unless the name already ends with it, so
    # the temp file must carry the suffix or the rename moves an empty
    # file.
    fd, tmp = tempfile.mkstemp(dir=path, suffix=".tmp.npz")
    os.close(fd)
    try:
        np.savez(tmp, **{f"a{i}": v for i, v in enumerate(vals)})
        os.replace(tmp, os.path.join(path, name + ".npz"))
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)
    return os.path.join(path, name + ".npz")


def saved_steps(path: str) -> list:
    """Sorted step numbers of the checkpoints in ``path``."""
    if not os.path.isdir(path):
        return []
    steps = []
    for f in os.listdir(path):
        if f.startswith("ckpt_") and f.endswith(".npz"):
            steps.append(int(f[5:13]))
    return sorted(steps)


def latest_step(path: str) -> Optional[int]:
    steps = saved_steps(path)
    return steps[-1] if steps else None


def restore_fallback(path: str, templates,
                     max_step: Optional[int] = None
                     ) -> Tuple[int, str, Any]:
    """Restore the newest *intact* checkpoint at or before ``max_step``.

    Crash-resume must survive more than a clean kill: a chaos kill (or
    disk-full, or a torn copy) can leave a discoverable ``.npz`` that
    is truncated mid-zip, a corrupt sidecar, or a foreign layout. This
    walks the saved steps newest-first, trying ``restore_any`` at
    each, and falls back past any checkpoint that fails to load for
    *any* reason -- a torn file must never wedge the resume when an
    older intact step exists. Returns (step, label, state); raises
    ValueError listing every per-step failure only when no checkpoint
    loads at all.
    """
    steps = [s for s in saved_steps(path)
             if max_step is None or s <= max_step]
    failures = []
    for s in reversed(steps):
        try:
            label, state = restore_any(path, templates, step=s)
            return s, label, state
        except Exception as e:  # noqa: BLE001 -- torn files raise
            # anything from BadZipFile to zlib.error to ValueError;
            # every load failure means "try the previous step".
            failures.append(f"step {s}: {type(e).__name__}: {e}")
    raise ValueError("no intact checkpoint found: "
                     + ("; ".join(failures) or "no steps saved"))


def restore_any(path: str, templates, step: Optional[int] = None
                ) -> Tuple[str, Any]:
    """Restore into the first matching template of an ordered list.

    ``templates`` is a sequence of (label, like) pairs tried in order;
    returns (label, restored). The checkpoint layout has grown over
    PRs (params-only -> {params, opt_state} -> {params, opt_state,
    compress}), and the driver must accept any of them: a mismatched
    template fails ``restore``'s leaf-count/shape validation
    (ValueError) or the npz key lookup (KeyError), and the next one is
    tried. Raises ValueError listing every failure if none match --
    never silently loads a torn or foreign checkpoint.
    """
    failures = []
    for label, like in templates:
        try:
            return label, restore(path, like, step=step)
        except (ValueError, KeyError) as e:
            failures.append(f"{label}: {e}")
    raise ValueError("no checkpoint template matched: "
                     + "; ".join(failures))


def restore(path: str, like: Any, step: Optional[int] = None) -> Any:
    """Restore into the structure of ``like`` (shapes validated)."""
    if step is None:
        step = latest_step(path)
    name = f"ckpt_{step:08d}" if step is not None else "ckpt"
    data = np.load(os.path.join(path, name + ".npz"))
    with open(os.path.join(path, name + ".json")) as f:
        meta = json.load(f)
    vals = [data[f"a{i}"] for i in range(len(meta["keys"]))]
    flat_like, treedef = jax.tree_util.tree_flatten(like)
    if len(flat_like) != len(vals):
        raise ValueError(f"checkpoint has {len(vals)} leaves, "
                         f"expected {len(flat_like)}")
    for a, b in zip(flat_like, vals):
        if tuple(a.shape) != tuple(b.shape):
            raise ValueError(f"shape mismatch {a.shape} vs {b.shape}")
    return jax.tree_util.tree_unflatten(treedef, vals)
