"""Assignments, stragglers, theory bounds, debiasing, coded GD."""

import numpy as np
import pytest

from repro.core import (Assignment, BernoulliStragglers,
                        FixedCountStragglers, LeastSquares,
                        MarkovStragglers, adjacency_assignment,
                        adversarial_mask, bernoulli_assignment,
                        debias_assignment, decode, estimate_mean_alpha,
                        expander_assignment, frc_assignment, gcod,
                        graph_assignment, monte_carlo_error,
                        normalized_error, random_regular_graph, sgd_alg,
                        theory, uncoded_assignment)


def test_assignment_properties():
    A = expander_assignment(24, 4, vertex_transitive=False, seed=0)
    assert A.n == 12 and A.m == 24
    assert A.replication_factor == pytest.approx(4.0)
    assert A.load == 2
    # every machine holds exactly the two endpoints of its edge
    for j in range(A.m):
        assert len(A.blocks_of_machine(j)) == 2
    F = frc_assignment(12, 3)
    assert F.n == 4 and F.load == 1
    assert F.replication_factor == pytest.approx(3.0)
    U = uncoded_assignment(5)
    assert U.replication_factor == 1.0
    B = bernoulli_assignment(16, 32, 4, seed=0)
    assert (B.A.sum(axis=1) >= 1).all()


def test_straggler_models():
    rng = np.random.default_rng(0)
    for model in (BernoulliStragglers(m=200, p=0.3),
                  FixedCountStragglers(m=200, p=0.3),
                  MarkovStragglers(m=200, p=0.3)):
        alives = np.stack([model.sample(rng) for _ in range(300)])
        frac = 1 - alives.mean()
        assert 0.2 < frac < 0.4, (type(model).__name__, frac)
    # fixed count is exact
    fc = FixedCountStragglers(m=200, p=0.3)
    assert (~fc.sample(rng)).sum() == 60


def test_markov_stragglers_are_stagnant():
    rng = np.random.default_rng(0)
    m = MarkovStragglers(m=500, p=0.2, persistence=20.0)
    a1 = m.sample(rng)
    a2 = m.sample(rng)
    # consecutive masks highly correlated (stagnation)
    agree = (a1 == a2).mean()
    assert agree > 0.9


def test_adversarial_attack_graph_isolates_blocks():
    A = expander_assignment(48, 4, vertex_transitive=False, seed=0)
    alive = adversarial_mask(A, 0.25)
    assert (~alive).sum() <= 12
    res = decode(A, alive, method="optimal")
    err = normalized_error(res.alpha)
    # attack approaches the p/2 lower bound and respects Cor V.2
    lam = A.graph.spectral_expansion()
    assert err <= theory.adversarial_bound_graph(0.25, 4, lam) + 1e-9
    assert err >= 0.5 * theory.adversarial_lower_bound_graph(0.25)


def test_adversarial_frc_much_worse():
    F = frc_assignment(48, 4)
    A = expander_assignment(48, 4, vertex_transitive=False, seed=0)
    p = 0.25
    err_f = normalized_error(
        decode(F, adversarial_mask(F, p), method="optimal").alpha)
    err_a = normalized_error(
        decode(A, adversarial_mask(A, p), method="optimal").alpha)
    assert err_f > err_a


def test_debias_construction():
    """Prop B.1: the debiased scheme has E[alpha-hat] ~ 1."""
    A = bernoulli_assignment(16, 64, 4, seed=0)
    p = 0.2
    dec = lambda alive: decode(A, alive, method="optimal").alpha
    mean_alpha = estimate_mean_alpha(A, dec, p, trials=300)
    eps = float(np.mean((mean_alpha - 1) ** 2)) + 0.01
    if eps >= 0.5:
        pytest.skip("scheme too biased for Prop B.1 premise")
    A_hat = debias_assignment(A, mean_alpha, eps)
    assert A_hat.n == A.n
    assert A_hat.load <= 2 * A.load


def test_gcod_converges_and_optimal_beats_fixed():
    prob = LeastSquares.synthetic(N=128, k=16, noise=0.1, n_blocks=16,
                                  seed=0)
    A = expander_assignment(24, 3, vertex_transitive=False, seed=1)
    model = BernoulliStragglers(m=24, p=0.2)
    tr_o = gcod(prob, A, model, steps=60, lr=3e-3, method="optimal",
                p=0.2, seed=0)
    assert tr_o.errors[-1] < tr_o.errors[0] * 0.1
    tr_f = gcod(prob, A, model, steps=60, lr=3e-3, method="fixed",
                p=0.2, seed=0)
    assert tr_o.errors[-1] <= tr_f.errors[-1] * 1.5


def test_sgd_alg_equivalence():
    """Algorithm 3 with beta ~ P_{alpha*} is stochastically equivalent
    to Algorithm 2 (same seeds -> same straggler draws -> same path)."""
    prob = LeastSquares.synthetic(N=64, k=8, noise=0.1, n_blocks=8,
                                  seed=0)
    A = expander_assignment(16, 4, vertex_transitive=False, seed=1)
    p = 0.2

    rng_masks = np.random.default_rng(42)
    masks = [rng_masks.random(A.m) >= p for _ in range(20)]
    it = iter(masks)

    def sample_beta(_rng):
        return decode(A, next(it), method="optimal").alpha

    tr_sgd = sgd_alg(prob, sample_beta, steps=20, lr=1e-3, seed=7)

    class Replay:
        def __init__(self):
            self.it = iter(masks)

        def sample(self, rng):
            return next(self.it)

    tr_gcod = gcod(prob, A, Replay(), steps=20, lr=1e-3,
                   method="optimal", p=p, seed=7)
    np.testing.assert_allclose(tr_sgd.errors, tr_gcod.errors, rtol=1e-8)


def test_monte_carlo_matches_bounds():
    A = expander_assignment(24, 3, vertex_transitive=False, seed=1)
    r = monte_carlo_error(A, 0.2, trials=300, method="optimal")
    lb = theory.lower_bound_any_decoding(0.2, 3)
    assert r["mean_error"] >= lb * 0.8
    assert r["mean_error"] <= 10 * lb  # near-optimal, not 1/d-far
