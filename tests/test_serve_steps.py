"""``make_prefill_step`` / ``make_serve_step`` across the decode-state
families the serving engine pages: KV (dense attention), SSM+KV
(hybrid), and xLSTM recurrent state.

The contract the cache pool leans on: replaying a shared prompt prefix
through the serve step then greedy-decoding N tokens is equivalent to
running the full-sequence forward at every step -- same logits at the
prefix boundary (to fp tolerance; incremental attention reorders the
reductions) and the *same greedy tokens* thereafter. The engine's own
8-virtual-device mesh path over these steps is exercised end to end by
tests/test_smoke_serve.py via the CLI subprocess.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.dist import coded_train
from repro.models import model as M

# one config per decode-state family: KV / SSM+KV hybrid / xLSTM
FAMILY_ARCHS = ["qwen1.5-4b", "zamba2-1.2b", "xlstm-1.3b"]

B, P, N, MAX_LEN = 2, 6, 4, 24


def _setup(arch):
    cfg = get_config(arch).smoke_variant()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jnp.asarray(
        np.random.default_rng(1).integers(0, cfg.vocab_size, (B, P)),
        jnp.int32)
    return cfg, params, tokens


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_prefill_step_is_last_position_forward(arch):
    cfg, params, tokens = _setup(arch)
    prefill = coded_train.make_prefill_step(cfg)
    full = M.forward(params, tokens, cfg)[:, -1]
    np.testing.assert_allclose(
        np.asarray(prefill(params, {"tokens": tokens})),
        np.asarray(full), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_prefix_replay_then_decode_matches_full_forward(arch):
    """Prefill-then-N-decode-steps == full-sequence forward on the
    shared prefix: same boundary logits, then bit-equal greedy tokens
    step for step."""
    cfg, params, tokens = _setup(arch)
    serve_step = jax.jit(coded_train.make_serve_step(cfg))
    V = cfg.vocab_size

    cache = M.init_decode_cache(cfg, B, MAX_LEN)
    logits = None
    for t in range(P):
        logits, cache = serve_step(params, tokens[:, t], cache)
    boundary = M.prefill(params, tokens, cfg)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(boundary),
                               rtol=2e-3, atol=2e-3)

    seq = tokens
    for _ in range(N):
        tok_dec = jnp.argmax(logits[:, :V], axis=-1).astype(jnp.int32)
        # oracle: re-run the whole sequence through the full forward
        tok_full = jnp.argmax(
            M.forward(params, seq, cfg)[:, -1, :V], axis=-1)
        np.testing.assert_array_equal(np.asarray(tok_dec),
                                      np.asarray(tok_full))
        seq = jnp.concatenate([seq, tok_dec[:, None]], axis=1)
        logits, cache = serve_step(params, tok_dec, cache)


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_decode_rows_are_independent(arch):
    """The property the pool's slot paging (and the scheduling
    bit-identity pin) rests on: a row's decode stream is unchanged by
    what the other rows compute."""
    cfg, params, tokens = _setup(arch)
    serve_step = jax.jit(coded_train.make_serve_step(cfg))

    def decode_row0(other_row):
        toks = jnp.stack([tokens[0], other_row])
        cache = M.init_decode_cache(cfg, B, MAX_LEN)
        out = []
        logits = None
        for t in range(P):
            logits, cache = serve_step(params, toks[:, t], cache)
        for _ in range(3):
            tok = jnp.argmax(logits[:, :cfg.vocab_size],
                             axis=-1).astype(jnp.int32)
            out.append(int(tok[0]))
            logits, cache = serve_step(params, tok, cache)
        return out

    assert decode_row0(tokens[1]) == decode_row0(tokens[1][::-1])
