"""Adaptive per-step decoding regression suite (PR 10).

Pins the ``core.adaptive`` layer and its ``CodingRuntime`` wiring:

* the online estimator converges on seeded bernoulli AND markov
  streams (p-hat to the true straggle fraction, persistence-hat to the
  chain's mean sojourn);
* the adaptive policy matches the omniscient method choice after
  burn-in, and its replayed regret beats every static fixed-decoding
  policy on a seeded markov stream (the BENCH_sweep.json acceptance,
  at test scale);
* ``CodingRuntime(adaptive="always_optimal")`` is BIT-IDENTICAL to the
  existing non-adaptive optimal path -- masks, weights, scale, and
  decode_calls -- through both ``step_weights`` and
  ``weights_lookahead`` (the anchor that keeps the adaptive layer a
  pure extension, not a behaviour change).
"""

import numpy as np
import pytest

from repro.configs.base import CodingConfig
from repro.core import (AdaptivePolicy, OnlineStragglerEstimator,
                        StaticPolicy, expander_assignment, make_policy,
                        policy_regret_report, replay_policy)
from repro.core.step_weights import (make_straggler_model,
                                     sample_mask_stream)
from repro.dist import coded_train


def markov_stream(assignment, p, persistence, steps, seed):
    model = make_straggler_model(assignment, "markov", p,
                                 persistence=persistence)
    _, masks = sample_mask_stream(assignment, model, steps=steps,
                                  shuffle=False,
                                  rng=np.random.default_rng(seed))
    return masks


# ---------------------------------------------------------------------------
# Estimator convergence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("p", [0.05, 0.2, 0.4])
def test_estimator_converges_on_bernoulli_stream(p):
    m, steps = 16, 600
    rng = np.random.default_rng(11)
    est = OnlineStragglerEstimator(m, prior_p=0.1)
    for _ in range(steps):
        est.observe(rng.random(m) >= p)
    e = est.estimate()
    # ~9600 machine-rounds: the MC error of p-hat is ~sqrt(p/9600).
    assert e.p_hat == pytest.approx(p, abs=0.03)
    assert e.steps == steps
    # i.i.d. stream: both rows of the transition matrix are the
    # marginal (straggling tomorrow is independent of today).
    assert e.transition_hat[0, 1] == pytest.approx(p, abs=0.05)
    assert e.transition_hat[1, 1] == pytest.approx(p, abs=0.08)


def test_estimator_converges_on_markov_stream():
    A = expander_assignment(16, 4)
    p, persistence = 0.2, 6.0
    est = OnlineStragglerEstimator(16, prior_p=0.1)
    for mask in markov_stream(A, p, persistence, steps=1500, seed=5):
        est.observe(mask)
    e = est.estimate()
    assert e.p_hat == pytest.approx(p, abs=0.05)
    # Mean straggle sojourn = persistence; the chain's exit rate is
    # 1/persistence, so transition_hat[1, 0] ~ 1/6.
    assert e.persistence_hat == pytest.approx(persistence, rel=0.35)
    assert e.transition_hat[1, 1] > e.transition_hat[0, 1], \
        "stagnant chain: straggling must predict straggling"


def test_estimator_rejects_bad_parameters():
    with pytest.raises(ValueError):
        OnlineStragglerEstimator(0)
    with pytest.raises(ValueError):
        OnlineStragglerEstimator(4, prior_p=1.0)
    with pytest.raises(ValueError):
        OnlineStragglerEstimator(4, prior_weight=0)
    est = OnlineStragglerEstimator(4)
    with pytest.raises(ValueError):
        est.observe(np.ones(5, dtype=bool))


# ---------------------------------------------------------------------------
# Policy decisions
# ---------------------------------------------------------------------------


def test_policy_matches_omniscient_after_burn_in():
    """Above the switch threshold the omniscient method is 'optimal'
    (it minimizes the decode error pointwise); the adaptive policy
    must settle on it once the estimate has converged."""
    A = expander_assignment(12, 4)
    masks = markov_stream(A, p=0.2, persistence=8.0, steps=300, seed=42)
    replay = replay_policy(A, masks, AdaptivePolicy())
    burn_in = 50
    assert set(replay["methods"][burn_in:]) == {"optimal"}
    # ... and therefore matches the omniscient errors pointwise there.
    omni = replay_policy(A, masks, StaticPolicy(method="optimal"))
    np.testing.assert_array_equal(replay["errors"][burn_in:],
                                  omni["errors"][burn_in:])
    # Lookahead tracks the chain's persistence (clipped to the cap).
    assert replay["lookaheads"][-1] >= 4


def test_policy_picks_fixed_below_threshold():
    est = OnlineStragglerEstimator(12, prior_p=0.0, prior_weight=1.0)
    for _ in range(50):
        est.observe(np.ones(12, dtype=bool))  # nobody ever straggles
    decision = AdaptivePolicy(threshold=0.05).decide(est.estimate())
    assert decision.method == "fixed"
    assert decision.p < 0.05


def test_adaptive_regret_beats_static_fixed_policies():
    """The BENCH_sweep.json acceptance at test scale: on a seeded
    markov stream the adaptive policy's post-burn-in regret (vs the
    always-optimal omniscient baseline) beats EVERY static
    fixed-decoding policy, including fixed at the true p."""
    A = expander_assignment(12, 4)
    masks = markov_stream(A, p=0.15, persistence=8.0, steps=300,
                          seed=42)
    policies = {"adaptive": AdaptivePolicy()}
    for p_f in (0.05, 0.15, 0.3):
        policies[f"fixed(p={p_f})"] = StaticPolicy(method="fixed", p=p_f)
    report = policy_regret_report(A, masks, policies, burn_in=50)
    assert report["omniscient"]["regret"] == 0.0
    for name, row in report.items():
        assert row["regret"] >= -1e-12, f"{name}: beat the omniscient?"
    best_fixed = min(v["regret"] for k, v in report.items()
                     if k.startswith("fixed"))
    assert report["adaptive"]["regret"] < best_fixed


def test_make_policy_specs():
    assert isinstance(make_policy("adaptive"), AdaptivePolicy)
    always = make_policy("always_optimal", p=0.3)
    assert isinstance(always, StaticPolicy)
    assert always.method == "optimal" and always.p == 0.3
    assert make_policy("always_fixed").method == "fixed"
    custom = AdaptivePolicy(threshold=0.2)
    assert make_policy(custom) is custom
    with pytest.raises(ValueError, match="policy"):
        make_policy("sometimes_optimal")


# ---------------------------------------------------------------------------
# CodingRuntime wiring
# ---------------------------------------------------------------------------


def _cfg(**kw):
    base = dict(scheme="expander", replication=4, decoding="optimal",
                straggler_model="markov", straggler_p=0.15, seed=3)
    base.update(kw)
    return CodingConfig(**base)


def test_runtime_always_optimal_bit_identical_per_step():
    rt_plain = coded_train.CodingRuntime(_cfg(), 12)
    rt_adapt = coded_train.CodingRuntime(_cfg(), 12,
                                         adaptive="always_optimal")
    assert rt_plain.scale == rt_adapt.scale
    for _ in range(25):
        w1, a1 = rt_plain.step_weights()
        w2, a2 = rt_adapt.step_weights()
        np.testing.assert_array_equal(a1, a2)
        np.testing.assert_array_equal(w1, w2)
    assert rt_plain.decode_calls == rt_adapt.decode_calls


def test_runtime_always_optimal_bit_identical_lookahead():
    rt_plain = coded_train.CodingRuntime(_cfg(), 12)
    rt_adapt = coded_train.CodingRuntime(_cfg(), 12,
                                         adaptive="always_optimal")
    for _ in range(4):
        W1, A1 = rt_plain.weights_lookahead(5)
        W2, A2 = rt_adapt.weights_lookahead(5)
        np.testing.assert_array_equal(A1, A2)
        np.testing.assert_array_equal(W1, W2)
    assert rt_plain.decode_calls == rt_adapt.decode_calls


def test_runtime_adaptive_estimates_and_counts_decisions():
    rt = coded_train.CodingRuntime(_cfg(straggler_p=0.25), 12,
                                   adaptive="adaptive")
    for _ in range(40):
        w, alive = rt.step_weights()
        assert np.all(w[~alive] == 0)
    assert sum(rt.decision_counts.values()) == 40
    est = rt.estimator.estimate()
    assert 0.0 < est.p_hat < 1.0
    assert rt.suggested_lookahead() >= 1
    assert rt.last_decision is not None
    # p=0.25 is far above the switch threshold: the policy must have
    # settled on optimal decoding.
    assert rt.decision_counts["optimal"] > 30


def test_runtime_adaptive_cache_keys_separate_methods():
    """An adaptive runtime may decode the SAME mask under different
    decisions; the memo must never alias them."""
    rt = coded_train.CodingRuntime(_cfg(), 12)
    mask = np.array([True] * 10 + [False] * 2)
    w_opt = rt.weights_for(mask, method="optimal")
    w_fix = rt.weights_for(mask, method="fixed", p=0.25)
    assert rt.decode_calls == 2
    assert not np.array_equal(w_opt, w_fix)
    # Second lookups hit the memo.
    np.testing.assert_array_equal(
        rt.weights_for(mask, method="fixed", p=0.25), w_fix)
    assert rt.decode_calls == 2


def test_elastic_reassign_carries_adaptive_policy():
    rt = coded_train.CodingRuntime(_cfg(), 12, adaptive="adaptive")
    rt2 = coded_train.elastic_reassign(rt, [0, 1], generation=1)
    assert rt2.policy is not None
    assert rt2.m == 10
    assert rt2.estimator.m == 10
