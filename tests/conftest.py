"""Test configuration. NOTE: no XLA_FLAGS here by design -- smoke tests
and benches must see the real (1-CPU) device; only the dry-run script
forces 512 placeholder devices."""

import pytest


def pytest_addoption(parser):
    parser.addoption("--runslow", action="store_true", default=False,
                     help="run slow end-to-end tests")


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: slow end-to-end test")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip = pytest.mark.skip(reason="needs --runslow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)
