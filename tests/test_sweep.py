"""Grid-sweep engine vs per-point ``monte_carlo_error``.

The acceptance contract: under the shared-uniform protocol every sweep
row is bit-identical to the corresponding per-point call (mean/std for
any cov method; cov_norm too when both sides use the dense path), warm
starts change nothing, and the matrix-free covariance path stays within
1e-8 of the dense SVD on these scales.
"""

import numpy as np
import pytest

from repro.core import (bernoulli_uniforms, batched_alpha, decode_grid,
                        bernoulli_assignment, expander_assignment,
                        frc_assignment, graph_assignment,
                        monte_carlo_error, random_regular_graph,
                        sweep_error)
from repro.core.batched_decoding import _HAS_JAX
from repro.kernels.spectral_matvec import ops as sm_ops

RNG = np.random.default_rng(0)
P_GRID = (0.05, 0.1, 0.2, 0.3, 0.45)
# float64 contract off-TPU; coarse bound when the f32 Pallas path runs
COV_TOL = 1e-8 if not sm_ops.uses_pallas() else 5e-3


def test_sweep_bit_identical_to_per_point():
    A = expander_assignment(24, 3, vertex_transitive=False, seed=1)
    for method in ("optimal", "fixed"):
        rows = sweep_error(A, P_GRID, trials=40, method=method, seed=9)
        for p, row in zip(P_GRID, rows):
            mc = monte_carlo_error(A, p, trials=40, method=method, seed=9)
            assert row["p"] == p
            assert row["mean_error"] == mc["mean_error"]
            assert row["std_error"] == mc["std_error"]
            assert row["cov_norm"] == mc["cov_norm"]  # dense at n=16


def test_sweep_order_and_warm_start_invariance():
    A = expander_assignment(16, 4, vertex_transitive=False, seed=0)
    shuffled = (0.3, 0.05, 0.45, 0.1)
    warm = sweep_error(A, shuffled, trials=30, seed=2, warm_start=True)
    cold = sweep_error(A, shuffled, trials=30, seed=2, warm_start=False)
    assert warm == cold
    ascending = sweep_error(A, tuple(sorted(shuffled)), trials=30, seed=2)
    by_p = {r["p"]: r for r in ascending}
    for r in warm:
        assert r == by_p[r["p"]]


@pytest.mark.skipif(not _HAS_JAX, reason="jax not installed")
def test_sweep_jax_backend_matches_numpy():
    g = random_regular_graph(16, 4, seed=0)
    A = graph_assignment(g)
    r_np = sweep_error(A, (0.1, 0.3, 0.6), trials=20, seed=3,
                       backend="numpy")
    r_jx = sweep_error(A, (0.1, 0.3, 0.6), trials=20, seed=3,
                       backend="jax")
    assert r_np == r_jx


def test_sweep_cov_lanczos_close_to_dense():
    A = expander_assignment(24, 3, vertex_transitive=False, seed=1)
    dense = sweep_error(A, P_GRID, trials=50, seed=4, cov_method="dense")
    lanc = sweep_error(A, P_GRID, trials=50, seed=4, cov_method="lanczos")
    for d_, l_ in zip(dense, lanc):
        assert d_["mean_error"] == l_["mean_error"]
        assert abs(d_["cov_norm"] - l_["cov_norm"]) <= \
            COV_TOL * max(d_["cov_norm"], 1.0)


def test_decode_grid_matches_batched_alpha_per_point():
    u = bernoulli_uniforms(24, 16, seed=5)
    grid = (0.2, 0.5)
    masks = np.stack([u >= p for p in grid])
    # graph scheme (warm start exercised: descending-p given order)
    A = expander_assignment(24, 3, vertex_transitive=False, seed=1)
    out = decode_grid(A, masks[::-1], warm_start=True)[::-1]
    for i, p in enumerate(grid):
        np.testing.assert_array_equal(
            out[i], batched_alpha(A, masks[i], method="optimal"))
    # FRC closed form and pseudoinverse fallback dispatch per point
    F = frc_assignment(24, 3)
    out_f = decode_grid(F, masks)
    B = bernoulli_assignment(8, 24, 3, seed=0)
    out_b = decode_grid(B, masks)
    for i in range(len(grid)):
        np.testing.assert_array_equal(
            out_f[i], batched_alpha(F, masks[i], method="optimal"))
        np.testing.assert_allclose(
            out_b[i], batched_alpha(B, masks[i], method="optimal"),
            atol=1e-12)
    # fixed decoding needs the per-point p
    out_fixed = decode_grid(A, masks, method="fixed", p_grid=grid)
    for i, p in enumerate(grid):
        np.testing.assert_array_equal(
            out_fixed[i], batched_alpha(A, masks[i], method="fixed", p=p))


def test_decode_grid_validation():
    A = expander_assignment(16, 4, vertex_transitive=False, seed=0)
    with pytest.raises(ValueError, match="trials"):
        decode_grid(A, np.ones((2, 16), bool))
    with pytest.raises(ValueError, match="p_grid"):
        decode_grid(A, np.ones((2, 3, 16), bool), method="fixed",
                    p_grid=(0.1,))
    with pytest.raises(ValueError, match="per-point p"):
        decode_grid(A, np.ones((2, 3, 16), bool), method="fixed")
    # warm_start rejects non-nested masks instead of silently
    # corrupting alphas with a stale label seed
    u = bernoulli_uniforms(16, 3, seed=8)
    nested = np.stack([u >= p for p in (0.6, 0.2)])  # descending p
    decode_grid(A, nested, warm_start=True)  # ok
    with pytest.raises(ValueError, match="nested"):
        decode_grid(A, nested[::-1], warm_start=True)  # ascending p
    rng = np.random.default_rng(0)
    indep = rng.random((2, 3, 16)) >= 0.5  # independent masks
    with pytest.raises(ValueError, match="nested"):
        decode_grid(A, indep, warm_start=True)


def test_monte_carlo_error_cov_method_param():
    A = expander_assignment(16, 4, vertex_transitive=False, seed=0)
    d_ = monte_carlo_error(A, 0.3, trials=40, seed=1)
    l_ = monte_carlo_error(A, 0.3, trials=40, seed=1,
                           cov_method="lanczos")
    assert d_["mean_error"] == l_["mean_error"]
    assert abs(d_["cov_norm"] - l_["cov_norm"]) <= \
        COV_TOL * max(d_["cov_norm"], 1.0)
