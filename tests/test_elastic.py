"""Elastic re-assignment differential pins.

The robustness rung of the repo's differential-testing convention: an
elastic re-assignment mid-run (re-draw the code over the survivors,
keep the live {params, opt_state}) must be **bit-identical** to a
fresh run launched on the survivors from the same state. Both sides
derive the generation coding through the same pure function
(``elastic_coding``: generation-derived seed, deterministic
replication degradation), data batches are a pure function of the step
index, and the replayed mask stream is shared -- so every device input
matches bitwise and the trajectories cannot diverge.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.step_weights as sw
from repro.configs import CodingConfig, get_config
from repro.data.pipeline import CodedBatcher, SyntheticLM
from repro.dist import coded_train
from repro.models import model as M
from repro.optim import optimizers as opt_mod


def _coding(**kw):
    kw.setdefault("scheme", "expander")
    kw.setdefault("replication", 2)
    kw.setdefault("seed", 0)
    return CodingConfig(**kw)


# ---------------------------------------------------------------------------
# elastic_seed / elastic_coding
# ---------------------------------------------------------------------------


def test_elastic_seed_pure_and_distinct():
    assert coded_train.elastic_seed(7, 0) == 7
    assert coded_train.elastic_seed(7, 1) == \
        coded_train.elastic_seed(7, 1)
    seeds = {coded_train.elastic_seed(7, g) for g in range(5)}
    assert len(seeds) == 5
    with pytest.raises(ValueError):
        coded_train.elastic_seed(7, -1)


def test_elastic_coding_keeps_feasible_replication():
    base = _coding(replication=4)
    # 2m' = 12: d = 4 still divides -> kept.
    assert coded_train.elastic_coding(base, 6, 1).replication == 4
    # 2m' = 10: 4 and 3 do not divide -> degrade to the cycle d = 2.
    assert coded_train.elastic_coding(base, 5, 1).replication == 2
    # FRC needs d | m'.
    frc = _coding(scheme="frc", replication=2)
    assert coded_train.elastic_coding(frc, 6, 1).replication == 2
    assert coded_train.elastic_coding(frc, 5, 1).replication == 1
    # A single survivor degenerates to uncoded.
    solo = coded_train.elastic_coding(base, 1, 2)
    assert solo.scheme == "uncoded" and solo.replication == 1
    with pytest.raises(ValueError):
        coded_train.elastic_coding(base, 0, 1)


def test_elastic_coding_seed_follows_generation():
    base = _coding(seed=3)
    g2 = coded_train.elastic_coding(base, 5, 2)
    assert g2.seed == coded_train.elastic_seed(3, 2)
    # Deterministic: same inputs, same config.
    assert g2 == coded_train.elastic_coding(base, 5, 2)


# ---------------------------------------------------------------------------
# elastic_reassign
# ---------------------------------------------------------------------------


def test_reassign_matches_fresh_runtime_exactly():
    """The heart of the differential pin: the re-assigned runtime and
    a freshly constructed survivors' runtime agree on the assignment
    matrix, debias scale, and decode weights for every mask."""
    rt0 = coded_train.CodingRuntime(_coding(), 6)
    rt1 = coded_train.elastic_reassign(rt0, [2], generation=1)
    fresh = coded_train.CodingRuntime(
        coded_train.elastic_coding(rt0.coding, 5, 1), 5)
    assert rt1.m == fresh.m == 5
    np.testing.assert_array_equal(rt1.assignment.A, fresh.assignment.A)
    assert rt1.scale == fresh.scale
    rng = np.random.default_rng(9)
    for _ in range(6):
        mask = rng.random(5) > 0.3
        np.testing.assert_array_equal(rt1.weights_for(mask),
                                      fresh.weights_for(mask))


def test_reassign_chains_across_generations():
    rt0 = coded_train.CodingRuntime(_coding(), 6)
    rt1 = coded_train.elastic_reassign(rt0, [0], generation=1)
    rt2 = coded_train.elastic_reassign(rt1, [3], generation=2)
    assert rt2.m == 4
    # Generation 2 derives from generation 1's coding -- the same
    # chain a fresh run walking the recorded reassignment history
    # would reconstruct.
    expect = coded_train.elastic_coding(rt1.coding, 4, 2)
    assert rt2.coding == expect


def test_reassign_validates_dead_ids():
    rt0 = coded_train.CodingRuntime(_coding(), 4)
    with pytest.raises(ValueError):
        coded_train.elastic_reassign(rt0, [1, 1], generation=1)
    with pytest.raises(ValueError):
        coded_train.elastic_reassign(rt0, [4], generation=1)
    with pytest.raises(ValueError):
        coded_train.elastic_reassign(rt0, [-1], generation=1)


def test_reassign_carries_mask_source():
    rt0 = coded_train.CodingRuntime(_coding(), 4)
    obs = sw.ObservedMaskSource(3)
    rt1 = coded_train.elastic_reassign(rt0, [1], generation=1,
                                       mask_source=obs)
    assert rt1.mask_source is obs
    with pytest.raises(ValueError):
        # Source sized for the wrong survivor count.
        coded_train.elastic_reassign(rt0, [1], generation=1,
                                     mask_source=sw.ObservedMaskSource(4))


# ---------------------------------------------------------------------------
# Trajectory pin: elastic continuation == fresh run on survivors
# ---------------------------------------------------------------------------


def _run_steps(cfg, opt, runtime, src, params, opt_state, start, steps,
               bs=2):
    """A miniature of the train driver's per-generation loop: dedup
    path, jitted step, masks from the runtime's source."""
    A = runtime.assignment
    batcher = CodedBatcher(A, shuffle_seed=0)
    step_fn = jax.jit(coded_train.make_train_step(
        cfg, opt, dedup=True,
        norm_scale=coded_train.dedup_norm_scale(A),
        alpha_weights=coded_train.alpha_bar_weights(A)))
    losses = []
    for step in range(start, start + steps):
        raw = src.batch(A.n * bs, step)
        blocks = {k: jnp.asarray(v)
                  for k, v in batcher.unique_blocks(raw).items()}
        w, _ = runtime.step_weights()
        v = runtime.block_weights(w)
        params, opt_state, met = step_fn(
            params, opt_state, blocks, jnp.asarray(v, jnp.float32))
        losses.append(float(met["loss"]))
    return params, opt_state, losses


def _tree_bit_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_elastic_trajectory_bit_identical_to_fresh_run():
    cfg = get_config("granite-3-8b").smoke_variant()
    coding = _coding(seed=0)
    opt = opt_mod.get_optimizer("adamw", 1e-3)
    src = SyntheticLM(cfg.vocab_size, 16, seed=0)
    rng = np.random.default_rng(11)
    masks0 = rng.random((3, 4)) > 0.2          # generation 0, m = 4
    masks1 = rng.random((3, 3)) > 0.2          # generation 1, m' = 3

    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = opt.init(params)

    # Elastic side: 3 steps on m = 4, machine 1 dies, re-assign,
    # 3 more steps on the survivors.
    rt0 = coded_train.CodingRuntime(
        coding, 4, mask_source=sw.ReplayedMaskSource(masks0))
    p_mid, o_mid, _ = _run_steps(cfg, opt, rt0, src, params, opt_state,
                                 start=0, steps=3)
    # Host snapshot of the mid-run state: the "same state" both the
    # elastic continuation and the fresh run resume from.
    p_mid = jax.device_get(p_mid)
    o_mid = jax.device_get(o_mid)
    rt1 = coded_train.elastic_reassign(
        rt0, [1], generation=1,
        mask_source=sw.ReplayedMaskSource(masks1))
    p_el, o_el, l_el = _run_steps(cfg, opt, rt1, src, p_mid, o_mid,
                                  start=3, steps=3)

    # Fresh side: a brand-new driver launched on the 3 survivors with
    # the same {params, opt_state} and the same observed mask stream,
    # deriving its coding through the same pure generation function.
    rt_fresh = coded_train.CodingRuntime(
        coded_train.elastic_coding(coding, 3, 1), 3,
        mask_source=sw.ReplayedMaskSource(masks1))
    p_fr, o_fr, l_fr = _run_steps(cfg, opt, rt_fresh, src, p_mid,
                                  o_mid, start=3, steps=3)

    assert l_el == l_fr
    _tree_bit_equal(p_el, p_fr)
    _tree_bit_equal(o_el, o_fr)


def test_elastic_uncoded_degeneration_still_trains():
    """Shrinking an expander below the 3-edge cycle (m' <= 2) flips to
    the uncoded scheme; the runtime must still produce usable
    weights."""
    rt0 = coded_train.CodingRuntime(_coding(), 3)
    rt1 = coded_train.elastic_reassign(rt0, [0], generation=1)
    assert rt1.m == 2 and rt1.coding.scheme == "uncoded"
    w = rt1.weights_for(np.array([True, True]))
    assert w.shape == (2,) and np.isfinite(w).all()
    rt2 = coded_train.elastic_reassign(rt1, [1], generation=2)
    assert rt2.m == 1 and rt2.coding.scheme == "uncoded"
    w = rt2.weights_for(np.array([True]))
    assert w.shape == (1,) and np.isfinite(w).all()


def test_elastic_coding_is_frozen_replace():
    """elastic_coding must not mutate the base config (frozen
    dataclass replace) -- generation 0 stays reconstructible."""
    base = _coding(seed=4)
    before = dataclasses.asdict(base)
    coded_train.elastic_coding(base, 3, 1)
    assert dataclasses.asdict(base) == before
