"""Fast end-to-end smoke: the real ``repro.launch.train`` driver on
the 8-virtual-device mesh.

Runs as a subprocess because the virtual-device count must enter
XLA_FLAGS before jax initialises (conftest keeps the test process on
the real 1-CPU device by design). The driver itself asserts the
decreasing window-mean loss and prints a JSON summary line; this test
checks the exit status and the summary. Two runs keep both execution
paths in tier-1: the async dedup pipeline (lookahead decoding,
buffered metrics) and the replicated path through the manual
``coded_allreduce`` collective. The longer variants stay behind
--runslow in test_system.py.
"""

import json
import os
import subprocess
import sys

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _driver_proc(*extra, env_extra=None, check=True):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env.update(env_extra or {})
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.train",
         "--arch", "qwen1.5-4b", "--steps", "12", "--seq-len", "32",
         "--block-size", "2", "--straggler-p", "0.2", *extra],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=420)
    if check:
        assert proc.returncode == 0, proc.stdout + proc.stderr
    return proc


def _run_driver(*extra, env_extra=None):
    proc = _driver_proc(*extra, env_extra=env_extra)
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_train_driver_smoke_async_dedup_pipeline():
    summary = _run_driver("--dedup", "--lookahead", "6",
                          "--log-every", "4")
    assert summary["steps"] == 12
    assert summary["m_workers"] == 4  # (4, 2) mesh over 8 virtual devices
    assert summary["path"] == "dedup"
    assert summary["collective"] == "gspmd"
    # decode memoisation sanity: at most one decode per sampled mask
    # (the lookahead-vs-per-step batching itself is pinned in
    # tests/test_coding_runtime.py)
    assert summary["decode_calls"] <= 12
    assert np.isfinite(summary["first_loss"])
    assert np.isfinite(summary["last_loss"])
    # the window-mean decrease is asserted inside train.main; reaching
    # the summary line means the full coded path (batcher -> decode ->
    # sharded step) ran and learned
    assert summary["last_loss"] < summary["first_loss"] + 1.0


def test_train_driver_smoke_manual_collective():
    summary = _run_driver("--collective", "manual", "--lookahead", "4",
                          "--log-every", "6")
    assert summary["steps"] == 12
    assert summary["path"] == "replicated"  # manual implies replicated
    assert summary["collective"] == "manual"
    assert np.isfinite(summary["last_loss"])
    assert summary["last_loss"] < summary["first_loss"] + 1.0


def test_train_driver_smoke_streaming_manual():
    """--stream-chunk routes the manual collective through the
    lax.scan streaming accumulator end to end (on the driver's m = 4
    workers over 4 data shards the scan is a single chunk -- the
    multi-chunk differential lives in tests/test_streaming.py)."""
    summary = _run_driver("--collective", "manual", "--stream-chunk",
                          "1", "--lookahead", "4", "--log-every", "6")
    assert summary["steps"] == 12
    assert summary["collective"] == "manual"
    assert summary["stream_chunk"] == 1
    assert np.isfinite(summary["last_loss"])
    assert summary["last_loss"] < summary["first_loss"] + 1.0


def test_train_driver_smoke_fsdp():
    """--fsdp swaps the replicated param placement for the
    worker-sharded fsdp_specs; the training stream itself must be
    unaffected (same algebra, different layout)."""
    summary = _run_driver("--dedup", "--fsdp", "--lookahead", "6",
                          "--log-every", "4")
    assert summary["steps"] == 12
    assert summary["fsdp"] is True
    assert np.isfinite(summary["last_loss"])
    assert summary["last_loss"] < summary["first_loss"] + 1.0


def test_train_driver_smoke_compressed_sign_packed():
    """The packed 1-bit wire codec end to end on the dedup path: the
    8-per-byte payload must clear the 0.05x comm acceptance bar."""
    summary = _run_driver("--dedup", "--compress", "sign_packed",
                          "--lookahead", "6", "--log-every", "4")
    assert summary["steps"] == 12
    assert summary["compress"] == "sign_packed"
    assert np.isfinite(summary["last_loss"])
    assert summary["last_loss"] < summary["first_loss"] + 1.0
    ratio = (summary["comm_bytes_per_step"]
             / summary["comm_bytes_per_step_float32"])
    assert ratio <= 0.05, \
        f"sign_packed comm ratio {ratio:.4f} exceeds 0.05"


def test_stream_chunk_requires_manual_collective():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--steps", "2",
         "--stream-chunk", "1"],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=120)
    assert proc.returncode != 0
    assert "--collective manual" in proc.stderr


def test_train_driver_chaos_kill_reassigns_and_converges(tmp_path):
    """The CI chaos smoke: kill one of the 4 coded machines at step 3
    of a 12-step run. The heartbeat monitor must declare it dead after
    --dead-after consecutive misses, the driver must elastically
    re-assign over the 3 survivors, and the final loss must land
    within tolerance of the clean (no-failure) run -- straggler
    sampling off on both sides so chaos is the only difference."""
    log = str(tmp_path / "events.json")
    clean = _run_driver("--straggler-p", "0", "--log-every", "4")
    summary = _run_driver("--straggler-p", "0", "--log-every", "4",
                          "--chaos", "kill:1@3", "--event-log", log)
    chaos = summary["chaos"]
    assert chaos["dead_machines"] == [1]
    assert chaos["steps_to_detect"] == {"1": 3}
    assert chaos["m_final"] == 3 and chaos["generations"] == 2
    assert len(chaos["reassignments"]) == 1
    re = chaos["reassignments"][0]
    assert re["dead"] == [1] and re["survivors"] == [0, 2, 3]
    kinds = [e["kind"] for e in chaos["events"]]
    assert kinds == ["straggle", "dead", "reassign"]
    # Pre-kill steps see identical inputs (same seed, no stragglers):
    # the streams must match bitwise until the first missed heartbeat.
    assert summary["losses"][:3] == clean["losses"][:3]
    # Post-reassignment convergence: same noise floor as the clean run.
    assert np.isfinite(summary["last_loss"])
    assert abs(summary["last_loss"] - clean["last_loss"]) < 0.6, (
        f"chaos run ended at {summary['last_loss']:.3f}, clean at "
        f"{clean['last_loss']:.3f}")
    # The structured event log is a JSON artifact mirroring the
    # summary's chaos object.
    with open(log) as f:
        assert json.load(f) == chaos


def test_train_driver_chaos_transient_delay_no_reassign():
    """A bounded delay window straggles a machine (misses, backoff,
    recovery) without ever declaring it dead: no re-assignment, all
    machines alive at the end."""
    summary = _run_driver("--straggler-p", "0", "--log-every", "4",
                          "--chaos", "delay:2@4-6:10")
    chaos = summary["chaos"]
    assert chaos["dead_machines"] == []
    assert chaos["reassignments"] == []
    assert chaos["m_final"] == 4 and chaos["generations"] == 1
    kinds = {e["kind"] for e in chaos["events"]}
    assert "dead" not in kinds
    assert np.isfinite(summary["last_loss"])


def test_batch_thread_failure_kills_driver_with_traceback():
    """Pipeline-hardening regression: an exception on the batch-builder
    worker thread (injected at a double-buffered step) must propagate
    to the main loop and exit the driver with the original error, not
    hang or train on with stale data."""
    proc = _driver_proc("--steps", "6", "--log-every", "2",
                        env_extra={"REPRO_FAIL_BATCH_AT": "3"},
                        check=False)
    assert proc.returncode != 0
    assert "injected batch failure at step 3" in proc.stderr
    assert "RuntimeError" in proc.stderr


def test_chaos_flag_cross_checks():
    proc = _driver_proc("--chaos", "kill:1@3", "--ckpt-dir", "/tmp/x",
                        check=False)
    assert proc.returncode != 0
    assert "--ckpt-dir" in proc.stderr
    proc = _driver_proc("--event-log", "/tmp/x.json", check=False)
    assert proc.returncode != 0
    assert "--chaos" in proc.stderr
    proc = _driver_proc("--chaos", "kill:1@3", "--no-dedup",
                        check=False)
    assert proc.returncode != 0
    assert "dedup" in proc.stderr


def test_train_driver_smoke_compressed_int8():
    """The compression-composed execution model end to end: int8
    quantization + error feedback + the fused quantized combine on the
    dedup path, with the comm-bytes accounting in the summary. The
    driver's own decreasing-loss assertion runs inside the subprocess;
    the 4x wire shrink (int8 payload + scale sideband vs float32
    gradients) must beat the 0.3x acceptance bar."""
    summary = _run_driver("--dedup", "--compress", "int8",
                          "--lookahead", "6", "--log-every", "4")
    assert summary["steps"] == 12
    assert summary["path"] == "dedup"
    assert summary["compress"] == "int8"
    assert np.isfinite(summary["last_loss"])
    assert summary["last_loss"] < summary["first_loss"] + 1.0
    ratio = (summary["comm_bytes_per_step"]
             / summary["comm_bytes_per_step_float32"])
    assert ratio <= 0.3, f"int8 comm ratio {ratio:.3f} exceeds 0.3"
