"""Cross-scheme property suite for the scheme zoo (PR 10).

The zoo's contract, per the differential-testing convention:

* every construction is d-regular / load-balanced (constant per-block
  replication AND constant per-machine load);
* decoded weights vanish on dead machines, for every decoder;
* ``batched_alpha`` == scalar ``decode`` alphas bit-for-bit on the new
  schemes (they dispatch to the pseudoinverse / graph paths -- the
  batched engine must not diverge from the scalar oracle);
* ``sweep_campaign`` over ``scheme_zoo_entries`` == per-point
  ``monte_carlo_error`` bit-for-bit (the shared-draw protocol);
* invalid constructions are rejected at construction time with clear
  errors (the FixedCountStragglers-style edge-case satellite).

Deterministic seeded checks always run; hypothesis fuzzes the
parameter space on top (CI guards hypothesis is installed).
"""

import numpy as np
import pytest

from repro.core import (batched_alpha, bibd_assignment,
                        cyclic_mds_assignment, decode, monte_carlo_error,
                        random_matching_assignment,
                        random_matching_regular_graph, scheme_zoo_entries,
                        sweep_campaign)
from repro.core.step_weights import batched_step_weights, step_weights

try:
    from hypothesis import given, settings, strategies as st

    HAS_HYP = True
except ImportError:  # pragma: no cover - CI fails loudly via the guard
    HAS_HYP = False


def zoo_assignments():
    return [
        cyclic_mds_assignment(12, 4),
        cyclic_mds_assignment(7, 3),
        bibd_assignment(7, 3),                    # Fano plane
        bibd_assignment(13, 4),                   # PG(2, 3)
        bibd_assignment(9, 3, design="affine"),   # AG(2, 3)
        random_matching_assignment(12, 4, seed=0),
        random_matching_assignment(8, 2, seed=1),
    ]


# ---------------------------------------------------------------------------
# Regularity / load balance
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("A", zoo_assignments(),
                         ids=lambda a: a.name)
def test_zoo_schemes_are_regular_and_load_balanced(A):
    loads = A.A.sum(axis=0)          # blocks per machine
    replication = A.A.sum(axis=1)    # machines per block
    assert len(set(loads.tolist())) == 1, f"{A.name}: unbalanced load"
    assert len(set(replication.tolist())) == 1, \
        f"{A.name}: unbalanced replication"
    assert np.all((A.A == 0) | (A.A == 1))


def test_zoo_shared_machine_count():
    """The whole q=3 zoo shares m=12 -- the precondition for the one-
    draw campaign protocol."""
    entries = scheme_zoo_entries(3, seed=0)
    assert len(entries) == 5
    assert {e.assignment.m for e in entries} == {12}
    labels = [e.resolved_label() for e in entries]
    assert len(set(labels)) == 5


# ---------------------------------------------------------------------------
# Dead machines get zero weight
# ---------------------------------------------------------------------------


def check_dead_weights_zero(A, seed):
    rng = np.random.default_rng(seed)
    for trial in range(10):
        alive = rng.random(A.m) >= 0.35
        for method in ("optimal", "fixed"):
            w, _ = step_weights(A, alive, method=method, p=0.35)
            assert np.all(w[~alive] == 0), \
                f"{A.name} {method}: dead machine got weight"


@pytest.mark.parametrize("A", zoo_assignments(),
                         ids=lambda a: a.name)
def test_dead_machine_weights_zero(A):
    check_dead_weights_zero(A, seed=0)


if HAS_HYP:

    @settings(max_examples=25, deadline=None)
    @given(st.sampled_from(range(len(zoo_assignments()))),
           st.integers(0, 2 ** 16))
    def test_dead_machine_weights_zero_hyp(idx, seed):
        check_dead_weights_zero(zoo_assignments()[idx], seed)


# ---------------------------------------------------------------------------
# Batched == scalar decoders
# ---------------------------------------------------------------------------


def check_batched_matches_scalar(A, seed, trials=16):
    rng = np.random.default_rng(seed)
    masks = rng.random((trials, A.m)) >= 0.3
    for method, p in (("optimal", 0.0), ("fixed", 0.3)):
        batched = batched_alpha(A, masks, method=method, p=p)
        scalar = np.stack([
            decode(A, a, method=method, p=p).alpha for a in masks])
        np.testing.assert_array_equal(
            batched, scalar,
            err_msg=f"{A.name} {method}: batched != scalar alphas")
        W, alphas = batched_step_weights(A, masks, method=method, p=p)
        scalar_w = np.stack([
            decode(A, a, method=method, p=p).w for a in masks])
        np.testing.assert_array_equal(W, scalar_w)


@pytest.mark.parametrize("A", zoo_assignments(),
                         ids=lambda a: a.name)
def test_batched_matches_scalar(A):
    check_batched_matches_scalar(A, seed=1)


if HAS_HYP:

    @settings(max_examples=15, deadline=None)
    @given(st.sampled_from(range(len(zoo_assignments()))),
           st.integers(0, 2 ** 16))
    def test_batched_matches_scalar_hyp(idx, seed):
        check_batched_matches_scalar(zoo_assignments()[idx], seed,
                                     trials=8)


# ---------------------------------------------------------------------------
# Campaign == per-point Monte-Carlo, bit for bit
# ---------------------------------------------------------------------------


def check_zoo_campaign_differential(seed, trials, p_grid):
    entries = scheme_zoo_entries(3, seed=0)
    camp = sweep_campaign(entries, p_grid, trials=trials, seed=seed,
                          cov=False)
    for e in entries:
        label = e.resolved_label()
        for i, p in enumerate(p_grid):
            oracle = monte_carlo_error(e.assignment, p, trials=trials,
                                       seed=seed, method=e.method,
                                       cov=False)
            row = camp[label][i]
            assert row["mean_error"] == oracle["mean_error"], \
                f"{label} p={p}: campaign mean != monte_carlo_error"
            assert row["std_error"] == oracle["std_error"], \
                f"{label} p={p}: campaign std != monte_carlo_error"


def test_zoo_campaign_bit_identical_to_per_point():
    check_zoo_campaign_differential(seed=7, trials=64,
                                    p_grid=[0.05, 0.2, 0.4])


if HAS_HYP:

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 2 ** 16),
           st.lists(st.sampled_from([0.05, 0.1, 0.15, 0.25, 0.35, 0.45]),
                    min_size=1, max_size=4, unique=True))
    def test_zoo_campaign_bit_identical_hyp(seed, p_grid):
        check_zoo_campaign_differential(seed, trials=16, p_grid=p_grid)


# ---------------------------------------------------------------------------
# Rejection paths (construction-time validation)
# ---------------------------------------------------------------------------


def test_cyclic_mds_rejects_bad_parameters():
    with pytest.raises(ValueError, match="d"):
        cyclic_mds_assignment(5, 6)     # d > m
    with pytest.raises(ValueError, match="d"):
        cyclic_mds_assignment(5, 0)     # d < 1


def test_bibd_rejects_bad_parameters():
    with pytest.raises(ValueError, match="[dD]ivisib|lambda"):
        bibd_assignment(8, 3)           # k(k-1) does not divide v-1
    with pytest.raises(ValueError, match="k"):
        bibd_assignment(4, 1)           # k < 2
    with pytest.raises(ValueError, match="k"):
        bibd_assignment(4, 4)           # k >= v
    with pytest.raises(ValueError, match="affine"):
        bibd_assignment(7, 3, design="affine")   # v != k^2
    with pytest.raises(ValueError, match="prime"):
        bibd_assignment(16, 4, design="affine")  # q=4 not prime
    with pytest.raises(ValueError, match="design"):
        bibd_assignment(7, 3, design="mystery")


def test_random_matching_rejects_bad_parameters():
    with pytest.raises(ValueError, match="d"):
        random_matching_assignment(12, 13)   # d > m
    with pytest.raises(ValueError, match="d"):
        random_matching_assignment(12, 0)    # d < 1
    with pytest.raises(ValueError, match=r"d \| 2m"):
        random_matching_assignment(9, 4)     # d does not divide 2m
    with pytest.raises(ValueError, match="even"):
        random_matching_regular_graph(7, 3)  # odd vertex count
    with pytest.raises(ValueError, match="d"):
        random_matching_regular_graph(6, 6)  # d >= n
