"""Failure-detection unit layer: heartbeat monitor semantics, chaos
spec parsing / injection determinism, mask-source protocol, and the
straggler-model edge cases the elastic path can reach (all-straggling
draws, budgets past the survivor count, permanently dead machines).
"""

import numpy as np
import pytest

import repro.core.step_weights as sw
from repro.configs import CodingConfig
from repro.core.stragglers import (AdversarialStragglers,
                                   FixedCountStragglers)
from repro.dist import chaos, coded_train, failures


def _monitor(**kw):
    kw.setdefault("deadline", 1.0)
    kw.setdefault("dead_after", 3)
    return failures.HeartbeatMonitor(4, **kw)


# ---------------------------------------------------------------------------
# HeartbeatMonitor
# ---------------------------------------------------------------------------


def test_all_on_time_all_alive_no_events():
    mon = _monitor()
    for step in range(5):
        alive = mon.observe(step, np.full(4, 0.5))
        assert alive.all()
    assert mon.events == []
    assert mon.steps_to_detect() == {}


def test_miss_is_excluded_immediately_even_within_grace():
    """Grace delays the straggle *event*, never the mask: a machine
    that missed its deadline contributed no gradient this round."""
    mon = _monitor(grace=2)
    times = np.full(4, 0.5)
    times[2] = np.inf
    alive = mon.observe(0, times)
    assert not alive[2] and alive[[0, 1, 3]].all()
    # Within grace: no event yet.
    assert mon.drain_events() == []


def test_straggle_event_after_grace_and_backoff_widens_deadline():
    mon = _monitor(grace=1, backoff=2.0, max_backoff=4, dead_after=10)
    times = np.full(4, 0.5)
    times[1] = 5.0                      # late, not absent
    assert mon.current_deadline(1) == 1.0
    mon.observe(0, times)               # miss 1: in grace, no event
    assert mon.drain_events() == []
    assert mon.current_deadline(1) == 2.0   # backoff doubled
    mon.observe(1, times)               # miss 2: straggle event
    ev = mon.drain_events()
    assert [e.kind for e in ev] == ["straggle"]
    assert ev[0].machine == 1 and ev[0].detail["since_step"] == 0
    assert mon.current_deadline(1) == 4.0
    gone = times.copy()
    gone[1] = np.inf
    for step in range(2, 8):
        mon.observe(step, gone)
    # Cap: at most max_backoff doublings.
    assert mon.current_deadline(1) == 1.0 * 2.0 ** 4
    # A late-but-under-widened-deadline report is on time again.
    alive = mon.observe(8, times)
    assert alive[1]
    assert [e.kind for e in mon.drain_events()] == ["recover"]
    assert mon.current_deadline(1) == 1.0


def test_dead_after_k_consecutive_misses_and_stays_dead():
    mon = _monitor(dead_after=3)
    dead_t = np.full(4, 0.5)
    dead_t[0] = np.nan                  # nan == no heartbeat
    for step in range(3):
        alive = mon.observe(step, dead_t)
        assert not alive[0]
    kinds = [e.kind for e in mon.events]
    assert kinds == ["straggle", "dead"]
    assert mon.is_dead(0)
    assert mon.dead_machines.tolist() == [0]
    assert mon.steps_to_detect() == {0: 3}
    # A zombie heartbeat is ignored: dead is permanent.
    alive = mon.observe(3, np.full(4, 0.1))
    assert not alive[0] and alive[1:].all()
    assert [e.kind for e in mon.drain_events()] == \
        ["straggle", "dead"]


def test_recovery_interrupts_death_countdown():
    mon = _monitor(dead_after=3)
    miss = np.full(4, 0.5)
    miss[2] = np.inf
    mon.observe(0, miss)
    mon.observe(1, miss)
    mon.observe(2, np.full(4, 0.5))     # back under deadline
    mon.observe(3, miss)
    mon.observe(4, miss)
    assert not mon.is_dead(2)           # never 3 consecutive


def test_monitor_validation():
    with pytest.raises(ValueError):
        failures.HeartbeatMonitor(0)
    with pytest.raises(ValueError):
        failures.HeartbeatMonitor(4, deadline=0.0)
    with pytest.raises(ValueError):
        failures.HeartbeatMonitor(4, backoff=0.5)
    mon = _monitor()
    with pytest.raises(ValueError):
        mon.observe(0, np.zeros(3))


def test_events_serialize_to_plain_json_types():
    mon = _monitor(grace=0, dead_after=2)
    t = np.full(4, 0.5)
    t[3] = np.inf
    mon.observe(0, t)
    mon.observe(1, t)
    out = failures.events_to_json(mon.events)
    assert [e["kind"] for e in out] == ["straggle", "dead"]
    for e in out:
        assert isinstance(e["step"], int)
        assert isinstance(e["machine"], int)
        assert all(not isinstance(v, np.generic)
                   for v in e["detail"].values())


# ---------------------------------------------------------------------------
# SurvivorMap
# ---------------------------------------------------------------------------


def test_survivor_map_remove_and_localize():
    surv = failures.SurvivorMap(5)
    assert surv.alive_count == 5
    surv.remove([1, 3])
    assert surv.survivors.tolist() == [0, 2, 4]
    mask = np.array([True, False, False, True, True])
    assert surv.localize(mask).tolist() == [True, False, True]
    with pytest.raises(ValueError):
        surv.remove([1])                # already gone
    with pytest.raises(ValueError):
        surv.localize(np.ones(3, dtype=bool))  # original-m shape only


# ---------------------------------------------------------------------------
# Chaos spec + injector
# ---------------------------------------------------------------------------


def test_parse_chaos_spec_grammar():
    evs = chaos.parse_chaos_spec(
        "kill:1@3; rack:0,2@5; delay:3@4-8:20; flap:2@6-12:2", m=4)
    assert [e.kind for e in evs] == ["kill", "rack", "delay", "flap"]
    assert evs[0].machines == (1,) and evs[0].start == 3
    assert evs[0].end is None and evs[0].active(99)
    assert evs[1].machines == (0, 2)
    assert evs[2].magnitude == 20.0
    assert evs[2].active(4) and not evs[2].active(8)  # end exclusive
    assert evs[3].magnitude == 2.0
    # Defaults: delay x10, flap period 1.
    d, f = chaos.parse_chaos_spec("delay:0@1-2;flap:1@1-3", m=2)
    assert d.magnitude == 10.0 and f.magnitude == 1.0


@pytest.mark.parametrize("bad", [
    "explode:1@3",            # unknown kind
    "kill:9@3",               # machine out of range
    "delay:0@5-5",            # empty window
    "kill:x@3",               # non-integer machine
])
def test_parse_chaos_spec_rejects(bad):
    with pytest.raises(ValueError):
        chaos.parse_chaos_spec(bad, m=4)


def test_injector_deterministic_and_fault_shapes():
    sched = chaos.parse_chaos_spec("kill:1@2;delay:2@3-5:10;flap:0@2-6",
                                   m=4)
    a = chaos.ChaosInjector(sched, 4, seed=7)
    b = chaos.ChaosInjector(sched, 4, seed=7)
    for step in range(8):
        np.testing.assert_array_equal(a.completion_times(step),
                                      b.completion_times(step))
    c = chaos.ChaosInjector(sched, 4, seed=7)
    healthy_hi = c.base_time * (1 + c.jitter)
    for step in range(8):
        t = c.completion_times(step)
        assert np.isinf(t[1]) == (step >= 2)          # kill
        if 3 <= step < 5:                             # delay window
            assert t[2] > healthy_hi
        else:
            assert t[2] <= healthy_hi
        if 2 <= step < 6:                             # flap: 1-step
            dark = (step - 2) % 2 == 0                # alternation
            assert np.isinf(t[0]) == dark
        assert np.isfinite(t[3]) and t[3] <= healthy_hi
    np.testing.assert_array_equal(c.killed(1), [0, 0, 0, 0])
    np.testing.assert_array_equal(c.killed(2), [0, 1, 0, 0])


def test_random_schedule_stays_in_bounds():
    evs = chaos.random_schedule(6, 20, seed=3, n_events=4)
    assert len(evs) == 4
    assert sum(e.kind == "kill" for e in evs) <= 1
    for e in evs:
        assert all(0 <= j < 6 for j in e.machines)
        assert 0 <= e.start < 20
        if e.end is not None:
            assert e.start < e.end <= 20


def test_injector_feeds_monitor_end_to_end():
    """The composed loop: injected kill -> missed heartbeats ->
    straggle -> dead, with detection latency == dead_after."""
    sched = chaos.parse_chaos_spec("kill:2@4", m=4)
    inj = chaos.ChaosInjector(sched, 4, seed=0)
    mon = failures.HeartbeatMonitor(4, deadline=0.5, dead_after=3)
    for step in range(10):
        mon.observe(step, inj.completion_times(step))
    assert mon.dead_machines.tolist() == [2]
    assert mon.steps_to_detect() == {2: 3}
    assert mon.dead_at[2] == 6          # kill@4 + 3 misses - 1


# ---------------------------------------------------------------------------
# Mask sources
# ---------------------------------------------------------------------------


def test_sampled_source_matches_direct_model_stream():
    cfg = CodingConfig(scheme="expander", replication=2, seed=5)
    rt = coded_train.CodingRuntime(cfg, 6)
    model = coded_train.CodingRuntime(cfg, 6).model
    rng = np.random.default_rng(cfg.seed)
    for _ in range(8):
        np.testing.assert_array_equal(rt.mask_source.next_mask(),
                                      model.sample(rng))


def test_replayed_source_order_skip_and_exhaustion():
    masks = np.array([[1, 0], [0, 1], [1, 1]], dtype=bool)
    src = sw.ReplayedMaskSource(masks)
    assert src.next_mask().tolist() == [True, False]
    src.skip(1)
    assert src.next_mask().tolist() == [True, True]
    with pytest.raises(RuntimeError):
        src.next_mask()
    with pytest.raises(RuntimeError):
        sw.ReplayedMaskSource(masks).skip(4)


def test_observed_source_fifo_and_errors():
    src = sw.ObservedMaskSource(3)
    src.push(np.array([True, False, True]))
    src.push(np.array([False, True, True]))
    assert src.next_mask().tolist() == [True, False, True]
    assert src.next_mask().tolist() == [False, True, True]
    with pytest.raises(RuntimeError):
        src.next_mask()                 # nothing observed yet
    with pytest.raises(RuntimeError):
        src.skip(1)                     # cannot fast-forward reality
    with pytest.raises(ValueError):
        src.push(np.ones(4, dtype=bool))


def test_runtime_rejects_mismatched_mask_source():
    cfg = CodingConfig(scheme="expander", replication=2)
    with pytest.raises(ValueError):
        coded_train.CodingRuntime(cfg, 4,
                                  mask_source=sw.ObservedMaskSource(5))


def test_runtime_weights_from_observed_masks():
    cfg = CodingConfig(scheme="expander", replication=2, seed=0)
    rt = coded_train.CodingRuntime(
        cfg, 4, mask_source=sw.ObservedMaskSource(4))
    alive_in = np.array([True, False, True, True])
    rt.mask_source.push(alive_in)
    w, alive = rt.step_weights()
    np.testing.assert_array_equal(alive, alive_in)
    assert w.shape == (4,) and w[1] == 0.0
    assert np.isfinite(w).all() and w[alive_in].sum() > 0


# ---------------------------------------------------------------------------
# Straggler-model edge cases (satellite: elastic-shrink extremes)
# ---------------------------------------------------------------------------


def test_fixed_count_all_straggling_and_over_budget():
    rng = np.random.default_rng(0)
    assert not FixedCountStragglers(4, 1.0).sample(rng).any()
    # p > 1 must clamp to all-dead, not raise from an oversized draw.
    assert not FixedCountStragglers(4, 1.5).sample(rng).any()
    alive = FixedCountStragglers(4, 0.5).sample(rng)
    assert (~alive).sum() == 2


def test_adversarial_budget_exceeding_survivors_after_shrink():
    """Elastic shrink keeps the straggler fraction p; the rebuilt
    adversarial model's budget floor(p*m') must stay within m' and the
    runtime's decode must stay finite with w = 0 on the attacked set."""
    cfg = CodingConfig(scheme="expander", replication=2,
                       straggler_model="adversarial",
                       straggler_p=0.9, seed=1)
    rt0 = coded_train.CodingRuntime(cfg, 6)
    rt1 = coded_train.elastic_reassign(rt0, [4], generation=1)
    assert rt1.m == 5
    mask = rt1.model.sample(np.random.default_rng(0))
    assert mask.shape == (5,)
    w, alive = rt1.weights_for(mask), mask
    assert np.isfinite(w).all()
    assert (w[~alive] == 0).all()
    assert np.isfinite(rt1.scale) and rt1.scale > 0


def test_dead_machine_stream_keeps_weights_zero_and_finite():
    """A permanently dead machine (always straggling in the replayed
    stream) must never receive weight, and the debias stays finite."""
    cfg = CodingConfig(scheme="expander", replication=2, seed=2)
    masks = np.ones((6, 4), dtype=bool)
    masks[:, 3] = False                 # machine 3 dead all run
    rt = coded_train.CodingRuntime(
        cfg, 4, mask_source=sw.ReplayedMaskSource(masks))
    for _ in range(6):
        w, alive = rt.step_weights()
        assert w[3] == 0.0
        assert np.isfinite(w).all()
    assert np.isfinite(rt.scale)
