"""Batched decoding engine vs the scalar Section III decoder.

The batched engine must agree with ``optimal_alpha_graph`` *exactly*
(same float expressions from the same integer side counts), on random
graphs x random masks and on the structural edge cases: all machines
dead, all alive, isolated vertices, odd cycles. Property-tested with
hypothesis when it is installed; the randomized numpy sweeps always run.
"""

import numpy as np
import pytest

from repro.core import (BernoulliStragglers, LeastSquares,
                        batched_alpha, batched_fixed_alpha,
                        batched_frc_alpha, batched_optimal_alpha_graph,
                        bernoulli_assignment, decode, expander_assignment,
                        fixed_decode, frc_assignment, gcod,
                        graph_assignment, monte_carlo_error,
                        optimal_alpha_graph, optimal_decode_frc,
                        optimal_decode_pinv, precompute_alphas,
                        random_regular_graph, sgd_alg)
from repro.core.batched_decoding import _HAS_JAX
from repro.core.graphs import Graph, complete_graph, cycle_graph

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

RNG = np.random.default_rng(0)


def _scalar_ref(g, masks):
    return np.stack([optimal_alpha_graph(g, mk) for mk in masks])


def test_batched_matches_scalar_random_graphs():
    for n, d, seed in [(8, 3, 0), (16, 3, 1), (12, 4, 2), (24, 5, 3),
                       (64, 4, 0)]:
        if (n * d) % 2:
            n += 1
        g = random_regular_graph(n, d, seed=seed)
        masks = RNG.random((24, g.m)) >= RNG.uniform(0.1, 0.9)
        masks[0, :] = True   # all alive
        masks[1, :] = False  # all dead
        ref = _scalar_ref(g, masks)
        out = batched_optimal_alpha_graph(g, masks, backend="numpy")
        np.testing.assert_array_equal(out, ref)


def test_edge_cases_odd_cycle_isolated_all_dead():
    # odd cycle: non-bipartite when whole -> alpha = 1 exactly
    g = cycle_graph(5)
    masks = np.stack([np.ones(5, bool), np.zeros(5, bool),
                      np.array([True, True, True, True, False]),
                      np.array([True, True, False, False, False])])
    out = batched_optimal_alpha_graph(g, masks, backend="numpy")
    np.testing.assert_array_equal(out[0], np.ones(5))   # odd cycle
    np.testing.assert_array_equal(out[1], np.zeros(5))  # all dead
    # one edge dead -> path of 5: sides 3/2, alpha in {1 -/+ 1/5}
    np.testing.assert_allclose(sorted(out[2]),
                               [0.8, 0.8, 0.8, 1.2, 1.2], atol=0)
    np.testing.assert_array_equal(out, _scalar_ref(g, masks))
    # graph with structurally isolated vertices (no incident edges)
    g2 = Graph(6, ((0, 1), (1, 2), (3, 4)))
    masks2 = RNG.random((16, 3)) >= 0.5
    np.testing.assert_array_equal(
        batched_optimal_alpha_graph(g2, masks2, backend="numpy"),
        _scalar_ref(g2, masks2))


@pytest.mark.skipif(not _HAS_JAX, reason="jax not installed")
def test_jax_backend_matches_numpy():
    for g in (random_regular_graph(16, 3, seed=1), cycle_graph(9),
              complete_graph(7), Graph(5, ((0, 1), (1, 2)))):
        masks = RNG.random((32, g.m)) >= 0.4
        masks[0, :] = True
        masks[1, :] = False
        a_np = batched_optimal_alpha_graph(g, masks, backend="numpy")
        a_jx = batched_optimal_alpha_graph(g, masks, backend="jax")
        np.testing.assert_array_equal(a_np, a_jx)
        np.testing.assert_array_equal(a_np, _scalar_ref(g, masks))


def test_batched_fixed_and_frc_match_scalar():
    A = expander_assignment(24, 4, vertex_transitive=False, seed=0)
    masks = RNG.random((20, A.m)) >= 0.3
    out = batched_fixed_alpha(A, masks, 0.3)
    ref = np.stack([fixed_decode(A, mk, 0.3).alpha for mk in masks])
    np.testing.assert_allclose(out, ref, atol=1e-12)

    F = frc_assignment(12, 3)
    masks_f = RNG.random((20, 12)) >= 0.4
    out_f = batched_frc_alpha(F, masks_f)
    ref_f = np.stack([optimal_decode_frc(F, mk).alpha for mk in masks_f])
    np.testing.assert_allclose(out_f, ref_f, atol=1e-12)
    # dispatch mirrors decode(): frc name -> closed form
    np.testing.assert_array_equal(
        out_f, batched_alpha(F, masks_f, method="optimal"))


def test_batched_fixed_rejects_p_ge_1():
    A = expander_assignment(16, 4, vertex_transitive=False, seed=0)
    masks = np.ones((2, 16), bool)
    with pytest.raises(ValueError, match="p < 1"):
        batched_fixed_alpha(A, masks, 1.0)
    with pytest.raises(ValueError, match="p < 1"):
        fixed_decode(A, np.ones(16, bool), 1.5)


def test_adjacency_on_2regular_graph_uses_pinv():
    """Regression: a d=2 adjacency assignment has A of shape n x n,
    indistinguishable from the (n, m) of an edge scheme -- the explicit
    ``machines`` marker must route it to the pseudoinverse, not the
    edge-component decoder."""
    from repro.core import adjacency_assignment

    A = adjacency_assignment(cycle_graph(6))
    assert A.machines == "vertices"
    alive = np.array([True, True, False, True, True, True])
    got = decode(A, alive, method="optimal")
    ref = optimal_decode_pinv(A, alive)
    np.testing.assert_allclose(got.alpha, ref.alpha, atol=1e-12)
    assert not np.allclose(ref.alpha, 1.0)  # pinv optimum is non-flat
    np.testing.assert_allclose(
        batched_alpha(A, alive[None], method="optimal")[0], ref.alpha,
        atol=1e-9)


def test_batched_pinv_fallback_matches_scalar():
    A = bernoulli_assignment(8, 16, 3, seed=0)
    masks = RNG.random((6, 16)) >= 0.3
    out = batched_alpha(A, masks, method="optimal")
    ref = np.stack(
        [optimal_decode_pinv(A, mk).alpha for mk in masks])
    np.testing.assert_allclose(out, ref, atol=1e-9)


def test_numpy_labels_narrow_to_int16():
    from repro.core.batched_decoding import _label_dtype, _propagate_numpy

    g = cycle_graph(12)
    masks = RNG.random((3, 12)) >= 0.3
    assert _propagate_numpy(g, masks).dtype == np.int16
    assert _label_dtype(12) == np.int16
    assert _label_dtype(16383) == np.int16   # 2n = 32766 still fits
    assert _label_dtype(16384) == np.int32   # 2n = 32768 does not


@pytest.mark.skipif(not _HAS_JAX, reason="jax not installed")
def test_backends_agree_across_int16_threshold():
    """Both backends, bit-identical alphas, on graphs straddling the
    2n < 32768 label-dtype threshold (int16 below, int32 above)."""
    from repro.core.batched_decoding import _label_dtype

    for n in (16383, 16385):
        g = cycle_graph(n)
        masks = RNG.random((4, g.m)) >= 0.15
        masks[0, :] = True
        a_np = batched_optimal_alpha_graph(g, masks, backend="numpy")
        a_jx = batched_optimal_alpha_graph(g, masks, backend="jax")
        np.testing.assert_array_equal(a_np, a_jx)
        # even cycle fully alive is bipartite and balanced; odd is an
        # odd cycle: alpha = 1 either way
        np.testing.assert_array_equal(a_np[0], np.ones(n))
    assert _label_dtype(16383) != _label_dtype(16385)


def test_warm_start_labels_bit_identical():
    """Seeding propagation with a subset-mask's labels (the sweep's
    nested-in-p protocol) must not change the fixed point."""
    g = random_regular_graph(20, 4, seed=2)
    u = RNG.random((10, g.m))
    prev = None
    for p in (0.7, 0.4, 0.2, 0.0):  # descending p: alive sets grow
        alive = u >= p
        cold = batched_optimal_alpha_graph(g, alive, backend="numpy")
        warm, labels = batched_optimal_alpha_graph(
            g, alive, backend="numpy", labels0=prev, return_labels=True)
        np.testing.assert_array_equal(warm, cold)
        if _HAS_JAX:
            warm_jx = batched_optimal_alpha_graph(
                g, alive, backend="jax", labels0=prev)
            np.testing.assert_array_equal(warm_jx, cold)
        prev = labels
    with pytest.raises(ValueError, match="labels0"):
        batched_optimal_alpha_graph(g, u >= 0.5, backend="numpy",
                                    labels0=np.zeros((10, 7), np.int16))


def test_mask_shape_validation():
    g = cycle_graph(4)
    with pytest.raises(ValueError, match="trials"):
        batched_optimal_alpha_graph(g, np.ones(4, bool))
    with pytest.raises(ValueError, match="machines"):
        batched_optimal_alpha_graph(g, np.ones((3, 5), bool))


def test_monte_carlo_error_matches_historical_loop():
    """The batched monte_carlo pipeline reproduces the per-trial loop
    bit-for-bit (same RNG stream, same decode values, same debias)."""
    from repro.core.decoding import debias_alpha

    A = expander_assignment(24, 3, vertex_transitive=False, seed=1)
    for method in ("optimal", "fixed"):
        got = monte_carlo_error(A, 0.2, trials=60, method=method, seed=9)
        rng = np.random.default_rng(9)
        alphas = np.empty((60, A.n))
        for t in range(60):
            alive = rng.random(A.m) >= 0.2
            alphas[t] = decode(A, alive, method=method, p=0.2).alpha
        ab = debias_alpha(alphas)
        errs = np.mean((ab - 1.0) ** 2, axis=1)
        centered = ab - ab.mean(axis=0, keepdims=True)
        cov = centered.T @ centered / 60
        assert got["mean_error"] == float(errs.mean())
        assert got["std_error"] == float(errs.std())
        assert got["cov_norm"] == float(np.linalg.norm(cov, 2))
    # cov=False drops the covariance (throughput mode)
    slim = monte_carlo_error(A, 0.2, trials=10, method="optimal", seed=9,
                             cov=False)
    assert "cov_norm" not in slim


def test_gcod_precomputed_alphas_bit_identical():
    prob = LeastSquares.synthetic(N=64, k=8, noise=0.1, n_blocks=8,
                                  seed=0)
    A = expander_assignment(16, 4, vertex_transitive=False, seed=1)
    model = lambda: BernoulliStragglers(m=16, p=0.25)
    base = gcod(prob, A, model(), steps=12, lr=1e-3, method="optimal",
                p=0.25, seed=3)
    pre = precompute_alphas(A, model(), steps=12, method="optimal",
                            p=0.25, seed=3)
    replay = gcod(prob, A, model(), steps=12, lr=1e-3, method="optimal",
                  p=0.25, seed=3, alphas=pre)
    assert base.errors == replay.errors
    for a, b in zip(base.alphas, replay.alphas):
        np.testing.assert_array_equal(a, b)


def test_sgd_alg_accepts_beta_batch():
    prob = LeastSquares.synthetic(N=64, k=8, noise=0.1, n_blocks=8,
                                  seed=0)
    betas = RNG.normal(loc=1.0, scale=0.1, size=(10, 8))
    tr_b = sgd_alg(prob, steps=10, lr=1e-3, seed=4, betas=betas)
    it = iter(betas)
    tr_s = sgd_alg(prob, lambda _rng: next(it), steps=10, lr=1e-3, seed=4)
    np.testing.assert_allclose(tr_b.errors, tr_s.errors, rtol=0, atol=0)
    with pytest.raises(ValueError, match="exactly one"):
        sgd_alg(prob, steps=10, lr=1e-3)


if HAVE_HYPOTHESIS:

    @st.composite
    def graph_and_masks(draw):
        n = draw(st.integers(4, 24))
        d = draw(st.integers(2, min(n - 1, 6)))
        if (n * d) % 2:
            n += 1
        seed = draw(st.integers(0, 10_000))
        try:
            g = random_regular_graph(n, d, seed=seed)
        except RuntimeError:
            pytest.skip("no simple regular graph sampled")
        trials = draw(st.integers(1, 8))
        bits = draw(st.lists(st.booleans(), min_size=trials * g.m,
                             max_size=trials * g.m))
        return g, np.asarray(bits, bool).reshape(trials, g.m)

    @given(graph_and_masks())
    @settings(max_examples=50, deadline=None)
    def test_property_batched_equals_scalar(gm):
        g, masks = gm
        out = batched_optimal_alpha_graph(g, masks, backend="numpy")
        ref = _scalar_ref(g, masks)
        np.testing.assert_allclose(out, ref, rtol=0, atol=1e-9)
        np.testing.assert_array_equal(out, ref)  # in fact bit-exact
        # and the decode() contract: alpha is A w for w supported on
        # survivors, so dispatch through an assignment agrees too
        A = graph_assignment(g)
        np.testing.assert_array_equal(
            batched_alpha(A, masks, method="optimal", backend="numpy"),
            ref)


def test_batched_alpha_label_plumbing():
    """labels0/return_labels through the dispatching entry point (the
    multi-scheme pipelines' warm-start protocol, exercised by
    decode_grid): warm-started alphas are bit-identical to cold ones
    under nested masks, and non-graph schemes carry no label state."""
    A = expander_assignment(24, 3, vertex_transitive=False, seed=1)
    rng = np.random.default_rng(3)
    u = rng.random((8, A.m))
    hi, lo = u >= 0.5, u >= 0.2  # lo revives machines: nested
    cold_hi, labels = batched_alpha(A, hi, method="optimal",
                                    backend="numpy", return_labels=True)
    assert labels.shape == (8, 2 * A.n)
    warm_lo = batched_alpha(A, lo, method="optimal", backend="numpy",
                            labels0=labels)
    np.testing.assert_array_equal(
        warm_lo, batched_alpha(A, lo, method="optimal", backend="numpy"))
    np.testing.assert_array_equal(
        cold_hi, batched_alpha(A, hi, method="optimal", backend="numpy"))
    # non-graph schemes: no label state
    F = frc_assignment(24, 3)
    out, none = batched_alpha(F, hi, method="optimal",
                              return_labels=True)
    assert none is None
    np.testing.assert_array_equal(out, batched_alpha(F, hi))
    with pytest.raises(ValueError, match="labels0"):
        batched_alpha(F, hi, method="optimal", labels0=labels)
    with pytest.raises(ValueError, match="labels0"):
        batched_alpha(A, hi, method="fixed", p=0.3, labels0=labels)
