"""Continuous-batching coded serving: scheduler, cache pool, coded
prefill layer, latency model, and the two differential pins.

The pins, per the repo convention (every fast path names its oracle):

* scheduling invisibility -- the engine's per-request token streams
  under continuous admission are bit-identical to the sequential-
  batching reference loop (``serve.reference.sequential_serve``) over
  the same jitted pool step;
* coding invisibility at p=0 -- with no straggler fired every combine
  weight is exactly 1.0, so the coded-serve stream is bit-identical to
  the uncoded single-replica stream.

Engine tests run at pool width 4 on the dense smoke config (the
SSM/xLSTM state families get the same treatment in
tests/test_serve_steps.py; MoE's expert-choice routing couples batch
rows and is the documented exception to bit-identity).
"""

import jax
import numpy as np
import pytest

import repro.core.step_weights as sw
from repro.configs import CodingConfig, get_config
from repro.core import expander_assignment
from repro.models import model as M
from repro import serve as S

SEED = 0


def _requests(cfg, n, rng, base_len=6, spread=3, new_tokens=4):
    return [S.Request(uid=i,
                      prompt=rng.integers(0, cfg.vocab_size,
                                          base_len - (i % (spread + 1))),
                      max_new_tokens=new_tokens + (i % 2))
            for i in range(n)]


@pytest.fixture(scope="module")
def dense():
    cfg = get_config("qwen1.5-4b").smoke_variant()
    params = M.init_params(cfg, jax.random.PRNGKey(SEED))
    return cfg, params


# ---------------------------------------------------------------- pins

def test_engine_matches_sequential_reference(dense):
    """Scheduling must change when tokens appear, never what they are:
    continuous admission (mixed prompt lengths, slot reuse across
    multiple admission waves) == the static-batching oracle."""
    cfg, params = dense
    reqs = _requests(cfg, 7, np.random.default_rng(1))
    eng = S.ServeEngine(cfg, params, n_slots=4, max_len=32, log_every=3)
    for r in reqs:
        eng.submit(r)
    summary = eng.run()
    assert summary["requests"] == 7
    assert summary["admissions"] == 7
    res = eng.results()
    ref = S.sequential_serve(params, cfg, reqs, n_slots=4, max_len=32)
    for r in reqs:
        assert len(res[r.uid]) == r.max_new_tokens
        np.testing.assert_array_equal(res[r.uid], ref[r.uid])


def test_coded_stream_equals_uncoded_at_p0(dense):
    """The tentpole pin: no straggler fired => alpha_i == 1.0 exactly
    => the coded-serve stream is bit-identical to the single-replica
    serve stream."""
    cfg, params = dense
    reqs = _requests(cfg, 6, np.random.default_rng(2))

    def run(scheme, p):
        coding = CodingConfig(scheme=scheme, replication=2,
                              straggler_model="bernoulli",
                              straggler_p=p, seed=SEED)
        eng = S.ServeEngine(cfg, params, n_slots=4, max_len=32,
                            coding=coding, m_replicas=8, log_every=4)
        for r in reqs:
            eng.submit(r)
        eng.run()
        return eng

    coded = run("expander", 0.0)
    uncoded = run("uncoded", 0.0)
    for r in reqs:
        assert coded.records[r.uid]["alpha"] == 1.0
        np.testing.assert_array_equal(coded.results()[r.uid],
                                      uncoded.results()[r.uid])


def test_engine_stream_invariant_under_straggler_p(dense):
    """Replica compute is deterministic: stragglers change latency
    bookkeeping (retries, TTFT), never the tokens."""
    cfg, params = dense
    reqs = _requests(cfg, 5, np.random.default_rng(3))

    def run(p):
        coding = CodingConfig(scheme="expander", replication=2,
                              straggler_model="bernoulli",
                              straggler_p=p, seed=SEED)
        eng = S.ServeEngine(cfg, params, n_slots=4, max_len=32,
                            coding=coding, m_replicas=8)
        for r in reqs:
            eng.submit(r)
        eng.run()
        return eng.results()

    r0, r05 = run(0.0), run(0.5)
    for r in reqs:
        np.testing.assert_array_equal(r0[r.uid], r05[r.uid])


# ----------------------------------------------------------- scheduler

def test_continuous_scheduler_interleaves_prefill_and_decode():
    sched = S.ContinuousScheduler(n_slots=2)
    sched.submit(S.Request(uid=0, prompt=np.arange(1, 4),
                           max_new_tokens=2))
    plan = sched.plan()
    assert [b for b, _ in plan.admitted] == [0]
    assert plan.use_forced[0] and plan.forced_tok[0] == 1
    assert plan.emits == []
    # a second request admitted mid-prefill lands in the free slot and
    # prefills while slot 0 keeps advancing -- no starvation
    sched.submit(S.Request(uid=1, prompt=np.array([9]),
                           max_new_tokens=1))
    plan = sched.plan()
    assert [b for b, _ in plan.admitted] == [1]
    assert plan.forced_tok.tolist() == [2, 9]
    # uid 1's single prompt token makes this its first+last emission
    assert (1, 1, True) in plan.emits
    assert plan.finished == [1]
    plan = sched.plan()   # uid 0 consumes its last prompt token
    assert (0, 0, True) in plan.emits
    plan = sched.plan()   # decode emission completes uid 0
    assert plan.emits == [(0, 0, False)]
    assert plan.finished == [0]
    assert not sched.has_work()


def test_sequential_scheduler_is_static_batching():
    sched = S.SequentialScheduler(n_slots=2)
    for i in range(3):
        sched.submit(S.Request(uid=i, prompt=np.array([1, 2]),
                               max_new_tokens=1))
    assert len(sched.plan().admitted) == 2
    # queue non-empty but the pool is busy: no admission until drained
    plan = sched.plan()
    assert plan.admitted == [] and plan.finished == [0, 1]
    assert [r.uid for _, r in sched.plan().admitted] == [2]


def test_request_validation():
    with pytest.raises(ValueError):
        S.Request(uid=0, prompt=np.array([], np.int32), max_new_tokens=1)
    with pytest.raises(ValueError):
        S.Request(uid=0, prompt=np.array([1]), max_new_tokens=0)


def test_validate_budget_rejects_overflow():
    import dataclasses
    cfg = get_config("qwen1.5-4b").smoke_variant()
    S.validate_budget(cfg, 8, 8, 16)
    with pytest.raises(ValueError, match="overflows the decode cache"):
        S.validate_budget(cfg, 8, 9, 16)
    with pytest.raises(ValueError, match="prompt_len"):
        S.validate_budget(cfg, 0, 4, 16)
    # windowed attention wraps its cache: no capacity bound
    wcfg = dataclasses.replace(cfg, sliding_window=8)
    S.validate_budget(wcfg, 8, 64, 16)


# ------------------------------------------------- coded prefill layer

def _coding(p, model="bernoulli", seed=0):
    return CodingConfig(scheme="expander", replication=2,
                        straggler_model=model, straggler_p=p, seed=seed)


def test_coded_layer_alpha_one_at_p0():
    layer = S.CodedPrefillLayer(_coding(0.0), 8)
    for svc in layer.serve_shards(layer.assign_shards(8)):
        assert svc.alpha == 1.0        # exactly: the p=0 pin relies on it
        assert svc.retries == 0
        assert svc.ttft_ms < layer.latency.deadline_ms


def test_coded_layer_serves_from_arrived_replicas():
    layer = S.CodedPrefillLayer(_coding(0.4, seed=3), 8)
    served = [layer.serve_shards(layer.assign_shards(4))
              for _ in range(40)]
    retried = sum(svc.retries for group in served for svc in group)
    assert retried > 0                  # p=0.4 double-straggles often
    for group in served:
        for svc in group:
            assert svc.alpha > 0
            # each retry costs one deadline before the serving round's
            # fastest-arrived-replica latency
            assert svc.ttft_ms >= svc.retries * layer.latency.deadline_ms


def test_coded_layer_adversarial_waits_out_pinned_replicas():
    """The adversarial mask never moves: a shard both of whose replicas
    it pins can only be served by waiting the stragglers out."""
    layer = S.CodedPrefillLayer(_coding(0.3, model="adversarial"), 8,
                                max_retries=4)
    services = layer.serve_shards(list(range(layer.assignment.n)))
    waited = [s for s in services
              if s.ttft_ms >= layer.latency.straggle_ms]
    alive = layer.model.sample(np.random.default_rng(0))
    dead_shards = [
        i for i in range(layer.assignment.n)
        if not alive[layer.assignment.machines_of_block(i)].any()]
    assert len(waited) == len(dead_shards)
    for s in waited:
        assert s.alpha == 1.0           # full-alive decode after the wait


def test_uncoded_layer_waits_out_its_single_replica():
    layer = S.UncodedPrefillLayer(_coding(0.5, seed=1), 8)
    ttfts = [svc.ttft_ms for _ in range(40)
             for svc in layer.serve_shards(layer.assign_shards(8))]
    lat = layer.latency
    slow = [t for t in ttfts if t > lat.straggle_ms]
    fast = [t for t in ttfts if t < lat.deadline_ms]
    assert slow and fast                # both modes, nothing in between
    assert len(slow) + len(fast) == len(ttfts)


# ------------------------------------------------------- latency model

def test_latency_model_alive_means_arrived():
    lat = S.ReplicaLatencyModel(m=16)
    rng = np.random.default_rng(0)
    alive = rng.random(16) >= 0.5
    t = lat.latencies(alive, rng)
    assert (t[alive] < lat.deadline_ms).all()
    assert (t[~alive] > lat.straggle_ms).all()
    with pytest.raises(ValueError):
        S.ReplicaLatencyModel(m=4, deadline_ms=1.0)  # < base_ms


def test_simulate_shard_ttft_bounds_the_tail():
    """The bench's acceptance in miniature: d=2 coded p99 is one
    deadline + retries (~ p^2), uncoded p99 is the slowest device."""
    m, rounds, p = 16, 2000, 0.2
    A = expander_assignment(m, 2, vertex_transitive=True, seed=0)
    rng = np.random.default_rng(0)
    alive = rng.random((rounds, m)) >= p
    W, _ = sw.batched_step_weights(A, alive)
    lat_model = S.ReplicaLatencyModel(m=m)
    lat = np.stack([lat_model.latencies(a, rng) for a in alive])
    coded, uncoded = S.simulate_shard_ttft(
        A, W, alive, lat, deadline_ms=lat_model.deadline_ms,
        straggle_ms=lat_model.straggle_ms)
    assert coded.shape == (rounds, A.n)
    c99 = np.percentile(coded, 99)
    u99 = np.percentile(uncoded, 99)
    assert c99 < u99
    assert u99 > lat_model.straggle_ms            # waits out stragglers
    # p50 unchanged: both sit at the base-latency plateau
    assert abs(np.percentile(coded, 50)
               - np.percentile(uncoded, 50)) < 1.0
    # at p=0 every shard is served round 0 by its fastest replica
    alive0 = np.ones((8, m), bool)
    W0, _ = sw.batched_step_weights(A, alive0)
    lat0 = np.stack([lat_model.latencies(a, rng) for a in alive0])
    coded0, _ = S.simulate_shard_ttft(
        A, W0, alive0, lat0, deadline_ms=lat_model.deadline_ms,
        straggle_ms=lat_model.straggle_ms)
    want = np.stack([lat0[:, A.machines_of_block(i)].min(axis=1)
                     for i in range(A.n)], axis=1)
    np.testing.assert_allclose(coded0, want)


def test_served_blocks_matches_alpha_support():
    A = expander_assignment(8, 2, vertex_transitive=True, seed=0)
    masks = np.random.default_rng(0).random((16, A.m)) >= 0.4
    W, alphas = sw.batched_step_weights(A, masks)
    np.testing.assert_array_equal(sw.served_blocks(A, W),
                                  alphas > 1e-3)
    w, alpha = sw.step_weights(A, masks[0])
    np.testing.assert_array_equal(sw.served_blocks(A, w),
                                  alpha > 1e-3)


# ----------------------------------------------------------- cache pool

def test_cache_pool_reset_zeroes_only_masked_slots(dense):
    from repro.dist import sharding as rules
    cfg, params = dense
    pool = S.CachePool(cfg, 4, 16)
    step = S.pool_step(cfg, cfg.sliding_window)
    # populate the pool with one real decode step first
    _, pool.cache = step(
        params, pool.cache, jax.numpy.zeros(4, "int32"),
        jax.numpy.asarray(np.array([3, 1, 4, 1], "int32")),
        jax.numpy.ones(4, bool), jax.numpy.ones(4, "float32"))
    before = jax.tree_util.tree_flatten_with_path(
        jax.device_get(pool.cache))[0]
    pool.reset_slots(np.array([True, False, False, False]))
    after = jax.tree_util.tree_flatten_with_path(
        jax.device_get(pool.cache))[0]
    assert any(np.asarray(leaf).any() for _, leaf in before)
    for (path, old), (_, new) in zip(before, after):
        keys = tuple(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path)
        bd = rules.cache_batch_dim(keys)
        old_s = np.moveaxis(old, bd, 0)
        new_s = np.moveaxis(new, bd, 0)
        assert not new_s[0].any()                      # slot 0 zeroed
        np.testing.assert_array_equal(new_s[1:], old_s[1:])
