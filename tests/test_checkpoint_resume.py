"""Checkpoint round-trip on the real train driver: save mid-run,
restore, and the resumed coded train step must continue the
loss/metric stream bit-identically.

What makes this exact (not just close): checkpoints carry the full
{params, opt_state} state as float32 npz (lossless), data batches are
a pure function of the step index, and ``CodingRuntime.skip`` replays
the straggler RNG stream to the resume point -- so the resumed run's
masks, decoded weights and device inputs are bitwise the inputs the
uninterrupted run saw. Subprocess for the same reason as
test_smoke_train: the 8-virtual-device count must enter XLA_FLAGS
before jax initialises.
"""

import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

STEPS, MID, EVERY = 8, 6, 4


def _run_driver(*extra):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.train",
         "--arch", "qwen1.5-4b", "--seq-len", "32", "--block-size", "2",
         "--straggler-p", "0.2", "--log-every", "3", *extra],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=420)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_resume_is_bit_identical(tmp_path):
    full = _run_driver("--steps", str(STEPS), "--dedup",
                       "--lookahead", "3")

    # Interrupted run: checkpoints every EVERY=4 steps, stopped at
    # MID=6 -- so BOTH save paths fire: the periodic mid-loop save at
    # step 4 (4 % 4 == 0 and 4 < 6) and the end-of-run save at 6.
    ck = str(tmp_path / "ck")
    first = _run_driver("--steps", str(MID), "--dedup", "--lookahead",
                        "3", "--ckpt-dir", ck, "--ckpt-every",
                        str(EVERY))
    assert first["start_step"] == 0
    assert first["losses"] == full["losses"][:MID], \
        "pre-checkpoint stream must match the uninterrupted run"
    assert os.path.exists(os.path.join(ck, "ckpt_00000004.npz")), \
        "periodic --ckpt-every save must fire mid-run"
    assert os.path.exists(os.path.join(ck, "ckpt_00000006.npz"))

    resumed = _run_driver("--steps", str(STEPS), "--dedup",
                          "--lookahead", "3", "--ckpt-dir", ck,
                          "--ckpt-every", str(EVERY))
    assert resumed["start_step"] == MID  # newest usable checkpoint
    assert len(resumed["losses"]) == STEPS - MID
    # The contract: bitwise equality of the resumed loss stream with
    # the uninterrupted run's tail (floats round-tripped through
    # json.dumps preserve every bit).
    assert resumed["losses"] == full["losses"][MID:], (
        f"resumed stream diverged:\n{resumed['losses']}\nvs\n"
        f"{full['losses'][MID:]}")

    # Capping --steps below a saved checkpoint resumes from the newest
    # checkpoint at-or-before it (the mid-run step-4 one), never
    # relabeling a later-step state as an earlier step.
    capped = _run_driver("--steps", str(EVERY), "--dedup",
                         "--lookahead", "3", "--ckpt-dir", ck)
    assert capped["start_step"] == EVERY
    assert capped["losses"] == []


def _truncate(path, keep=0.5):
    with open(path, "rb") as f:
        blob = f.read()
    with open(path, "wb") as f:
        f.write(blob[:int(len(blob) * keep)])


def test_restore_fallback_skips_torn_checkpoint(tmp_path):
    """Unit contract of ckpt.restore_fallback: a truncated .npz (crash
    mid-write survives the atomic rename only if the tear happens at
    copy/disk level -- but it can) must fall back to the previous
    intact step, and raise only when nothing intact remains."""
    import numpy as np

    import repro.checkpoint.checkpoint as ckpt

    tree2 = {"w": np.arange(4, dtype=np.float32)}
    tree4 = {"w": np.arange(4, dtype=np.float32) * 2}
    ck = str(tmp_path / "ck")
    ckpt.save(ck, tree2, step=2)
    ckpt.save(ck, tree4, step=4)
    templates = [("t", {"w": np.zeros(4, dtype=np.float32)})]

    step, label, state = ckpt.restore_fallback(ck, templates)
    assert step == 4
    np.testing.assert_array_equal(state["w"], tree4["w"])

    _truncate(f"{ck}/ckpt_00000004.npz")
    step, label, state = ckpt.restore_fallback(ck, templates)
    assert step == 2, "torn step-4 file must fall back to step 2"
    np.testing.assert_array_equal(state["w"], tree2["w"])

    _truncate(f"{ck}/ckpt_00000002.npz", keep=0.1)
    try:
        ckpt.restore_fallback(ck, templates)
        raise AssertionError("all-torn directory must raise")
    except ValueError as e:
        assert "no intact checkpoint" in str(e)


def test_driver_resumes_past_torn_checkpoint(tmp_path):
    """Driver-level crash-safety: with the newest checkpoint file
    truncated mid-zip, the resume falls back to the previous intact
    step and the loss stream stays bitwise the uninterrupted run's
    tail from there."""
    full = _run_driver("--steps", str(STEPS), "--dedup",
                       "--lookahead", "3")
    ck = str(tmp_path / "ck")
    _run_driver("--steps", str(MID), "--dedup", "--lookahead", "3",
                "--ckpt-dir", ck, "--ckpt-every", str(EVERY))
    _truncate(os.path.join(ck, f"ckpt_{MID:08d}.npz"))

    resumed = _run_driver("--steps", str(STEPS), "--dedup",
                          "--lookahead", "3", "--ckpt-dir", ck)
    assert resumed["start_step"] == EVERY, \
        "torn newest checkpoint must fall back to the intact step 4"
    assert resumed["losses"] == full["losses"][EVERY:], (
        f"fallback resume diverged:\n{resumed['losses']}\nvs\n"
        f"{full['losses'][EVERY:]}")


def test_compressed_resume_is_bit_identical(tmp_path):
    """--compress int8 threads the error-feedback residual through the
    checkpoint: a resumed compressed run replays the loss stream
    bitwise (dropping the residual would shift every post-resume
    quantization and diverge)."""
    flags = ("--dedup", "--lookahead", "3", "--compress", "int8")
    full = _run_driver("--steps", str(STEPS), *flags)

    ck = str(tmp_path / "ck")
    first = _run_driver("--steps", str(MID), *flags, "--ckpt-dir", ck,
                        "--ckpt-every", str(EVERY))
    assert first["losses"] == full["losses"][:MID]

    resumed = _run_driver("--steps", str(STEPS), *flags, "--ckpt-dir",
                          ck, "--ckpt-every", str(EVERY))
    assert resumed["start_step"] == MID
    assert resumed["losses"] == full["losses"][MID:], (
        f"compressed resume diverged:\n{resumed['losses']}\nvs\n"
        f"{full['losses'][MID:]}")


def test_sign_packed_resume_is_bit_identical(tmp_path):
    """The packed 1-bit codec's residual (always float32 at gradient
    shape -- only the wire payload is packed) threads through the
    checkpoint exactly like int8's: a resumed --compress sign_packed
    run replays the loss stream bitwise."""
    flags = ("--dedup", "--lookahead", "3", "--compress", "sign_packed")
    full = _run_driver("--steps", str(STEPS), *flags)

    ck = str(tmp_path / "ck")
    first = _run_driver("--steps", str(MID), *flags, "--ckpt-dir", ck,
                        "--ckpt-every", str(EVERY))
    assert first["losses"] == full["losses"][:MID]

    resumed = _run_driver("--steps", str(STEPS), *flags, "--ckpt-dir",
                          ck, "--ckpt-every", str(EVERY))
    assert resumed["start_step"] == MID
    assert resumed["losses"] == full["losses"][MID:], (
        f"sign_packed resume diverged:\n{resumed['losses']}\nvs\n"
        f"{full['losses'][MID:]}")


def test_sign_to_sign_packed_warm_start(tmp_path):
    """A --compress sign checkpoint restores into a --compress
    sign_packed run: the error-feedback residual is codec-independent
    state (float32 rows at parameter shape), so switching the wire
    codec mid-training keeps the residual instead of dropping it."""
    ck = str(tmp_path / "ck")
    _run_driver("--steps", str(MID), "--dedup", "--lookahead", "3",
                "--compress", "sign", "--ckpt-dir", ck)
    resumed = _run_driver("--steps", str(STEPS), "--dedup",
                          "--lookahead", "3", "--compress",
                          "sign_packed", "--ckpt-dir", ck)
    assert resumed["start_step"] == MID
    assert len(resumed["losses"]) == STEPS - MID


def test_compress_resumes_from_uncompressed_checkpoint(tmp_path):
    """An uncompressed {params, opt_state} checkpoint restores into a
    --compress run (fresh zero residual) -- the layout-compatibility
    contract of ckpt.restore_any."""
    ck = str(tmp_path / "ck")
    _run_driver("--steps", str(MID), "--dedup", "--lookahead", "3",
                "--ckpt-dir", ck)
    resumed = _run_driver("--steps", str(STEPS), "--dedup",
                          "--lookahead", "3", "--compress", "int8",
                          "--ckpt-dir", ck)
    assert resumed["start_step"] == MID
    assert len(resumed["losses"]) == STEPS - MID
