"""Fast end-to-end smoke: the real ``repro.launch.serve`` driver on
the 8-virtual-device mesh.

Runs as a subprocess because the virtual-device count must enter
XLA_FLAGS before jax initialises (conftest keeps the test process on
the real 1-CPU device by design). ``--check`` makes the driver itself
assert the engine token streams against the sequential-batching
reference loop; this test checks the exit status and the JSON summary.
Two runs keep both serve paths in tier-1: the coded expander prefill
(d=2 replicas, bernoulli stragglers) and the xLSTM recurrent-state
family through the same pool. Budget validation (satellite: fail fast
instead of mid-generation) is pinned by the third case.
"""

import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_driver(*extra, expect_fail=False):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve",
         "--requests", "7", "--slots", "4", "--prompt-len", "8",
         "--prompt-spread", "3", "--max-new-tokens", "6",
         "--max-len", "32", "--log-every", "4", *extra],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=420)
    if expect_fail:
        assert proc.returncode != 0, proc.stdout + proc.stderr
        return proc.stderr
    assert proc.returncode == 0, proc.stdout + proc.stderr
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_serve_driver_smoke_coded_checked():
    summary = _run_driver("--scheme", "expander", "--straggler-p", "0.2",
                          "--check")
    assert summary["path"] == "engine"
    assert summary["scheme"] == "expander"
    assert summary["replication"] == 2
    assert summary["check_passed"] is True  # bit-identical to reference
    assert summary["requests"] == 7
    assert summary["new_tokens"] == 7 * 6
    assert summary["mesh"] == [4, 2]
    assert summary["tokens_per_s"] > 0
    # synthetic TTFT is populated by the coded prefill layer
    assert summary["ttft_p99_ms"] >= summary["ttft_p50_ms"] > 0


def test_serve_driver_smoke_xlstm_family():
    summary = _run_driver("--arch", "xlstm-1.3b", "--scheme", "uncoded",
                          "--check")
    assert summary["path"] == "engine"
    assert summary["arch"] == "xlstm-1.3b"
    assert summary["check_passed"] is True
    assert summary["new_tokens"] == 7 * 6


def test_serve_driver_rejects_overflowing_budget_up_front():
    # prompt+new > --max-len must fail in argparse, not mid-generation
    err = _run_driver("--max-new-tokens", "64", expect_fail=True)
    assert "overflows the decode cache" in err
