"""Property suite for ``core.step_weights``: the batched pipeline is
the scalar one row-for-row, stragglers always carry zero weight, and
``block_weights`` is exactly the linear map A @ w -- across randomized
regular, FRC, and irregular/padded assignments (extending the fixed
cases of tests/test_dedup.py).

The properties run twice: over a deterministic seeded sample (always,
so tier-1 pins them even where hypothesis isn't installed) and under
hypothesis fuzzing when available (CI guards that it is).
"""

import numpy as np
import pytest

import repro.core.step_weights as sw
from repro.core import frc_assignment, graph_assignment
from repro.core.assignment import Assignment
from repro.core.graphs import random_regular_graph

try:
    from hypothesis import given, settings, strategies as st

    HAS_HYP = True
except ImportError:  # pragma: no cover - CI fails loudly via the guard
    HAS_HYP = False


def random_assignment(rng: np.random.Generator) -> Assignment:
    """A randomized scheme: graph / FRC / irregular binary A (the
    irregular draw includes padded machines with below-max load and
    guarantees every block and machine is assigned somewhere)."""
    kind = rng.integers(3)
    if kind == 0:
        n = int(rng.choice([4, 6, 8]))
        d = int(rng.choice([2, 3]))
        if (n * d) % 2:
            n += 1
        return graph_assignment(random_regular_graph(n, d, seed=int(
            rng.integers(1 << 16))), name=f"rr_{n}_{d}")
    if kind == 1:
        d = int(rng.choice([2, 3]))
        n = int(rng.integers(2, 5))
        return frc_assignment(n * d, d)
    n = int(rng.integers(2, 6))
    m = int(rng.integers(2, 7))
    A = (rng.random((n, m)) < 0.5).astype(np.float64)
    A[np.arange(n), rng.integers(0, m, size=n)] = 1.0  # no empty block
    A[rng.integers(0, n, size=m), np.arange(m)] = 1.0  # no idle machine
    return Assignment(A=A, name="irregular")


def check_batched_matches_scalar(A: Assignment, masks: np.ndarray,
                                 method: str, p: float) -> None:
    W, alphas = sw.batched_step_weights(A, masks, method=method, p=p)
    assert W.shape == masks.shape and alphas.shape == (len(masks), A.n)
    for t, alive in enumerate(masks):
        w_t, a_t = sw.step_weights(A, alive, method=method, p=p)
        np.testing.assert_array_equal(W[t], w_t)
        np.testing.assert_array_equal(alphas[t], a_t)
        assert not np.any(W[t][~alive]), "stragglers must carry w = 0"


def check_block_weights_linear(A: Assignment, W: np.ndarray) -> None:
    V = sw.block_weights(A, W)
    assert V.shape == (W.shape[0], A.n)
    for t, w in enumerate(W):
        # GEMM rows vs GEMV agree to reduction-order rounding only (the
        # weights here are arbitrary floats, unlike the exact-count
        # fixed path); the scalar form IS A @ w by definition.
        np.testing.assert_allclose(V[t], sw.block_weights(A, w),
                                   rtol=1e-12, atol=1e-14)
        np.testing.assert_array_equal(sw.block_weights(A, w), A.A @ w)
    # linearity: block_weights(a u + b v) == a block_weights(u) + ...
    if len(W) >= 2:
        u, v = W[0], W[1]
        np.testing.assert_allclose(
            sw.block_weights(A, 2.0 * u - 0.5 * v),
            2.0 * sw.block_weights(A, u) - 0.5 * sw.block_weights(A, v),
            rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("method,p", [("optimal", 0.0), ("fixed", 0.3)])
def test_batched_step_weights_matches_scalar_seeded(seed, method, p):
    rng = np.random.default_rng(seed)
    A = random_assignment(rng)
    masks = rng.random((5, A.m)) >= rng.uniform(0.1, 0.6)
    check_batched_matches_scalar(A, masks, method, p)


@pytest.mark.parametrize("seed", range(8))
def test_block_weights_linearity_seeded(seed):
    rng = np.random.default_rng(100 + seed)
    A = random_assignment(rng)
    W = rng.random((4, A.m)) * (rng.random((4, A.m)) > 0.3)
    check_block_weights_linear(A, W)


def test_batched_step_weights_scale_and_empty():
    rng = np.random.default_rng(7)
    A = random_assignment(rng)
    masks = rng.random((3, A.m)) >= 0.4
    W1, a1 = sw.batched_step_weights(A, masks, scale=1.0)
    W2, a2 = sw.batched_step_weights(A, masks, scale=2.5)
    np.testing.assert_allclose(W2, 2.5 * W1, rtol=1e-12)
    np.testing.assert_allclose(a2, 2.5 * a1, rtol=1e-12)
    W0, a0 = sw.batched_step_weights(
        A, np.zeros((0, A.m), dtype=bool))
    assert W0.shape == (0, A.m) and a0.shape == (0, A.n)


if HAS_HYP:

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2 ** 31 - 1),
           method_p=st.sampled_from([("optimal", 0.0), ("fixed", 0.25),
                                     ("fixed", 0.6)]),
           trials=st.integers(1, 6),
           thresh=st.floats(0.0, 0.9))
    def test_batched_step_weights_matches_scalar_hyp(seed, method_p,
                                                     trials, thresh):
        method, p = method_p
        rng = np.random.default_rng(seed)
        A = random_assignment(rng)
        masks = rng.random((trials, A.m)) >= thresh
        check_batched_matches_scalar(A, masks, method, p)

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2 ** 31 - 1), rows=st.integers(1, 5))
    def test_block_weights_linearity_hyp(seed, rows):
        rng = np.random.default_rng(seed)
        A = random_assignment(rng)
        W = rng.standard_normal((rows, A.m))
        check_block_weights_linear(A, W)
