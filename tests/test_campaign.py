"""Differential harness for the multi-scheme campaign engine.

Every fast path names its oracle (the repo's differential-testing
convention):

* ``sweep_campaign`` rows  ==  per-scheme ``sweep_error``  ==
  per-point ``monte_carlo_error`` -- BIT-FOR-BIT on mean/std (and on
  cov_norm when both sides use the same per-point cov method), across
  randomized (scheme mix, m, d, p_grid, trials) draws. This covers the
  stacked exact-counts fixed/FRC GEMMs, the shared-uniform mask stacks,
  and the warm-started graph decode chains.
* blocked lockstep Lanczos == per-point Lanczos == dense SVD to 1e-8
  (float64 CPU path; the TPU float32 kernel carries a coarser bound,
  handled as in tests/test_sweep.py).

The properties run over a deterministic seeded sample (always) and
under hypothesis fuzzing when available (CI guards that it is).
"""

import numpy as np
import pytest

from repro.core import (CampaignEntry, adjacency_assignment,
                        adversarial_mask, batched_alpha,
                        bernoulli_assignment, expander_assignment,
                        frc_assignment, graph_assignment,
                        monte_carlo_error, random_regular_graph,
                        sweep_campaign, sweep_error, uncoded_assignment)
from repro.kernels.batched_alpha import ops as ba_ops
from repro.kernels.spectral_matvec import ops as sm_ops

try:
    from hypothesis import given, settings, strategies as st

    HAS_HYP = True
except ImportError:  # pragma: no cover - CI fails loudly via the guard
    HAS_HYP = False

# float64 contract off-TPU; coarse bound when the f32 Pallas path runs
COV_TOL = 1e-8 if not sm_ops.uses_pallas() else 5e-3


def random_scheme_mix(rng: np.random.Generator):
    """A randomized cross-scheme campaign: graph schemes (the Def II.2
    fast decoder), FRC closed form, uncoded, adjacency (pseudoinverse
    fallback) -- mixed methods, possibly several machine counts."""
    mixes = []
    n_entries = rng.integers(2, 5)
    for i in range(int(n_entries)):
        kind = rng.integers(5)
        d = int(rng.choice([2, 3, 4]))
        if kind == 0:
            n = int(rng.choice([6, 8, 12]))
            if (n * d) % 2:
                n += 1
            g = random_regular_graph(n, d, seed=int(rng.integers(1000)))
            A = graph_assignment(g, name=f"rr{i}_{n}_{d}")
            method = "optimal" if rng.random() < 0.7 else "fixed"
        elif kind == 1:
            A = frc_assignment(int(rng.integers(2, 5)) * d, d)
            method = "optimal"
        elif kind == 2:
            A = uncoded_assignment(int(rng.integers(4, 12)))
            method = "fixed"
        elif kind == 3:
            g = random_regular_graph(8, d if d % 2 == 0 else d + 1,
                                     seed=int(rng.integers(1000)))
            A = adjacency_assignment(g, name=f"adj{i}")
            method = "optimal"
        else:
            A = bernoulli_assignment(4, 10, 3,
                                     seed=int(rng.integers(1000)))
            method = "optimal"
        mixes.append(CampaignEntry(A, method, label=f"e{i}:{A.name}"))
    return mixes


def check_campaign_differential(seed: int, trials: int,
                                p_grid) -> None:
    rng = np.random.default_rng(seed)
    entries = random_scheme_mix(rng)
    camp = sweep_campaign(entries, p_grid, trials=trials, seed=seed,
                          cov_method="dense")
    for e in entries:
        label = e.resolved_label()
        rows = sweep_error(e.assignment, p_grid, trials=trials,
                           method=e.method, seed=seed,
                           cov_method="dense")
        assert len(camp[label]) == len(rows)
        for p, r_c, r_s in zip(p_grid, camp[label], rows):
            mc = monte_carlo_error(e.assignment, p, trials=trials,
                                   method=e.method, seed=seed,
                                   cov_method="dense")
            assert r_c["p"] == r_s["p"] == p
            # the tentpole contract: bit-for-bit, all three layers
            assert r_c["mean_error"] == r_s["mean_error"] == \
                mc["mean_error"]
            assert r_c["std_error"] == r_s["std_error"] == \
                mc["std_error"]
            assert r_c["cov_norm"] == r_s["cov_norm"] == mc["cov_norm"]


@pytest.mark.parametrize("seed,trials,p_grid", [
    (0, 12, (0.1, 0.3)),
    (1, 7, (0.45, 0.05, 0.2)),       # unsorted grid
    (2, 20, (0.3,)),                 # single point
    (3, 5, (0.6, 0.25, 0.1, 0.02)),
    (4, 16, (0.15, 0.35)),
])
def test_campaign_differential_seeded(seed, trials, p_grid):
    check_campaign_differential(seed, trials, p_grid)


def test_campaign_blocked_cov_matches_dense_and_lanczos():
    A = expander_assignment(24, 3, vertex_transitive=False, seed=1)
    F = frc_assignment(24, 3)
    entries = [(A, "optimal"), (A, "fixed"), (F, "optimal")]
    grid = (0.1, 0.3, 0.5)
    dense = sweep_campaign(entries, grid, trials=40, seed=3,
                           cov_method="dense")
    lanc = sweep_campaign(entries, grid, trials=40, seed=3,
                          cov_method="lanczos")
    blocked = sweep_campaign(entries, grid, trials=40, seed=3,
                             cov_method="blocked")
    for label in dense:
        for r_d, r_l, r_b in zip(dense[label], lanc[label],
                                 blocked[label]):
            # mean/std identical on every cov path
            assert r_d["mean_error"] == r_l["mean_error"] == \
                r_b["mean_error"]
            scale = max(abs(r_d["cov_norm"]), 1.0)
            # blocked == per-point lanczos == dense SVD to 1e-8
            assert abs(r_l["cov_norm"] - r_d["cov_norm"]) <= \
                COV_TOL * scale
            assert abs(r_b["cov_norm"] - r_d["cov_norm"]) <= \
                COV_TOL * scale
            assert abs(r_b["cov_norm"] - r_l["cov_norm"]) <= \
                COV_TOL * scale
    # per-point cov methods in the campaign are bit-identical to the
    # per-scheme sweep oracle (same arithmetic, same order)
    for (S, method) in entries:
        rows = sweep_error(S, grid, trials=40, method=method, seed=3,
                           cov_method="lanczos")
        for r_c, r_s in zip(lanc[f"{S.name}:{method}"], rows):
            assert r_c["cov_norm"] == r_s["cov_norm"]


def test_campaign_mask_stack_entries():
    """Adversarial-stack entries: explicit (P, T, m) masks bypass the
    shared draw; rows must equal direct batched decodes of the stack
    (debias off -> raw (1/n)|alpha - 1|^2)."""
    A = expander_assignment(24, 3, vertex_transitive=False, seed=1)
    grid = (0.2, 0.4)
    masks = np.stack([adversarial_mask(A, p) for p in grid])[:, None, :]
    camp = sweep_campaign(
        [CampaignEntry(A, "optimal", label="attack", debias=False,
                       masks=masks)],
        grid, trials=1, cov=False)
    for i, p in enumerate(grid):
        alphas = batched_alpha(A, masks[i], method="optimal")
        errs, scale = ba_ops.fused_error(alphas, debias=False)
        assert scale == 1.0
        assert camp["attack"][i]["mean_error"] == float(errs.mean())


def test_campaign_topk_spectrum_rows():
    A = expander_assignment(24, 3, vertex_transitive=False, seed=1)
    camp = sweep_campaign([(A, "optimal")], (0.3, 0.5), trials=30,
                          seed=2, cov_method="dense", cov_topk=4)
    from repro.core import covariance_topk
    from repro.core.batched_decoding import batched_alpha as ba
    from repro.core.sweep import bernoulli_uniforms

    u = bernoulli_uniforms(A.m, 30, seed=2)
    for row, p in zip(camp[f"{A.name}:optimal"], (0.3, 0.5)):
        tk = row["cov_topk"]
        assert len(tk) == 4
        assert all(tk[i] >= tk[i + 1] - 1e-12 for i in range(3))
        # top-1 of the spectrum is the spectral norm
        assert abs(tk[0] - row["cov_norm"]) <= \
            COV_TOL * max(row["cov_norm"], 1.0)
        # differential vs the dense oracle on the same scaled alphas
        alphas = ba(A, u >= p, method="optimal")
        _, scale = ba_ops.fused_error(alphas, debias=True)
        dense_tk = covariance_topk(alphas * scale, 4, method="dense")
        np.testing.assert_allclose(tk, dense_tk, atol=COV_TOL,
                                   rtol=COV_TOL)


def test_campaign_entry_forms_and_validation():
    A = expander_assignment(24, 3, vertex_transitive=False, seed=1)
    # bare assignment and tuple forms normalize
    camp = sweep_campaign([A, (A, "fixed"), (A, "optimal", "again")],
                          (0.2,), trials=5, cov=False)
    assert set(camp) == {f"{A.name}:optimal", f"{A.name}:fixed",
                         "again"}
    with pytest.raises(ValueError, match="duplicate"):
        sweep_campaign([A, (A, "optimal")], (0.2,), trials=5)
    with pytest.raises(ValueError, match="at least one"):
        sweep_campaign([], (0.2,), trials=5)
    with pytest.raises(TypeError, match="entry"):
        sweep_campaign(["nope"], (0.2,), trials=5)
    with pytest.raises(ValueError, match="mask stack"):
        sweep_campaign(
            [CampaignEntry(A, masks=np.ones((1, 2, 3), dtype=bool))],
            (0.2, 0.4), trials=2)
    with pytest.raises(ValueError, match="unknown method"):
        sweep_campaign([(A, "wat")], (0.2,), trials=2)


def test_campaign_shares_draws_across_equal_m():
    """Two different schemes with equal m face the same straggler draw
    (the paper's cross-scheme comparison protocol): identical masks =>
    the uncoded fixed rows equal a same-m graph scheme's fixed rows
    whenever A matches, and more to the point the draw comes from
    bernoulli_uniforms(m, trials, seed) exactly once per m."""
    from repro.core.sweep import bernoulli_uniforms

    A = expander_assignment(24, 3, vertex_transitive=False, seed=1)
    U = uncoded_assignment(24)
    grid = (0.25,)
    camp = sweep_campaign([(A, "fixed"), (U, "fixed")], grid, trials=15,
                          seed=11, cov=False)
    # both rows derive from the same uniforms: recompute directly
    u = bernoulli_uniforms(24, 15, seed=11)
    masks = u >= 0.25
    for S, label in ((A, f"{A.name}:fixed"), (U, f"{U.name}:fixed")):
        alphas = batched_alpha(S, masks, method="fixed", p=0.25)
        errs, _ = ba_ops.fused_error(alphas, debias=True)
        assert camp[label][0]["mean_error"] == float(errs.mean())


if HAS_HYP:

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2 ** 31 - 1),
           trials=st.integers(1, 25),
           p_grid=st.lists(st.floats(0.01, 0.8), min_size=1,
                           max_size=4, unique=True))
    def test_campaign_differential_hyp(seed, trials, p_grid):
        check_campaign_differential(seed, trials, tuple(p_grid))
