"""Adversarial attacks vs the brute-force Def I.3 oracle.

On small schemes (m <= 10) every straggler set within the |S| <= pm
budget is enumerable: C(m, floor(pm)) optimal decodes give the TRUE
worst-case decoding error (checking only sets of size exactly
floor(pm) is sound -- shrinking the alive set shrinks the decoder's
feasible set, so the worst case is attained at a full-budget S). The
greedy attacks in ``core.stragglers`` must (a) never exceed the
budget, and (b) attain that worst case on the paper-regime cases --
one known exception is documented below with its measured gap.
"""

import itertools

import numpy as np
import pytest

from repro.core import (AdversarialStragglers, adversarial_mask,
                        bibd_assignment, cycle_graph, complete_graph,
                        cyclic_mds_assignment, decode, frc_assignment,
                        graph_assignment, normalized_error,
                        random_regular_graph)


def brute_force_worst(assignment, p):
    """True worst-case normalized error over all |S| <= floor(pm)."""
    m = assignment.m
    budget = int(np.floor(p * m))
    worst = 0.0
    for S in itertools.combinations(range(m), budget):
        alive = np.ones(m, dtype=bool)
        alive[list(S)] = False
        worst = max(worst, normalized_error(
            decode(assignment, alive, method="optimal").alpha))
    return worst, budget


CASES = [
    ("cycle5", lambda: graph_assignment(cycle_graph(5), name="cycle5")),
    ("cycle7", lambda: graph_assignment(cycle_graph(7), name="cycle7")),
    ("K4", lambda: graph_assignment(complete_graph(4), name="K4")),
    ("rr_n6_d3", lambda: graph_assignment(
        random_regular_graph(6, 3, seed=0), name="rr_n6_d3")),
    ("frc_8_2", lambda: frc_assignment(8, 2)),
    ("frc_9_3", lambda: frc_assignment(9, 3)),
    # Scheme zoo (PR 10): the stride/window portfolio attack for the
    # circulant cyclic-MDS codes and the marginal-error greedy for the
    # block designs, each enumerable at these m.
    ("cyclic_7_3", lambda: cyclic_mds_assignment(7, 3)),
    ("cyclic_8_3", lambda: cyclic_mds_assignment(8, 3)),
    ("cyclic_10_4", lambda: cyclic_mds_assignment(10, 4)),
    ("bibd_fano", lambda: bibd_assignment(7, 3)),
    ("bibd_affine_q2", lambda: bibd_assignment(4, 2, design="affine")),
]


@pytest.mark.parametrize("p", [0.2, 0.3, 0.4])
@pytest.mark.parametrize("name,make", CASES)
def test_attack_attains_brute_force_worst_case(name, make, p):
    A = make()
    worst, budget = brute_force_worst(A, p)
    mask = adversarial_mask(A, p)
    assert int((~mask).sum()) <= budget, \
        f"{name}: attack exceeds the Def I.3 budget"
    attained = normalized_error(decode(A, mask, method="optimal").alpha)
    # Sanity: an attack can never beat the enumerated worst case.
    assert attained <= worst + 1e-12
    # In the paper's p <= 0.4 regime the greedy attacks are exactly
    # worst-case optimal on all these schemes (verified by enumeration;
    # the known sub-optimality lives at larger p, see the gap test).
    assert attained == pytest.approx(worst, abs=1e-12), \
        f"{name} p={p}: greedy attack {attained} < brute force {worst}"


def test_documented_greedy_gap_at_large_p():
    """The greedy vertex-isolation attack is NOT always optimal: on
    this random 3-regular graph at p=0.5 (budget 4 of m=9) the true
    worst case isolates differently and the greedy attack reaches only
    5/6 of it. Documented here with its measured value so a future
    smarter attack shows up as this test failing in the good
    direction."""
    A = graph_assignment(random_regular_graph(6, 3, seed=2),
                         name="rr_n6_d3_seed2")
    worst, budget = brute_force_worst(A, 0.5)
    mask = adversarial_mask(A, 0.5)
    attained = normalized_error(decode(A, mask, method="optimal").alpha)
    assert int((~mask).sum()) <= budget
    assert attained <= worst + 1e-12
    assert worst == pytest.approx(0.2, abs=1e-12)
    assert attained == pytest.approx(1 / 6, abs=1e-12)  # the 5/6 gap
    assert attained >= 0.8 * worst  # never worse than 80% of optimal


def _attack_error(assignment, p):
    mask = adversarial_mask(assignment, p)
    assert int((~mask).sum()) <= int(np.floor(p * assignment.m))
    return normalized_error(decode(assignment, mask, method="optimal").alpha)


def test_bibd_adversarial_advantage_over_cyclic():
    """Kadhe et al.'s claim, pinned at (m=13, d=4): once the straggler
    budget exceeds the replication degree, the pairwise-balanced
    PG(2,3) design takes strictly less worst-case damage than the
    circulant cyclic-MDS code of the same load -- an adversary can
    align consecutive kills with the circulant structure, while the
    BIBD spreads any straggler set's damage evenly (lambda=1: every
    block pair shares exactly one machine). Exact values pinned from
    the portfolio / marginal-greedy attacks, both of which attain the
    C(m, pm) brute-force worst case at enumerable m (test above).

    The flip side is pinned too: at small budgets the ordering
    REVERSES (the claimed advantage is a large-straggler-fraction
    phenomenon, not a blanket dominance).
    """
    bibd = bibd_assignment(13, 4)    # PG(2,3): (13, 4, 1) difference set
    cyclic = cyclic_mds_assignment(13, 4)
    # Budget > d: BIBD strictly better, exact pinned values.
    for p, e_bibd, e_cyc in [(0.39, 15 / 143, 7 / 39),
                             (0.47, 9 / 65, 17 / 65)]:
        got_b, got_c = _attack_error(bibd, p), _attack_error(cyclic, p)
        assert got_b == pytest.approx(e_bibd, rel=1e-9), (p, got_b)
        assert got_c == pytest.approx(e_cyc, rel=1e-9), (p, got_c)
        assert got_b < got_c
    # Small budget (2 < d): cyclic takes less damage than the design.
    assert _attack_error(cyclic, 0.2) < _attack_error(bibd, 0.2)


@pytest.mark.parametrize("p", [0.2, 0.3])
def test_cyclic_window_ties_brute_force_at_m13(p):
    """The Raviv-style consecutive-window kill is exactly worst-case
    for the (13, 4) circulant at these budgets (enumerated here --
    m=13 is above the CASES grid but C(13, <=3) is still cheap), and
    the portfolio attack must attain it. Arithmetic-stride sets tie
    the window (two half-erased windows = one doubly-erased one, same
    quadratic damage) -- the portfolio keeps both families because
    ties are scheme-dependent, not because either dominates."""
    A = cyclic_mds_assignment(13, 4)
    worst, budget = brute_force_worst(A, p)
    window = np.ones(13, dtype=bool)
    window[:budget] = False
    window_err = normalized_error(
        decode(A, window, method="optimal").alpha)
    assert window_err == pytest.approx(worst, abs=1e-12)
    assert _attack_error(A, p) == pytest.approx(worst, abs=1e-12)


@pytest.mark.parametrize("p", [0.2, 0.4])
def test_adversarial_process_respects_budget_and_replays(p):
    """``AdversarialStragglers`` (the Def I.3 *process*) replays one
    fixed attack mask every round, within budget, ignoring the RNG."""
    A = graph_assignment(random_regular_graph(8, 3, seed=1), name="rr8")
    model = AdversarialStragglers(assignment=A, p=p)
    rng = np.random.default_rng(0)
    first = model.sample(rng)
    budget = int(np.floor(p * A.m))
    assert int((~first).sum()) <= budget
    for _ in range(5):
        again = model.sample(np.random.default_rng(rng.integers(1 << 30)))
        np.testing.assert_array_equal(again, first)
    np.testing.assert_array_equal(first, adversarial_mask(A, p))
