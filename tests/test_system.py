"""End-to-end behaviour tests: real coded training runs (loss goes
down, stragglers tolerated), serving generates, configs match the
assignment table, dry-run machinery works on a 1-device mesh."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ALL_SHAPES, ARCH_IDS, CodingConfig,
                           get_config)

EXPECTED = {
    "qwen1.5-4b": dict(n_layers=40, d_model=2560, n_heads=20,
                       n_kv_heads=20, d_ff=6912, vocab_size=151936,
                       qkv_bias=True, arch_type="dense"),
    "zamba2-1.2b": dict(n_layers=38, d_model=2048, n_heads=32,
                        n_kv_heads=32, d_ff=8192, vocab_size=32000,
                        ssm_state=64, arch_type="hybrid"),
    "deepseek-coder-33b": dict(n_layers=62, d_model=7168, n_heads=56,
                               n_kv_heads=8, d_ff=19200,
                               vocab_size=32256, arch_type="dense"),
    "yi-34b": dict(n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
                   d_ff=20480, vocab_size=64000, arch_type="dense"),
    "deepseek-moe-16b": dict(n_layers=28, d_model=2048, n_heads=16,
                             n_kv_heads=16, d_ff=1408,
                             vocab_size=102400, n_experts=64, top_k=6,
                             n_shared_experts=2, arch_type="moe"),
    "llama4-scout-17b-a16e": dict(n_layers=48, d_model=5120, n_heads=40,
                                  n_kv_heads=8, d_ff=8192,
                                  vocab_size=202048, n_experts=16,
                                  top_k=1, arch_type="moe"),
    "granite-3-8b": dict(n_layers=40, d_model=4096, n_heads=32,
                         n_kv_heads=8, d_ff=12800, vocab_size=49155,
                         arch_type="dense"),
    "seamless-m4t-large-v2": dict(n_layers=24, d_model=1024, n_heads=16,
                                  n_kv_heads=16, d_ff=8192,
                                  vocab_size=256206, arch_type="audio"),
    "pixtral-12b": dict(n_layers=40, d_model=5120, n_heads=32,
                        n_kv_heads=8, d_ff=14336, vocab_size=131072,
                        arch_type="vlm"),
    "xlstm-1.3b": dict(n_layers=48, d_model=2048, n_heads=4,
                       n_kv_heads=4, d_ff=0, vocab_size=50304,
                       arch_type="ssm"),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_configs_match_assignment_table(arch):
    cfg = get_config(arch)
    for k, v in EXPECTED[arch].items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
    assert cfg.source  # citation present


def test_shapes_table():
    by = {s.name: s for s in ALL_SHAPES}
    assert by["train_4k"].seq_len == 4096
    assert by["train_4k"].global_batch == 256
    assert by["prefill_32k"].seq_len == 32768
    assert by["decode_32k"].global_batch == 128
    assert by["long_500k"].seq_len == 524288


@pytest.mark.slow
def test_end_to_end_coded_training_loss_decreases():
    from repro.launch import train as train_mod
    out = train_mod.main([
        "--arch", "granite-3-8b", "--steps", "12", "--seq-len", "32",
        "--block-size", "2", "--straggler-p", "0.25"])
    losses = out["losses"]
    assert losses[-1] < losses[0]


@pytest.mark.slow
def test_end_to_end_training_survives_adversarial_stragglers():
    from repro.launch import train as train_mod
    out = train_mod.main([
        "--arch", "xlstm-1.3b", "--steps", "10", "--seq-len", "32",
        "--block-size", "2", "--straggler-model", "adversarial",
        "--straggler-p", "0.25"])
    assert out["losses"][-1] < out["losses"][0]


@pytest.mark.slow
def test_end_to_end_serving_generates():
    from repro.launch import serve as serve_mod
    out = serve_mod.main(["--arch", "zamba2-1.2b", "--batch", "2",
                          "--prompt-len", "8", "--new-tokens", "4",
                          "--max-len", "32"])
    assert out["tokens"].shape == (2, 4)


def test_dryrun_machinery_tiny_mesh():
    """The full step-spec -> lower -> compile -> analysis path on the
    1-device CPU mesh with a smoke config (the 512-device production
    dry-run runs via python -m repro.launch.dryrun)."""
    from repro.configs.base import ShapeSpec
    from repro.dist import coded_train
    from repro.launch import hlo_analysis, specs as specs_mod
    from repro.launch.mesh import make_test_mesh
    from repro.optim import optimizers as opt_mod

    cfg = get_config("qwen1.5-4b").smoke_variant()
    mesh = make_test_mesh((1, 1))
    shape = ShapeSpec("tiny_train", 32, 8, "train")
    coding = CodingConfig(replication=2)
    spec = specs_mod.make_step_spec(cfg, shape, mesh, coding)
    opt = opt_mod.get_optimizer("adamw", 1e-4)
    fn = coded_train.make_train_step(cfg, opt, n_microbatches=2)
    with mesh:
        lowered = jax.jit(fn, in_shardings=spec.in_shardings,
                          out_shardings=spec.out_shardings).lower(
            *spec.args)
        compiled = lowered.compile()
    stats = hlo_analysis.analyze(compiled.as_text())
    assert stats["flops"] > 0
    mem = compiled.memory_analysis()
    assert mem.argument_size_in_bytes > 0


def test_dryrun_machinery_tiny_mesh_fsdp():
    """make_step_spec(fsdp=True) must lower and compile on the tiny
    mesh too -- same path as above with the worker-sharded param
    placement (on 1 worker fsdp_specs degenerates to the replicated
    layout, which pins that the degenerate geometry stays valid)."""
    from repro.configs.base import ShapeSpec
    from repro.dist import coded_train
    from repro.launch import specs as specs_mod
    from repro.launch.mesh import make_test_mesh
    from repro.optim import optimizers as opt_mod

    cfg = get_config("qwen1.5-4b").smoke_variant()
    mesh = make_test_mesh((1, 1))
    shape = ShapeSpec("tiny_train", 32, 8, "train")
    coding = CodingConfig(replication=2)
    spec = specs_mod.make_step_spec(cfg, shape, mesh, coding,
                                    fsdp=True)
    opt = opt_mod.get_optimizer("adamw", 1e-4)
    fn = coded_train.make_train_step(cfg, opt, n_microbatches=2)
    with mesh:
        compiled = jax.jit(fn, in_shardings=spec.in_shardings,
                           out_shardings=spec.out_shardings).lower(
            *spec.args).compile()
    assert compiled.memory_analysis().argument_size_in_bytes > 0


@pytest.mark.slow
def test_fsdp_shrinks_per_device_param_bytes():
    """The PR-8 FSDP acceptance on the production geometry: the
    specs-only dry-run of yi-34b on the 512-device mesh must place
    strictly fewer per-device parameter bytes under --fsdp than the
    replicated baseline (subprocess so the virtual-device count enters
    XLA_FLAGS before jax initialises)."""
    import json as json_mod
    import os
    import subprocess
    import sys

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    results = {}
    for fsdp in (False, True):
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", "yi-34b", "--shape", "train_4k",
               "--specs-only"] + (["--fsdp"] if fsdp else [])
        proc = subprocess.run(cmd, cwd=repo, env=env,
                              capture_output=True, text=True,
                              timeout=420)
        assert proc.returncode == 0, proc.stdout + proc.stderr
        line = [l for l in proc.stdout.splitlines()
                if l.startswith("DRYRUN_SPECS_JSON:")][-1]
        results[fsdp] = json_mod.loads(line.split(":", 1)[1])
    repl, fsdp = results[False], results[True]
    assert repl["fsdp"] is False and fsdp["fsdp"] is True
    assert fsdp["param_bytes_per_device"] < \
        repl["param_bytes_per_device"], (fsdp, repl)
    # the shard factor is the worker count (pod x data axes), so the
    # shrink is substantial, not marginal
    assert fsdp["param_bytes_per_device"] * 8 <= \
        repl["param_bytes_per_device"]


def test_long_500k_skip_policy():
    from repro.launch import specs as specs_mod
    ok, why = specs_mod.long_500k_supported(
        get_config("seamless-m4t-large-v2"))
    assert not ok and "500k" in why
    for arch in ("xlstm-1.3b", "zamba2-1.2b", "qwen1.5-4b"):
        ok, _ = specs_mod.long_500k_supported(get_config(arch))
        assert ok
