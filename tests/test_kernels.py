"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs the pure
jnp oracle in ref.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.batched_alpha import kernel as ba_k, ops as ba_ops, \
    ref as ba_r
from repro.kernels.coded_combine import kernel as cc_k, ref as cc_r
from repro.kernels.decode_attention import kernel as da_k, ref as da_r
from repro.kernels.rmsnorm import kernel as rn_k, ops as rn_ops, \
    ref as rn_r
from repro.kernels.spectral_matvec import kernel as sm_k, ops as sm_ops, \
    ref as sm_r

RNG = np.random.default_rng(0)


def _tol(dt):
    return dict(atol=3e-2, rtol=3e-2) if dt == "bfloat16" else \
        dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("shape", [(4, 128), (3, 5, 256), (64, 512),
                                   (1, 1024), (7, 384)])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_rmsnorm_kernel_matches_ref(shape, dtype):
    x = jnp.asarray(RNG.normal(size=shape), jnp.dtype(dtype))
    s = jnp.asarray(RNG.normal(size=shape[-1]), jnp.dtype(dtype))
    out = rn_k.rmsnorm(x, s, interpret=True)
    ref = rn_r.rmsnorm(x, s)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_rmsnorm_vjp_matches_autodiff():
    x = jnp.asarray(RNG.normal(size=(6, 64)), jnp.float32)
    s = jnp.asarray(RNG.normal(size=64), jnp.float32)

    def via_ops(x, s):
        return (rn_ops.rmsnorm(x, s) ** 2).sum()

    def via_raw(x, s):
        xf = x.astype(jnp.float32)
        var = jnp.mean(xf * xf, -1, keepdims=True)
        return (((xf * (var + 1e-6) ** -0.5) * s) ** 2).sum()

    g1 = jax.grad(via_ops, (0, 1))(x, s)
    g2 = jax.grad(via_raw, (0, 1))(x, s)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("B,H,KVH,S,Dh,bk", [
    (2, 8, 2, 256, 64, 64),
    (1, 4, 4, 128, 32, 128),
    (2, 16, 4, 512, 128, 256),
    (3, 4, 1, 192, 64, 64),
])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_decode_attention_kernel_matches_ref(B, H, KVH, S, Dh, bk,
                                             dtype):
    dt = jnp.dtype(dtype)
    q = jnp.asarray(RNG.normal(size=(B, H, Dh)), dt)
    k = jnp.asarray(RNG.normal(size=(B, S, KVH, Dh)), dt)
    v = jnp.asarray(RNG.normal(size=(B, S, KVH, Dh)), dt)
    lengths = jnp.asarray(RNG.integers(1, S + 1, size=B), jnp.int32)
    out = da_k.decode_attention(q, k, v, lengths, block_k=bk,
                                interpret=True)
    ref = da_r.decode_attention(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_decode_attention_respects_lengths():
    """Tokens beyond `length` must not affect the result."""
    B, H, KVH, S, Dh = 1, 4, 2, 128, 32
    q = jnp.asarray(RNG.normal(size=(B, H, Dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, KVH, Dh)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, KVH, Dh)), jnp.float32)
    lengths = jnp.asarray([40], jnp.int32)
    out1 = da_k.decode_attention(q, k, v, lengths, block_k=32,
                                 interpret=True)
    k2 = k.at[:, 40:].set(999.0)
    v2 = v.at[:, 40:].set(-999.0)
    out2 = da_k.decode_attention(q, k2, v2, lengths, block_k=32,
                                 interpret=True)
    np.testing.assert_allclose(out1, out2, atol=1e-6)


@pytest.mark.parametrize("n,D", [(8, 1000), (24, 4096), (3, 130),
                                 (1, 256), (16, 65536)])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_coded_combine_kernel_matches_ref(n, D, dtype):
    dt = jnp.dtype(dtype)
    g = jnp.asarray(RNG.normal(size=(n, D)), dt)
    w = jnp.asarray(RNG.normal(size=n), jnp.float32)
    out = cc_k.coded_combine(g, w, interpret=True)
    ref = cc_r.coded_combine(g, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def _exact_qsw(rng, n, D, payload):
    """Exactness-preserving quantized-combine inputs: integer payload,
    power-of-two scales and weights (with straggler zeros). Every
    float32 partial sum is exact (n * 127 * 2^spread << 2^24), so the
    combine's bits are independent of accumulation order and FMA
    contraction -- the regime where a bitwise pin is meaningful."""
    q = rng.integers(-127, 128, size=(n, D)).astype(
        np.int8 if payload == "int8" else np.float32)
    s = (2.0 ** rng.integers(-4, 1, size=n)).astype(np.float32)
    w = (rng.choice([-1.0, 0.0, 1.0], size=n)
         * 2.0 ** rng.integers(-2, 3, size=n)).astype(np.float32)
    return q, s, w


@pytest.mark.parametrize("n,D", [(1, 256), (2, 130), (4, 1000),
                                 (7, 61), (16, 4096), (3, 129)])
@pytest.mark.parametrize("payload", ["int8", "float32"])
def test_quantized_combine_kernel_bit_identical_to_np(n, D, payload):
    """The fused dequantize-weight-combine pins BITWISE against the
    exact NumPy oracle on exactness-preserving inputs -- across
    payload dtypes, odd widths that force lane padding, and zeroed
    straggler rows. The jnp fallback must land on the same bits."""
    rng = np.random.default_rng(n * 1000 + D)
    q, s, w = _exact_qsw(rng, n, D, payload)
    ref = cc_r.quantized_combine_np(q, s, w)
    out = cc_k.quantized_combine(jnp.asarray(q), jnp.asarray(s),
                                 jnp.asarray(w), interpret=True)
    np.testing.assert_array_equal(np.asarray(out), ref)
    fallback = jax.jit(cc_r.quantized_combine)(
        jnp.asarray(q), jnp.asarray(s), jnp.asarray(w))
    np.testing.assert_array_equal(np.asarray(fallback), ref)


@pytest.mark.parametrize("n,D", [(2, 73), (5, 700), (6, 69), (16, 4096)])
def test_quantized_combine_general_inputs_tolerance(n, D):
    """General scales/weights: the float32 chain differs from the
    exact f64 oracle by accumulation rounding only (XLA's per-lane FMA
    contraction mix -- see ref.quantized_combine_np), bounded by the
    repo's float32 kernel tolerance."""
    rng = np.random.default_rng(n * 1000 + D)
    q = rng.integers(-127, 128, size=(n, D)).astype(np.int8)
    s = (rng.uniform(0.1, 2.0, size=n)
         * 10.0 ** rng.integers(-2, 3, size=n)).astype(np.float32)
    w = rng.normal(size=n).astype(np.float32)
    ref = np.asarray(cc_r.quantized_combine_np(q, s, w), np.float64)
    out = cc_k.quantized_combine(jnp.asarray(q), jnp.asarray(s),
                                 jnp.asarray(w), interpret=True)
    scale = max(1.0, float(np.abs(ref).max()))
    np.testing.assert_allclose(np.asarray(out, np.float64) / scale,
                               ref / scale, atol=2e-5, rtol=0)
    eager = cc_r.quantized_combine(jnp.asarray(q), jnp.asarray(s),
                                   jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(eager, np.float64) / scale,
                               ref / scale, atol=2e-5, rtol=0)


def test_quantized_combine_matches_dequantized_coded_combine():
    """Semantics, not bit patterns: the fused path equals dequantize-
    then-coded_combine at float tolerance."""
    q = RNG.integers(-127, 128, size=(6, 513)).astype(np.int8)
    s = RNG.uniform(0.1, 2.0, size=6).astype(np.float32)
    w = RNG.normal(size=6).astype(np.float32)
    g = jnp.asarray(q, jnp.float32) * jnp.asarray(s)[:, None]
    out = cc_k.quantized_combine(jnp.asarray(q), jnp.asarray(s),
                                 jnp.asarray(w), interpret=True)
    ref = cc_r.coded_combine(g, jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_quantized_combine_matches_dequantized_coded_combine():
    """Semantics, not bit patterns: the fused path equals dequantize-
    then-coded_combine at float tolerance."""
    q = RNG.integers(-127, 128, size=(6, 513)).astype(np.int8)
    s = RNG.uniform(0.1, 2.0, size=6).astype(np.float32)
    w = RNG.normal(size=6).astype(np.float32)
    g = jnp.asarray(q, jnp.float32) * jnp.asarray(s)[:, None]
    out = cc_k.quantized_combine(jnp.asarray(q), jnp.asarray(s),
                                 jnp.asarray(w), interpret=True)
    ref = cc_r.coded_combine(g, jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def _exact_packed(rng, n, D):
    """Exactness-preserving packed inputs: arbitrary bit payload,
    power-of-two scales, {-1, 0, 1} x power-of-two weights -- every
    product and partial sum is a small exact float32."""
    q = rng.integers(0, 256, size=(n, (D + 7) // 8)).astype(np.uint8)
    s = (2.0 ** rng.integers(-4, 1, size=n)).astype(np.float32)
    w = (rng.choice([-1.0, 0.0, 1.0], size=n)
         * 2.0 ** rng.integers(-2, 3, size=n)).astype(np.float32)
    return q, s, w


@pytest.mark.parametrize("n,D", [(1, 256), (2, 130), (4, 1000),
                                 (7, 61), (16, 4096), (3, 129),
                                 (5, 8)])
def test_packed_sign_combine_kernel_bit_identical_to_np(n, D):
    """The fused unpack-weight-combine pins BITWISE against the exact
    float64 NumPy oracle (np.unpackbits decoder) on exactness-
    preserving inputs -- across widths that are and are not multiples
    of 8 (trailing-byte padding) and zeroed straggler rows. The jnp
    fallback must land on the same bits."""
    rng = np.random.default_rng(n * 1000 + D)
    q, s, w = _exact_packed(rng, n, D)
    ref = cc_r.packed_sign_combine_np(q, s, w, D)
    out = cc_k.packed_sign_combine(jnp.asarray(q), jnp.asarray(s),
                                   jnp.asarray(w), d=D, interpret=True)
    assert out.shape == (D,)
    np.testing.assert_array_equal(np.asarray(out), ref)
    fallback = cc_r.packed_sign_combine(jnp.asarray(q), jnp.asarray(s),
                                        jnp.asarray(w), D)
    np.testing.assert_array_equal(np.asarray(fallback), ref)


@pytest.mark.parametrize("block_db", [8, 128, None])
def test_packed_sign_combine_block_db_variants(block_db):
    """Grid tiling over the packed axis cannot change a single bit."""
    rng = np.random.default_rng(9)
    D = 3000  # padded packed axis: 375 bytes -> lane-aligned tiles
    q, s, w = _exact_packed(rng, 4, D)
    ref = cc_r.packed_sign_combine_np(q, s, w, D)
    out = cc_k.packed_sign_combine(jnp.asarray(q), jnp.asarray(s),
                                   jnp.asarray(w), d=D,
                                   block_db=block_db, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), ref)


def test_packed_sign_combine_general_inputs_tolerance():
    """General scales/weights: float32 accumulation vs the f64 oracle,
    bounded by the repo's kernel tolerance."""
    rng = np.random.default_rng(17)
    n, D = 6, 700
    q = rng.integers(0, 256, size=(n, (D + 7) // 8)).astype(np.uint8)
    s = (rng.uniform(0.1, 2.0, size=n)
         * 10.0 ** rng.integers(-2, 3, size=n)).astype(np.float32)
    w = rng.normal(size=n).astype(np.float32)
    ref = np.asarray(cc_r.packed_sign_combine_np(q, s, w, D),
                     np.float64)
    out = cc_k.packed_sign_combine(jnp.asarray(q), jnp.asarray(s),
                                   jnp.asarray(w), d=D, interpret=True)
    scale = max(1.0, float(np.abs(ref).max()))
    np.testing.assert_allclose(np.asarray(out, np.float64) / scale,
                               ref / scale, atol=2e-5, rtol=0)


def test_dead_rows_cannot_influence_packed_combine():
    """w_j == 0 zeroes u_j = w_j * s_j exactly: perturbing a straggler
    row's packed payload must leave the combine BITWISE unchanged."""
    rng = np.random.default_rng(5)
    D = 400
    q, s, w = _exact_packed(rng, 5, D)
    w[1] = 0.0
    w[3] = 0.0
    q2 = q.copy()
    q2[1] = 0xFF
    q2[3] = 0x00
    for fn in (lambda *a: cc_r.packed_sign_combine_np(*a, D),
               lambda *a: cc_k.packed_sign_combine(
                   *map(jnp.asarray, a), d=D, interpret=True)):
        np.testing.assert_array_equal(np.asarray(fn(q, s, w)),
                                      np.asarray(fn(q2, s, w)))


def test_packed_sign_combine_rejects_mismatched_width():
    q = jnp.zeros((2, 4), jnp.uint8)
    with pytest.raises(ValueError, match="width"):
        cc_k.packed_sign_combine(q, jnp.ones(2), jnp.ones(2), d=64,
                                 interpret=True)


@pytest.mark.parametrize("T,n,bt", [(4, 128, None), (10, 130, 8),
                                    (64, 1000, 16), (1, 256, None),
                                    (33, 384, 8)])
def test_batched_alpha_fused_error_kernel_matches_ref(T, n, bt):
    a = RNG.normal(loc=1.0, scale=0.2, size=(T, n))
    scale = float(RNG.uniform(0.5, 1.5))
    out = ba_k.fused_error(jnp.asarray(a, jnp.float32),
                           jnp.float32(scale), block_t=bt,
                           interpret=True)
    ref = ba_r.fused_error(a, scale)
    np.testing.assert_allclose(np.asarray(out, np.float64), ref,
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("R,k,br", [(64, 16, None), (100, 1, 16),
                                    (256, 130, 32), (33, 64, None),
                                    (17, 384, 8), (2184, 30, None)])
def test_spectral_matvec_kernel_matches_ref(R, k, br):
    x = RNG.normal(size=(R, k))
    v = RNG.normal(size=k)
    out = sm_k.gram_matvec(jnp.asarray(x, jnp.float32),
                           jnp.asarray(v, jnp.float32), block_r=br,
                           interpret=True)
    ref = sm_r.gram_matvec(x, v)
    scale = max(1.0, float(np.abs(ref).max()))
    np.testing.assert_allclose(np.asarray(out, np.float64) / scale,
                               ref / scale, atol=5e-6, rtol=0)


def test_spectral_matvec_ops_is_float64_oracle_on_cpu():
    x = RNG.normal(size=(50, 7))
    v = RNG.normal(size=7)
    np.testing.assert_array_equal(sm_ops.gram_matvec(x, v),
                                  sm_r.gram_matvec(x, v))
    with pytest.raises(ValueError, match="R, k"):
        sm_ops.gram_matvec(x, np.ones(3))


@pytest.mark.parametrize("R,k,bv", [(64, 16, 1), (100, 30, 4),
                                    (33, 130, 7), (2184, 30, 3)])
def test_spectral_matvec_block_kernel_matches_ref(R, k, bv):
    """The widened-tile block form: bv right-hand sides per pass."""
    x = RNG.normal(size=(R, k))
    V = RNG.normal(size=(k, bv))
    out = sm_k.gram_matvec(jnp.asarray(x, jnp.float32),
                           jnp.asarray(V.T, jnp.float32),
                           interpret=True)
    ref = sm_r.gram_matvec_block(x, V)
    scale = max(1.0, float(np.abs(ref).max()))
    np.testing.assert_allclose(np.asarray(out, np.float64).T / scale,
                               ref / scale, atol=5e-6, rtol=0)


@pytest.mark.parametrize("B,R,k,br", [(1, 64, 16, None), (5, 100, 30, 16),
                                      (3, 33, 130, 8), (12, 2184, 30, None)])
def test_spectral_matvec_batch_kernel_matches_ref(B, R, k, br):
    """The lockstep batch form: grid (B, R // br), one accumulator tile
    per slice."""
    x = RNG.normal(size=(B, R, k))
    v = RNG.normal(size=(B, k))
    out = sm_k.gram_matvec_batch(jnp.asarray(x, jnp.float32),
                                 jnp.asarray(v, jnp.float32),
                                 block_r=br, interpret=True)
    ref = sm_r.gram_matvec_batch(x, v)
    scale = max(1.0, float(np.abs(ref).max()))
    np.testing.assert_allclose(np.asarray(out, np.float64) / scale,
                               ref / scale, atol=5e-6, rtol=0)


def test_spectral_matvec_block_and_batch_ops_oracle_on_cpu():
    x = RNG.normal(size=(40, 9))
    V = RNG.normal(size=(9, 3))
    np.testing.assert_array_equal(sm_ops.gram_matvec_block(x, V),
                                  sm_r.gram_matvec_block(x, V))
    xb = RNG.normal(size=(4, 40, 9))
    vb = RNG.normal(size=(4, 9))
    np.testing.assert_array_equal(sm_ops.gram_matvec_batch(xb, vb),
                                  sm_r.gram_matvec_batch(xb, vb))
    # batch oracle == single-slice oracle per slice, by construction
    for i in range(4):
        np.testing.assert_array_equal(
            sm_r.gram_matvec_batch(xb, vb)[i],
            sm_r.gram_matvec(xb[i], vb[i]))
    with pytest.raises(ValueError, match="k, b"):
        sm_ops.gram_matvec_block(x, np.ones((3, 2)))
    with pytest.raises(ValueError, match="B, R, k"):
        sm_ops.gram_matvec_batch(xb, np.ones((4, 3)))


def test_batched_alpha_ops_debias_matches_debias_alpha():
    from repro.core.decoding import debias_alpha

    a = RNG.normal(loc=1.0, scale=0.1, size=(32, 24))
    errs, scale = ba_ops.fused_error(a, debias=True)
    ab = debias_alpha(a)
    np.testing.assert_array_equal(errs, np.mean((ab - 1.0) ** 2, axis=1))
    np.testing.assert_array_equal(a * scale, ab)
    errs0, scale0 = ba_ops.fused_error(a, debias=False)
    assert scale0 == 1.0
    np.testing.assert_array_equal(errs0, np.mean((a - 1.0) ** 2, axis=1))


def test_coded_combine_tree():
    from repro.kernels.coded_combine import ops
    tree = {"a": jnp.arange(12.0).reshape(4, 3),
            "b": jnp.ones((4, 2, 2))}
    w = jnp.asarray([1.0, 0.0, 2.0, 0.5])
    out = ops.coded_combine_tree(tree, w)
    np.testing.assert_allclose(
        out["a"], (tree["a"] * w[:, None]).sum(0), rtol=1e-6)
    np.testing.assert_allclose(out["b"], 3.5 * jnp.ones((2, 2)),
                               rtol=1e-6)
