"""Decoder correctness: the O(m) component decoder must agree with the
pseudoinverse (Eq. 9) on every straggler pattern -- property-tested
with hypothesis over random graphs and masks."""

import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis "
                           "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import (decode, expander_assignment, fixed_decode,
                        frc_assignment, graph_assignment,
                        normalized_error, optimal_alpha_graph,
                        optimal_decode_frc, optimal_decode_graph,
                        optimal_decode_pinv, random_regular_graph)


@st.composite
def graph_and_mask(draw):
    n = draw(st.integers(4, 24))
    d = draw(st.integers(2, min(n - 1, 6)))
    if (n * d) % 2:
        n += 1
    seed = draw(st.integers(0, 10_000))
    try:
        g = random_regular_graph(n, d, seed=seed)
    except RuntimeError:
        pytest.skip("no simple regular graph sampled")
    alive = draw(st.lists(st.booleans(), min_size=g.m, max_size=g.m))
    return g, np.asarray(alive, bool)


@given(graph_and_mask())
@settings(max_examples=60, deadline=None)
def test_graph_decoder_matches_pseudoinverse(gm):
    g, alive = gm
    A = graph_assignment(g)
    res = optimal_decode_graph(g, alive)
    ref = optimal_decode_pinv(A, alive)
    np.testing.assert_allclose(res.alpha, ref.alpha, atol=1e-6)
    # w is a valid certificate: A w == alpha and w = 0 on stragglers
    np.testing.assert_allclose(A.A @ res.w, res.alpha, atol=1e-6)
    assert (res.w[~alive] == 0).all()


@given(graph_and_mask())
@settings(max_examples=40, deadline=None)
def test_optimality_no_better_w_exists(gm):
    """alpha* is the projection: any random feasible w does no better."""
    g, alive = gm
    A = graph_assignment(g)
    res = optimal_decode_graph(g, alive)
    err_opt = res.error()
    rng = np.random.default_rng(0)
    for _ in range(5):
        w = rng.normal(size=g.m)
        w[~alive] = 0.0
        err = float(np.sum((A.A @ w - 1.0) ** 2))
        assert err >= err_opt - 1e-8


def test_component_characterisation_cycle():
    """Section III worked example: a path (bipartite) component."""
    from repro.core.graphs import cycle_graph
    g = cycle_graph(4)  # square: bipartite when whole
    # kill one edge -> path of 4 vertices: sides 2/2 balanced -> alpha=1
    alive = np.array([True, True, True, False])
    alpha = optimal_alpha_graph(g, alive)
    np.testing.assert_allclose(alpha, 1.0, atol=1e-9)
    # kill two adjacent edges -> path of 3 + isolated vertex
    alive = np.array([True, True, False, False])
    alpha = optimal_alpha_graph(g, alive)
    # path 0-1-2: L={0,2}, R={1}: alpha = 1 -/+ 1/3; vertex 3 isolated
    np.testing.assert_allclose(
        sorted(alpha), sorted([1 - 1 / 3, 1 + 1 / 3, 1 - 1 / 3, 0.0]),
        atol=1e-9)


def test_odd_cycle_gives_exact_recovery():
    from repro.core.graphs import cycle_graph
    g = cycle_graph(5)  # odd cycle, non-bipartite
    alive = np.ones(5, bool)
    res = optimal_decode_graph(g, alive)
    np.testing.assert_allclose(res.alpha, 1.0, atol=1e-9)
    np.testing.assert_allclose(res.w, 0.5, atol=1e-9)


def test_frc_closed_form():
    A = frc_assignment(12, 3)
    rng = np.random.default_rng(0)
    for _ in range(20):
        alive = rng.random(12) >= 0.4
        res = optimal_decode_frc(A, alive)
        ref = optimal_decode_pinv(A, alive)
        np.testing.assert_allclose(res.alpha, ref.alpha, atol=1e-9)


def test_fixed_decoding_unbiased():
    A = expander_assignment(24, 4, vertex_transitive=False, seed=0)
    p = 0.25
    rng = np.random.default_rng(1)
    acc = np.zeros(A.n)
    trials = 4000
    for _ in range(trials):
        alive = rng.random(A.m) >= p
        acc += fixed_decode(A, alive, p).alpha
    np.testing.assert_allclose(acc / trials, 1.0, atol=0.05)


def test_decode_dispatch():
    A = expander_assignment(16, 4, vertex_transitive=False, seed=0)
    alive = np.ones(16, bool)
    res = decode(A, alive, method="optimal")
    assert normalized_error(res.alpha) < 1e-12
