"""Property suite for ``core.compress`` + the compressed train step.

Pins: codec round-trip bounds (int8 error <= scale/2, sign payload in
{-1, 0, 1}); the np/jnp codec pair is bitwise for int8 (elementwise
IEEE chain) and tolerance-only for sign's summation-order-sensitive
mean; the error-feedback telescoping identity
``sum_t dequant_t == sum_t g_t - e_T``; straggler rows (w_j == 0)
cannot influence the quantized combine bitwise; and the compressed
train step under the 'none' codec is differentially pinned against the
baseline fused-autodiff step at the repo's vmapped-combine tolerance
(rtol=2e-4 -- test_dist.py precedent).

The randomized properties run twice: over a deterministic seeded
sample (always, so tier-1 pins them even where hypothesis isn't
installed) and under hypothesis fuzzing when available (CI guards that
it is).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import compress as cm
from repro.core import expander_assignment
from repro.data.pipeline import CodedBatcher, SyntheticLM
from repro.dist import coded_train
from repro.kernels.coded_combine import ops as cc_ops, ref as cc_r
from repro.launch.mesh import make_test_mesh
from repro.models import model as M
from repro.optim import optimizers as opt_mod

try:
    from hypothesis import given, settings, strategies as st

    HAS_HYP = True
except ImportError:  # pragma: no cover - CI fails loudly via the guard
    HAS_HYP = False

RNG = np.random.default_rng(0)
KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Codec round-trips
# ---------------------------------------------------------------------------


def check_int8_roundtrip(g: np.ndarray) -> None:
    codec = cm.get_codec("int8")
    q, s = codec.compress(g, xp=np)
    assert q.dtype == np.int8 and s.dtype == np.float32
    deq = codec.decompress(q, s, xp=np)
    # round-to-nearest onto the symmetric grid: error <= scale/2 per
    # component (tiny slack for the float division)
    bound = s[..., None] * (0.5 + 1e-5)
    assert np.all(np.abs(deq - g.astype(np.float32)) <= bound)
    # all-zero rows take scale 1 and quantize to exactly 0
    zrow = ~np.any(g, axis=-1)
    assert np.all(s[zrow] == 1.0) and not np.any(q[zrow])


def check_sign_roundtrip(g: np.ndarray) -> None:
    codec = cm.get_codec("sign")
    q, s = codec.compress(g, xp=np)
    assert q.dtype == np.int8
    assert np.all(np.isin(q, (-1, 0, 1)))
    np.testing.assert_allclose(
        s, np.mean(np.abs(g), axis=-1).astype(np.float32), rtol=1e-6)
    # the L1 scale makes the round-trip correlate positively with g
    # wherever g is nonzero (the signSGD descent-direction property)
    deq = codec.decompress(q, s, xp=np)
    live = np.any(g, axis=-1)
    assert np.all((deq * g).sum(axis=-1)[live] > 0)


def check_sign_packed_roundtrip(g: np.ndarray) -> None:
    codec = cm.get_codec("sign_packed")
    d = g.shape[-1]
    q, s = codec.compress(g, xp=np)
    assert q.dtype == np.uint8
    assert q.shape == g.shape[:-1] + (cm.packed_width(d),)
    # same L1 scale as the unpacked sign codec
    np.testing.assert_allclose(
        s, np.mean(np.abs(g), axis=-1).astype(np.float32), rtol=1e-6)
    deq = codec.decompress(q, s, xp=np, d=d)
    assert deq.shape == g.shape
    # bit convention: g >= 0 -> +scale, g < 0 -> -scale; agrees with
    # the unpacked sign codec's dequantized value wherever g != 0 (the
    # g == 0 disagreement -- packed says +scale, sign says 0 -- is
    # absorbed by error feedback)
    sq, ss = cm.get_codec("sign").compress(g, xp=np)
    sdeq = cm.get_codec("sign").decompress(sq, ss, xp=np)
    np.testing.assert_array_equal(deq[g != 0], sdeq[g != 0])
    live = np.any(g, axis=-1)
    assert np.all((deq * g).sum(axis=-1)[live] > 0)


def _random_rows(rng: np.random.Generator) -> np.ndarray:
    rows = int(rng.integers(1, 6))
    d = int(rng.integers(1, 600))
    g = rng.normal(size=(rows, d)) * 10.0 ** rng.integers(-3, 3)
    if rng.random() < 0.3:
        g[rng.integers(rows)] = 0.0  # exercise the amax == 0 guard
    return g.astype(np.float32)


def test_roundtrip_bounds_seeded():
    rng = np.random.default_rng(7)
    for _ in range(20):
        g = _random_rows(rng)
        check_int8_roundtrip(g)
        check_sign_roundtrip(g)
        check_sign_packed_roundtrip(g)


if HAS_HYP:

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1))
    def test_roundtrip_bounds_hyp(seed):
        g = _random_rows(np.random.default_rng(seed))
        check_int8_roundtrip(g)
        check_sign_roundtrip(g)
        check_sign_packed_roundtrip(g)


def test_none_codec_is_float32_passthrough():
    g = RNG.normal(size=(3, 40)).astype(np.float32)
    codec = cm.get_codec("none")
    q, s = codec.compress(g, xp=np)
    np.testing.assert_array_equal(q, g)
    np.testing.assert_array_equal(s, np.ones(3, np.float32))
    np.testing.assert_array_equal(codec.decompress(q, s, xp=np), g)


def test_int8_codec_np_jnp_bitwise():
    """The int8 chain (amax / round / clip / cast) is elementwise IEEE:
    the on-device compression must match the host reference bitwise."""
    for shape in [(4, 257), (1, 8), (6, 1024)]:
        g = RNG.normal(size=shape).astype(np.float32) * 3.0
        codec = cm.get_codec("int8")
        qn, sn = codec.compress(g, xp=np)
        qj, sj = jax.jit(codec.compress)(jnp.asarray(g))
        np.testing.assert_array_equal(qn, np.asarray(qj))
        np.testing.assert_array_equal(sn, np.asarray(sj))


def test_sign_codec_np_jnp_payload_bitwise_scale_close():
    """sign's payload is elementwise (bitwise); its mean-|g| scale is
    summation-order sensitive, hence tolerance only."""
    g = RNG.normal(size=(5, 700)).astype(np.float32)
    codec = cm.get_codec("sign")
    qn, sn = codec.compress(g, xp=np)
    qj, sj = jax.jit(codec.compress)(jnp.asarray(g))
    np.testing.assert_array_equal(qn, np.asarray(qj))
    np.testing.assert_allclose(sn, np.asarray(sj), rtol=1e-6)


@pytest.mark.parametrize("d", [1, 7, 8, 9, 64, 700])
def test_pack_unpack_signs_inverse_and_unpackbits_oracle(d):
    """pack_signs/unpack_signs are exact inverses at every width (incl.
    non-multiples of 8), np and jnp agree bitwise (pure integer
    shift/mask arithmetic), and numpy's own np.unpackbits little-endian
    decoder reads the same bits back -- an independent check of the
    bit convention."""
    rng = np.random.default_rng(d)
    bits = rng.integers(0, 2, size=(3, d)).astype(np.uint8)
    qn = cm.pack_signs(bits, np)
    qj = cm.pack_signs(jnp.asarray(bits), jnp)
    assert qn.shape == (3, cm.packed_width(d))
    np.testing.assert_array_equal(qn, np.asarray(qj))
    np.testing.assert_array_equal(cm.unpack_signs(qn, np, d=d), bits)
    np.testing.assert_array_equal(
        np.asarray(cm.unpack_signs(qj, jnp, d=d)), bits)
    oracle = np.unpackbits(qn, axis=-1, bitorder="little")[:, :d]
    np.testing.assert_array_equal(oracle, bits)


def test_sign_packed_codec_np_jnp_payload_bitwise_scale_close():
    """Like the unpacked sign codec: the packed payload is pure integer
    arithmetic (bitwise np == jnp); the mean-|g| scale is summation-
    order sensitive, hence tolerance only."""
    g = RNG.normal(size=(5, 700)).astype(np.float32)
    codec = cm.get_codec("sign_packed")
    qn, sn = codec.compress(g, xp=np)
    qj, sj = jax.jit(codec.compress)(jnp.asarray(g))
    np.testing.assert_array_equal(qn, np.asarray(qj))
    np.testing.assert_allclose(sn, np.asarray(sj), rtol=1e-6)


def test_get_codec_rejects_unknown():
    with pytest.raises(ValueError, match="unknown codec"):
        cm.get_codec("fp4")
    assert cm.get_codec(cm.CODECS["int8"]) is cm.CODECS["int8"]


# ---------------------------------------------------------------------------
# Error feedback
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["sign", "int8", "sign_packed"])
def test_error_feedback_telescopes(name):
    """e_{t+1} = (g_t + e_t) - dequant_t telescopes:
    sum_t dequant_t == sum_t g_t - e_T. The codec's bias is bounded by
    a single residual, not accumulated -- the property that makes the
    biased sign codec convergent."""
    codec = cm.get_codec(name)
    rng = np.random.default_rng(3)
    rows, d, T = 4, 300, 12
    e = np.zeros((rows, d), np.float64)
    sum_g = np.zeros((rows, d), np.float64)
    sum_deq = np.zeros((rows, d), np.float64)
    s = None
    for _ in range(T):
        g = rng.normal(size=(rows, d))
        pre = (g + e).astype(np.float32)
        q, s = codec.compress(pre, xp=np)
        deq = np.asarray(codec.decompress(q, s, xp=np, d=d),
                         np.float64)
        e = pre.astype(np.float64) - deq
        sum_g += g
        sum_deq += deq
    np.testing.assert_allclose(sum_deq, sum_g - e, rtol=1e-4, atol=1e-4)
    # the residual is bounded by one quantization step, never the T
    # accumulated ones: int8's by half the final row scale
    if name == "int8":
        assert np.all(np.abs(e) <= s[:, None] * (0.5 + 1e-5))


def test_init_state_shapes():
    params = {"a": jnp.zeros((3, 5)), "b": {"c": jnp.zeros(7)}}
    state = cm.init_state(params, rows=4)
    assert state["residual"]["a"].shape == (4, 3, 5)
    assert state["residual"]["b"]["c"].shape == (4, 7)
    assert all(not l.any() for l in jax.tree.leaves(state))
    with pytest.raises(ValueError, match="rows"):
        cm.init_state(params, rows=0)


def test_comm_bytes_per_step():
    params = {"a": jnp.zeros((3, 5)), "b": jnp.zeros(9)}  # 24 comps
    assert cm.comm_bytes_per_step(None, 4, params) == 4 * 24 * 4
    assert cm.comm_bytes_per_step(cm.get_codec("int8"), 4, params) \
        == 4 * (24 + 2 * 4)
    # sign ships the same int8 container + scales as int8
    assert cm.comm_bytes_per_step(cm.get_codec("sign"), 4, params) \
        == cm.comm_bytes_per_step(cm.get_codec("int8"), 4, params)
    # sign_packed ships ceil(size/8) bytes per leaf: ceil(15/8) +
    # ceil(9/8) = 2 + 2 payload bytes + two float32 scales, per row
    assert cm.comm_bytes_per_step(cm.get_codec("sign_packed"), 4,
                                  params) == 4 * ((2 + 2) + 2 * 4)


def test_sign_packed_comm_ratio_under_5_percent():
    """At realistic leaf sizes the packed wire payload is ~1/32 of the
    float32 combine -- the <= 0.05x acceptance the benchmark comm
    report enforces."""
    params = {"w": jnp.zeros((256, 128)), "b": jnp.zeros(512)}
    packed = cm.comm_bytes_per_step(cm.get_codec("sign_packed"), 4,
                                    params)
    f32 = cm.comm_bytes_per_step(None, 4, params)
    assert packed <= 0.05 * f32
    # and the unpacked sign codec does NOT clear that bar
    sign = cm.comm_bytes_per_step(cm.get_codec("sign"), 4, params)
    assert sign > 0.05 * f32


# ---------------------------------------------------------------------------
# Quantized combine: straggler invariance + tree plumbing
# ---------------------------------------------------------------------------


def test_dead_rows_cannot_influence_quantized_combine():
    """w_j == 0 makes u_j = w_j * s_j exactly 0, and 0 * q is exactly
    0 for any finite payload: perturbing a straggler's payload must
    leave the combine BITWISE unchanged -- on the jnp fallback and in
    the Pallas kernel alike."""
    from repro.kernels.coded_combine import kernel as cc_k

    q = RNG.integers(-127, 128, size=(5, 400)).astype(np.int8)
    s = RNG.uniform(0.1, 2.0, size=5).astype(np.float32)
    w = np.asarray([1.0, 0.0, 0.5, 0.0, 2.0], np.float32)
    q2 = q.copy()
    q2[1] = 127
    q2[3] = -127
    for fn in (cc_r.quantized_combine,
               lambda *a: cc_k.quantized_combine(*map(jnp.asarray, a),
                                                 interpret=True)):
        a = np.asarray(fn(jnp.asarray(q), jnp.asarray(s),
                          jnp.asarray(w)))
        b = np.asarray(fn(jnp.asarray(q2), jnp.asarray(s),
                          jnp.asarray(w)))
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(
        cc_r.quantized_combine_np(q, s, w),
        cc_r.quantized_combine_np(q2, s, w))


def test_quantized_combine_tree_matches_dequant_combine():
    """The fused tree combine == dequantize-then-coded_combine, leaf by
    leaf (float64 reference, tolerance)."""
    tree_shapes = {"w1": (4, 6, 3), "b": (4, 10)}
    q_tree = {k: jnp.asarray(RNG.integers(-127, 128, size=shp), jnp.int8)
              for k, shp in tree_shapes.items()}
    s_tree = {k: jnp.asarray(RNG.uniform(0.1, 1.0, size=4), jnp.float32)
              for k in tree_shapes}
    w = jnp.asarray([0.7, 0.0, 1.3, 0.4], jnp.float32)
    out = cc_ops.quantized_combine_tree(q_tree, s_tree, w)
    for k in tree_shapes:
        qf = np.asarray(q_tree[k], np.float64)
        lead = (-1,) + (1,) * (qf.ndim - 1)
        deq = qf * np.asarray(s_tree[k], np.float64).reshape(lead)
        expect = (deq * np.asarray(w, np.float64).reshape(lead)) \
            .sum(axis=0)
        assert out[k].shape == tree_shapes[k][1:]
        np.testing.assert_allclose(np.asarray(out[k], np.float64),
                                   expect, rtol=1e-5, atol=1e-5)


def test_compress_combine_tree_none_is_exact_with_zero_residual():
    """The 'none' codec is a float32 passthrough: residual stays
    exactly zero and the combine equals coded_combine at tolerance."""
    grads = {"a": jnp.asarray(RNG.normal(size=(3, 8, 2)), jnp.float32),
             "b": jnp.asarray(RNG.normal(size=(3, 5)), jnp.float32)}
    resid = jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    w = jnp.asarray([1.0, 0.0, 0.6], jnp.float32)
    combined, new_r = coded_train.compress_combine_tree(
        grads, resid, w, cm.get_codec("none"))
    for k in grads:
        assert not np.asarray(new_r[k]).any()
        np.testing.assert_allclose(
            np.asarray(combined[k]),
            np.asarray(cc_ops.coded_combine_tree(grads, w)[k]),
            rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Compressed train step differentials
# ---------------------------------------------------------------------------


def _setup(m=4, d=2, bs=3, S=16):
    cfg = get_config("granite-3-8b").smoke_variant()
    A = expander_assignment(m, d, vertex_transitive=False, seed=1)
    batcher = CodedBatcher(A, shuffle_seed=0)
    src = SyntheticLM(cfg.vocab_size, S, seed=0)
    batch_np = batcher.code_batch(src.batch(A.n * bs, 0))
    batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
    params = M.init_params(cfg, KEY)
    return cfg, A, batch, params


def test_compressed_step_none_codec_matches_baseline():
    """codec='none' reduces the compressed execution model to the
    baseline step: same loss and same updated params at the vmapped
    per-machine-grads + combine tolerance (test_dist.py precedent)."""
    cfg, A, batch, params = _setup()
    w = jnp.asarray([1.0, 0.0, 0.7, 2.0], jnp.float32)
    opt = opt_mod.sgd(1e-2)
    base = coded_train.make_train_step(cfg, opt)
    comp = coded_train.make_train_step(cfg, opt, compress="none")
    state = cm.init_state(params, rows=A.m)
    p0, _, m0 = base(params, opt.init(params), batch, w)
    p1, _, s1, m1 = comp(params, opt.init(params), state, batch, w)
    np.testing.assert_allclose(float(m0["loss"]), float(m1["loss"]),
                               rtol=1e-5)
    # the metric is a float32 scalar of an exact host-side integer
    np.testing.assert_allclose(
        float(m1["comm_bytes"]),
        cm.comm_bytes_per_step(cm.get_codec("none"), A.m, params),
        rtol=1e-6)
    for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(p1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)
    # float32 passthrough: the error-feedback residual stays zero
    assert all(not np.asarray(l).any()
               for l in jax.tree.leaves(s1["residual"]))


def test_compressed_step_int8_quantization_is_bounded():
    """Under int8 the loss path is untouched (quantization sits after
    the backward pass) and the parameter update differs from the
    'none'-codec step by at most the lr-scaled quantization noise."""
    cfg, A, batch, params = _setup()
    w = jnp.asarray([1.0, 0.0, 0.7, 2.0], jnp.float32)
    lr = 1e-2
    opt = opt_mod.sgd(lr)
    state = cm.init_state(params, rows=A.m)
    none_step = coded_train.make_train_step(cfg, opt, compress="none")
    int8_step = coded_train.make_train_step(cfg, opt, compress="int8")
    p0, _, _, m0 = none_step(params, opt.init(params), state, batch, w)
    p1, _, s1, m1 = int8_step(params, opt.init(params), state, batch, w)
    assert float(m0["loss"]) == float(m1["loss"])
    assert float(m1["comm_bytes"]) < 0.3 * float(m0["comm_bytes"])
    wsum = float(np.abs(np.asarray(w)).sum())
    for (a, b, r) in zip(jax.tree.leaves(p0), jax.tree.leaves(p1),
                         jax.tree.leaves(s1["residual"])):
        # the EF residual IS the quantization error of this step
        bound = lr * wsum * (float(np.abs(np.asarray(r)).max()) + 1e-7)
        assert float(np.abs(np.asarray(a) - np.asarray(b)).max()) \
            <= bound * 1.01 + 1e-7
    # a second step consumes the residual: state must actually change
    assert any(np.asarray(l).any()
               for l in jax.tree.leaves(s1["residual"]))


def test_quantized_allreduce_matches_tree_combine():
    """The shard_map quantized collective == the local fused tree
    combine (single-shard mesh: the psum is an identity)."""
    mesh = make_test_mesh((1, 1))
    q_tree = {"w": jnp.asarray(RNG.integers(-127, 128, size=(1, 2, 4)),
                               jnp.int8)}
    s_tree = {"w": jnp.asarray([1.5], jnp.float32)}
    w = jnp.asarray([2.0], jnp.float32)
    out = coded_train.quantized_coded_allreduce(q_tree, s_tree, w, mesh)
    expect = cc_ops.quantized_combine_tree(q_tree, s_tree, w)
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(expect["w"]), rtol=1e-6)


def test_packed_allreduce_matches_tree_combine():
    """The shard_map packed-sign collective == the local fused packed
    tree combine (single-shard mesh: the psum is an identity)."""
    mesh = make_test_mesh((1, 1))
    shapes = {"w": (2, 4)}  # d = 8 -> one packed byte per row
    q_tree = {"w": jnp.asarray(RNG.integers(0, 256, size=(1, 1)),
                               jnp.uint8)}
    s_tree = {"w": jnp.asarray([1.5], jnp.float32)}
    w = jnp.asarray([2.0], jnp.float32)
    out = coded_train.packed_sign_coded_allreduce(q_tree, s_tree, w,
                                                  mesh, shapes)
    expect = cc_ops.packed_sign_combine_tree(q_tree, s_tree, w, shapes)
    assert out["w"].shape == (2, 4)
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(expect["w"]), rtol=1e-6)


# ---------------------------------------------------------------------------
# Campaign grid
# ---------------------------------------------------------------------------


def test_compression_campaign_grid_shape_and_ordering():
    A = expander_assignment(8, 2, vertex_transitive=True, seed=0)
    p_grid = (0.1, 0.3)
    rows = cm.compression_campaign(A, p_grid, trials=64, dim=128,
                                   seed=0)
    # 3 codecs + majority vote per p
    assert len(rows) == len(p_grid) * 4
    by = {(r["codec"], r["decoding"], r["p"]): r for r in rows}
    for p in p_grid:
        none = by[("none", "optimal", p)]
        int8 = by[("int8", "optimal", p)]
        sign = by[("sign", "optimal", p)]
        mv = by[("sign", "majority_vote", p)]
        assert none["bits"] == 32 and int8["bits"] == 8 \
            and sign["bits"] == 1 == mv["bits"]
        for r in (none, int8, sign, mv):
            assert np.isfinite(r["mean_error"]) and r["mean_error"] >= 0
        # int8's quantization noise is negligible next to the decoding
        # floor; sign's is not, and the optimally-decoded sign stays
        # below the majority vote it replaces
        assert int8["mean_error"] <= none["mean_error"] * 1.10 + 1e-3
        assert sign["mean_error"] >= none["mean_error"] - 1e-6
        assert mv["mean_error"] > none["mean_error"]
