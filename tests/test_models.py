"""Per-architecture smoke tests: reduced same-family config, one
forward/train step on CPU, shape + NaN assertions; decode path; exact
sequence-mixer equivalences."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import model as M
from repro.models.attention import blockwise_attention

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=24):
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.arch_type == "vlm":
        batch["prefix"] = jax.random.normal(
            KEY, (B, cfg.prefix_len, cfg.d_model)) * 0.02
    if cfg.arch_type == "audio":
        batch["src"] = jax.random.normal(
            KEY, (B, cfg.prefix_len, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_grad(arch):
    cfg = get_config(arch).smoke_variant()
    assert cfg.n_layers == 2 and cfg.d_model <= 512
    if cfg.n_experts:
        assert cfg.n_experts <= 4
    params = M.init_params(cfg, KEY)
    batch = _batch(cfg)
    logits = M.forward(params, batch["tokens"], cfg,
                       prefix=batch.get("prefix"), src=batch.get("src"))
    S_total = batch["tokens"].shape[1] + (
        cfg.prefix_len if cfg.arch_type == "vlm" else 0)
    assert logits.shape == (2, S_total, cfg.padded_vocab())
    assert not bool(jnp.isnan(logits).any())
    loss, grads = jax.value_and_grad(
        lambda p: M.train_loss(p, batch, cfg))(params)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_config(arch).smoke_variant()
    params = M.init_params(cfg, KEY)
    B = 2
    cache = M.init_decode_cache(
        cfg, B, 48, pos=7,
        src_len=cfg.prefix_len if cfg.arch_type == "audio" else 0)
    if cfg.arch_type == "audio":
        src = jax.random.normal(KEY, (B, cfg.prefix_len, cfg.d_model))
        cache["enc"] = M.encode(params, src * 0.02, cfg)
    tok = jnp.asarray([1, 2], jnp.int32)
    logits, cache2 = M.decode_step(params, tok, cache, cfg)
    assert logits.shape == (B, cfg.padded_vocab())
    assert not bool(jnp.isnan(logits).any())
    jax.tree.map(lambda a, b: None, cache, cache2)  # same structure


def test_prefill_matches_forward_last_position():
    cfg = get_config("granite-3-8b").smoke_variant()
    params = M.init_params(cfg, KEY)
    tokens = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    full = M.forward(params, tokens, cfg)[:, -1]
    pre = M.prefill(params, tokens, cfg)
    np.testing.assert_allclose(np.asarray(full), np.asarray(pre),
                               rtol=2e-4, atol=2e-4)


def test_decode_matches_forward_teacher_forcing():
    """Autoregressive decode over a prompt must reproduce the full
    forward logits position by position (dense arch)."""
    cfg = get_config("granite-3-8b").smoke_variant()
    params = M.init_params(cfg, KEY)
    B, S = 1, 12
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full = M.forward(params, tokens, cfg)  # (B, S, V)
    cache = M.init_decode_cache(cfg, B, S)
    outs = []
    for t in range(S):
        logits, cache = M.decode_step(params, tokens[:, t], cache, cfg)
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_decode_matches_forward_ssm_family():
    cfg = get_config("xlstm-1.3b").smoke_variant()
    params = M.init_params(cfg, KEY)
    B, S = 1, 10
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full = M.forward(params, tokens, cfg)
    cache = M.init_decode_cache(cfg, B, S)
    outs = []
    for t in range(S):
        logits, cache = M.decode_step(params, tokens[:, t], cache, cfg)
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_decode_matches_forward_hybrid():
    cfg = get_config("zamba2-1.2b").smoke_variant()
    params = M.init_params(cfg, KEY)
    B, S = 1, 9
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full = M.forward(params, tokens, cfg)
    cache = M.init_decode_cache(cfg, B, S)
    outs = []
    for t in range(S):
        logits, cache = M.decode_step(params, tokens[:, t], cache, cfg)
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_sliding_window_attention_blockwise():
    rng = np.random.default_rng(0)
    B, S, H, KVH, D = 1, 64, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KVH, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KVH, D)), jnp.float32)
    full = blockwise_attention(q, k, v, causal=True, window=None,
                               block_q=16, block_k=16)
    win = blockwise_attention(q, k, v, causal=True, window=8,
                              block_q=16, block_k=16)
    # early positions (< window) agree; late positions differ
    np.testing.assert_allclose(full[:, :8], win[:, :8], atol=1e-5)
    assert float(jnp.abs(full[:, -1] - win[:, -1]).max()) > 1e-3
