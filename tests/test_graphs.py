import numpy as np
import pytest

from repro.core import graphs as G


def test_cycle():
    g = G.cycle_graph(8)
    assert g.n == 8 and g.m == 8
    assert g.is_regular() and g.is_connected()
    assert g.replication_factor == 2.0


def test_complete():
    g = G.complete_graph(6)
    assert g.m == 15
    # K_n has lambda = n (gap d - lambda_2 = (n-1) - (-1))
    assert g.spectral_expansion() == pytest.approx(6.0, abs=1e-8)


def test_random_regular():
    g = G.random_regular_graph(20, 4, seed=0)
    assert g.is_regular() and g.is_connected()
    deg = g.degrees()
    assert (deg == 4).all()
    # whp near-Ramanujan: lambda >= d - 2 sqrt(d-1) - 1 slack
    assert g.spectral_expansion() > 4 - 2 * np.sqrt(3) - 1.0


def test_hypercube():
    g = G.hypercube_graph(4)
    assert g.n == 16 and g.is_regular()
    assert g.spectral_expansion() == pytest.approx(2.0, abs=1e-8)


def test_paley():
    g = G.paley_graph(13)
    assert g.n == 13 and g.is_regular()
    d = 6
    lam2 = (np.sqrt(13) - 1) / 2
    assert g.spectral_expansion() == pytest.approx(d - lam2, abs=1e-6)


def test_circulant_vertex_transitive_degree():
    g = G.circulant_graph(16, (1, 3, 5))
    assert g.is_regular()
    assert (g.degrees() == 6).all()


def test_circulant_half_offset_dedup():
    # o = n/2 pairs with itself: each such edge must appear exactly once
    # (the seen-set dedup), giving degree 2*|offs<n/2| + 1.
    g = G.circulant_graph(8, (2, 4))
    assert g.is_regular() and (g.degrees() == 3).all()
    assert g.m == 12
    # duplicate / mirrored offsets collapse like the edge dedup does
    g2 = G.circulant_graph(10, (1, 9, 1))
    assert (g2.degrees() == 2).all()
    assert g2.circulant_offsets == (1,)


def test_sqrt_mod_annotations_resolve():
    import typing

    # regression: `Optional` was used in the annotation but not
    # imported, a latent NameError for runtime annotation inspection
    hints = typing.get_type_hints(G._sqrt_mod)
    assert hints["return"] == G.Optional[int]
    assert G._sqrt_mod(4, 13) in (2, 11)
    assert G._sqrt_mod(5, 7) is None


@pytest.mark.slow
def test_lps_graph_is_ramanujan():
    g = G.lps_graph(5, 13)
    assert g.n == 2184 and g.m == 6552
    assert g.is_regular() and g.is_connected()
    assert g.spectral_expansion() >= 6 - 2 * np.sqrt(5)


def test_make_expander_dispatch():
    assert G.make_expander(8, 7).m == 28          # complete
    assert G.make_expander(10, 2).m == 10         # cycle
    g = G.make_expander(16, 4, vertex_transitive=True)
    assert g.is_regular() and (g.degrees() == 4).all()
    g2 = G.make_expander(24, 3, vertex_transitive=False, seed=1)
    assert (g2.degrees() == 3).all()
