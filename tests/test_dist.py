"""Distributed runtime: the coded train step's weighted-loss gradient
must equal the explicit paper combine sum_j w_j g_j; the shard_map
collective path; batcher geometry; substrates."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CodingConfig, get_config
from repro.core import expander_assignment
from repro.data.pipeline import CodedBatcher, SyntheticLM
from repro.dist import coded_train, sharding as rules
from repro.kernels.coded_combine import ops as cc_ops
from repro.launch.mesh import make_test_mesh
from repro.models import model as M
from repro.optim import optimizers as opt_mod

KEY = jax.random.PRNGKey(0)


def _setup(m=4, d=2, bs=3, S=16):
    cfg = get_config("granite-3-8b").smoke_variant()
    A = expander_assignment(m, d, vertex_transitive=False, seed=1)
    batcher = CodedBatcher(A, shuffle_seed=0)
    src = SyntheticLM(cfg.vocab_size, S, seed=0)
    gb = A.n * bs
    batch_np = batcher.code_batch(src.batch(gb, 0))
    batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
    params = M.init_params(cfg, KEY)
    return cfg, A, batch, params


def test_coded_batcher_replicates_blocks():
    A = expander_assignment(6, 2, vertex_transitive=False, seed=3)
    batcher = CodedBatcher(A, shuffle_seed=None)
    data = {"tokens": np.arange(A.n * 2 * 5).reshape(A.n * 2, 5)}
    coded = batcher.code_batch(data)
    assert coded["tokens"].shape == (A.m, 2, 2, 5)
    # machine j holds exactly the blocks of edge j
    for j in range(A.m):
        blocks = A.blocks_of_machine(j)
        for slot, b in enumerate(blocks):
            expect = data["tokens"].reshape(A.n, 2, 5)[b]
            np.testing.assert_array_equal(coded["tokens"][j, slot],
                                          expect)


def test_coded_loss_grad_equals_manual_combine():
    """grad(sum_j w_j L_j) == sum_j w_j g_j (Eq. 1 of the paper)."""
    cfg, A, batch, params = _setup()
    w = jnp.asarray([1.0, 0.0, 0.7, 2.0])  # one straggler

    auto = jax.grad(coded_train.coded_loss_fn)(params, batch, w, cfg)

    # manual: per-worker gradients, then the explicit weighted combine
    m, load = batch["block_weight"].shape
    norm = float(batch["labels"].size)

    def worker_loss(p, j):
        sub = {k: v[j].reshape((-1,) + v[j].shape[2:])
               for k, v in batch.items() if k != "block_weight"}
        per_seq = M.train_loss(p, sub, cfg, per_example=True)
        per_block = per_seq.reshape(load, -1).sum(axis=1)
        return (per_block * batch["block_weight"][j]).sum() / norm

    grads = [jax.grad(worker_loss)(params, j) for j in range(m)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *grads)
    manual = cc_ops.coded_combine_tree(stacked, w)
    for a, b in zip(jax.tree.leaves(auto), jax.tree.leaves(manual)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_straggler_zero_weight_removes_contribution():
    cfg, A, batch, params = _setup()
    w1 = jnp.asarray([1.0, 1.0, 1.0, 0.0])
    # perturb the straggler's data: gradient must be unchanged
    batch2 = dict(batch)
    batch2["tokens"] = batch["tokens"].at[3].set(1)
    batch2["labels"] = batch["labels"].at[3].set(1)
    g1 = jax.grad(coded_train.coded_loss_fn)(params, batch, w1, cfg)
    g2 = jax.grad(coded_train.coded_loss_fn)(params, batch2, w1, cfg)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6)


def test_microbatched_step_matches_single_shot():
    cfg, A, batch, params = _setup(bs=4)
    w = jnp.asarray([0.5, 1.5, 0.0, 1.0])
    opt = opt_mod.sgd(1e-2)
    s1 = coded_train.make_train_step(cfg, opt, n_microbatches=1)
    s4 = coded_train.make_train_step(cfg, opt, n_microbatches=4)
    p1, _, m1 = s1(params, opt.init(params), batch, w)
    p4, _, m4 = s4(params, opt.init(params), batch, w)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_shard_map_coded_allreduce():
    mesh = make_test_mesh((1, 1))
    grads = {"w": jnp.arange(8.0).reshape(1, 2, 4)}  # m_local=1
    w = jnp.asarray([2.0])
    out = coded_train.coded_allreduce(grads, w, mesh)
    np.testing.assert_allclose(out["w"],
                               2.0 * grads["w"][0], rtol=1e-6)


def test_param_specs_divisibility_fallback():
    cfg = get_config("qwen1.5-4b").smoke_variant()
    params = M.init_params(cfg, KEY)
    mesh = make_test_mesh((1, 1))
    specs = rules.safe_param_specs(params, mesh)
    # all specs must be valid for the mesh (everything divides by 1)
    leaves = jax.tree.leaves(specs, is_leaf=lambda x: hasattr(x, "index"))
    assert leaves


def test_coding_runtime_step_weights():
    coding = CodingConfig(scheme="expander", replication=2,
                          decoding="optimal", straggler_p=0.3)
    rt = coded_train.CodingRuntime(coding, m=8)
    w, alive = rt.step_weights()
    assert w.shape == (8,)
    assert (w[~alive] == 0).all()
    coding2 = CodingConfig(scheme="expander", replication=2,
                           straggler_model="adversarial",
                           straggler_p=0.25)
    rt2 = coded_train.CodingRuntime(coding2, m=8)
    w2, alive2 = rt2.step_weights()
    assert (~alive2).sum() <= 2


def test_optimizers_and_checkpoint(tmp_path):
    from repro.checkpoint import checkpoint as ckpt
    params = {"a": jnp.ones((3, 2)), "b": {"c": jnp.zeros(4)}}
    opt = opt_mod.adamw(1e-2)
    state = opt.init(params)
    grads = jax.tree.map(jnp.ones_like, params)
    updates, state = opt.update(grads, state, params)
    params2 = opt_mod.apply_updates(params, updates)
    assert float(params2["a"][0, 0]) < 1.0
    path = str(tmp_path / "ck")
    ckpt.save(path, params2, step=3)
    assert ckpt.latest_step(path) == 3
    restored = ckpt.restore(path, params2)
    np.testing.assert_allclose(restored["a"], np.asarray(params2["a"]))


def test_schedule():
    sched = opt_mod.cosine_schedule(1.0, warmup=10, total=100)
    assert float(sched(jnp.asarray(0))) == 0.0
    assert float(sched(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(sched(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-6)
