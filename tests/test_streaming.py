"""Streaming manual-collective step: the ``lax.scan`` chunked combine
must match the materialising manual step at float32 tolerance (the
combine is linear in the per-machine gradients, so chunking only
reassociates the sum), for the uncompressed and compression-composed
paths alike; the machine-axis chunk regrouping must be an exact
bijection."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import compress as compress_mod
from repro.core import expander_assignment
from repro.data.pipeline import CodedBatcher, SyntheticLM
from repro.dist import coded_train
from repro.launch.mesh import make_test_mesh
from repro.models import model as M
from repro.optim import optimizers as opt_mod

KEY = jax.random.PRNGKey(0)
RTOL, ATOL = 2e-4, 2e-5  # float32 reassociation tolerance (test_dist)


def _setup(m=4, d=2, bs=3, S=16):
    cfg = get_config("granite-3-8b").smoke_variant()
    A = expander_assignment(m, d, vertex_transitive=False, seed=1)
    batcher = CodedBatcher(A, shuffle_seed=0)
    src = SyntheticLM(cfg.vocab_size, S, seed=0)
    batch_np = batcher.code_batch(src.batch(A.n * bs, 0))
    batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
    params = M.init_params(cfg, KEY)
    return cfg, A, batch, params


def _tree_close(a, b, rtol=RTOL, atol=ATOL):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)


@pytest.mark.parametrize("shape", [(4,), (4, 5), (8, 3, 2)])
@pytest.mark.parametrize("n_shards,chunk", [(1, 1), (1, 2), (2, 1),
                                            (2, 2), (4, 1)])
def test_stream_chunk_regroup_roundtrip(shape, n_shards, chunk):
    m = shape[0]
    if m % (n_shards * chunk):
        pytest.skip("geometry not divisible")
    x = jnp.arange(np.prod(shape), dtype=jnp.float32).reshape(shape)
    chunked = coded_train._to_stream_chunks(x, n_shards, chunk)
    t = m // (n_shards * chunk)
    assert chunked.shape == (t, n_shards * chunk) + shape[1:]
    back = coded_train._from_stream_chunks(chunked, n_shards, chunk)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


def test_stream_chunks_preserve_shard_blocks():
    # Shard s owns machines [s*m/W, (s+1)*m/W); after regrouping, scan
    # step t slot (s*chunk + c) must hold machine s*(m//W) + t*chunk + c
    # -- consecutive machines from every shard each step, so the
    # per-chunk block-sharded collective specs stay valid.
    m, n_shards, chunk = 8, 2, 2
    x = jnp.arange(m, dtype=jnp.float32)
    chunked = np.asarray(
        coded_train._to_stream_chunks(x, n_shards, chunk))
    per = m // n_shards
    for t in range(chunked.shape[0]):
        for s in range(n_shards):
            for c in range(chunk):
                assert chunked[t, s * chunk + c] == \
                    s * per + t * chunk + c


@pytest.mark.parametrize("chunk", [1, 2, 4])
def test_streaming_matches_materialising_manual(chunk):
    cfg, A, batch, params = _setup()
    mesh = make_test_mesh((1, 1))
    w = jnp.asarray([1.0, 0.0, 0.7, 2.0])
    opt = opt_mod.get_optimizer("adamw", 1e-3)
    opt_state = opt.init(params)
    base = coded_train.make_manual_collective_train_step(
        cfg, opt, mesh)
    stream = coded_train.make_manual_collective_train_step(
        cfg, opt, mesh, streaming_chunk=chunk)
    with mesh:
        p0, o0, m0 = jax.jit(base)(params, opt_state, batch, w)
        p1, o1, m1 = jax.jit(stream)(params, opt_state, batch, w)
    _tree_close(p0, p1)
    _tree_close(o0, o1)
    np.testing.assert_allclose(float(m1["loss"]), float(m0["loss"]),
                               rtol=RTOL)
    np.testing.assert_allclose(float(m1["grad_norm"]),
                               float(m0["grad_norm"]), rtol=RTOL)


@pytest.mark.parametrize("codec", ["int8", "sign_packed"])
@pytest.mark.parametrize("chunk", [1, 2])
def test_streaming_compressed_matches_materialising(codec, chunk):
    cfg, A, batch, params = _setup()
    mesh = make_test_mesh((1, 1))
    m = A.m
    w = jnp.asarray([1.0, 0.0, 0.7, 2.0])
    opt = opt_mod.get_optimizer("adamw", 1e-3)
    opt_state = opt.init(params)
    comp0 = compress_mod.init_state(params, m)
    base = coded_train.make_manual_collective_train_step(
        cfg, opt, mesh, compress=codec)
    stream = coded_train.make_manual_collective_train_step(
        cfg, opt, mesh, compress=codec, streaming_chunk=chunk)
    with mesh:
        p0, o0, c0, m0 = jax.jit(base)(params, opt_state, comp0,
                                       batch, w)
        p1, o1, c1, m1 = jax.jit(stream)(params, opt_state, comp0,
                                         batch, w)
    _tree_close(p0, p1)
    # Error-feedback residuals must agree row-for-row: the streaming
    # path quantizes the same per-machine gradients, just chunk by
    # chunk, and restores machine order on the way out.
    _tree_close(c0["residual"], c1["residual"], rtol=2e-4, atol=2e-4)
    assert float(m0["comm_bytes"]) == float(m1["comm_bytes"])


def test_streaming_two_steps_carry_residual():
    # The residual regrouping must round-trip across steps: two
    # streaming compressed steps from a zero residual end where two
    # materialising steps do.
    cfg, A, batch, params = _setup()
    mesh = make_test_mesh((1, 1))
    w = jnp.asarray([1.0, 1.0, 0.0, 1.0])
    opt = opt_mod.get_optimizer("adamw", 1e-3)
    opt_state = opt.init(params)
    comp = compress_mod.init_state(params, A.m)
    base = coded_train.make_manual_collective_train_step(
        cfg, opt, mesh, compress="sign_packed")
    stream = coded_train.make_manual_collective_train_step(
        cfg, opt, mesh, compress="sign_packed", streaming_chunk=2)
    with mesh:
        jb, js = jax.jit(base), jax.jit(stream)
        s0 = (params, opt_state, comp)
        s1 = (params, opt_state, comp)
        for _ in range(2):
            p, o, c, _ = jb(*s0, batch, w)
            s0 = (p, o, c)
            p, o, c, _ = js(*s1, batch, w)
            s1 = (p, o, c)
    _tree_close(s0[0], s1[0], rtol=5e-4, atol=5e-5)


def test_streaming_rejects_indivisible_geometry():
    cfg, A, batch, params = _setup()  # m = 4
    mesh = make_test_mesh((1, 1))
    opt = opt_mod.get_optimizer("adamw", 1e-3)
    step = coded_train.make_manual_collective_train_step(
        cfg, opt, mesh, streaming_chunk=3)  # 4 % 3 != 0
    with pytest.raises(ValueError, match="divisible"):
        with mesh:
            jax.jit(step)(params, opt.init(params), batch,
                          jnp.ones((4,), jnp.float32))


def test_streaming_chunk_must_be_positive():
    cfg = get_config("granite-3-8b").smoke_variant()
    mesh = make_test_mesh((1, 1))
    opt = opt_mod.get_optimizer("adamw", 1e-3)
    with pytest.raises(ValueError, match="streaming_chunk"):
        coded_train.make_manual_collective_train_step(
            cfg, opt, mesh, streaming_chunk=0)
