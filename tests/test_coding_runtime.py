"""CodingRuntime host bridge: straggler processes x decode paths.

Covers the pieces the dist tests don't: the Markov (stagnant) model's
run statistics and decode-cache behaviour, the w[~alive] == 0
invariant across all three straggler models, and the batched
step-weights path against the scalar decoder.
"""

import numpy as np
import pytest

import repro.core.step_weights as sw
from repro.configs import CodingConfig
from repro.core import (expander_assignment, frc_assignment,
                        optimal_decode_frc)
from repro.dist import coded_train

M_WORKERS = 8


def _runtime(**kw):
    kw.setdefault("scheme", "expander")
    kw.setdefault("replication", 2)
    return coded_train.CodingRuntime(CodingConfig(**kw), m=M_WORKERS)


@pytest.mark.parametrize("model", ["bernoulli", "markov", "adversarial"])
def test_w_zero_on_stragglers_all_models(model):
    rt = _runtime(straggler_model=model, straggler_p=0.25, seed=3)
    for _ in range(50):
        w, alive = rt.step_weights()
        assert w.shape == (M_WORKERS,)
        assert np.isfinite(w).all()
        assert (w[~alive] == 0).all()


def test_markov_runs_are_stagnant():
    """The Markov model exists because straggling machines stay
    stagnant (paper Section VIII): per-machine state flips must be far
    rarer than under i.i.d. Bernoulli with the same stationary p."""
    rt = _runtime(straggler_model="markov", straggler_p=0.3, seed=0)
    masks = np.stack([rt.step_weights()[1] for _ in range(400)])
    straggle_rate = (~masks).mean()
    assert 0.15 < straggle_rate < 0.45  # stationary distribution ~ p
    flip_rate = (masks[1:] != masks[:-1]).mean()
    iid_flip = 2 * 0.3 * 0.7  # = 0.42
    assert flip_rate < iid_flip / 2, (flip_rate, iid_flip)


def test_decode_cache_hits_on_stagnant_processes():
    rt = _runtime(straggler_model="adversarial", straggler_p=0.25)
    for _ in range(20):
        rt.step_weights()
    assert rt.steps_sampled == 20
    assert rt.decode_calls == 1  # the adversarial mask never moves
    rt2 = _runtime(straggler_model="markov", straggler_p=0.3, seed=1)
    for _ in range(100):
        rt2.step_weights()
    assert rt2.decode_calls < rt2.steps_sampled


def test_debias_scale_counteracts_optimal_shrinkage():
    """Optimal decoding has E[alpha] <= 1; the runtime scale must be
    >= 1 and make |E[scaled alpha]|_2 = sqrt(n)."""
    rt = _runtime(straggler_model="bernoulli", straggler_p=0.3)
    assert rt.scale >= 1.0
    A = rt.assignment
    W, alphas = rt.decode_batch(
        np.random.default_rng(0).random((64, A.m)) >= 0.3)
    # A w = alpha holds through the shared scale (decoder invariant).
    np.testing.assert_allclose(W @ A.A.T, alphas, atol=1e-9)


def test_batched_step_weights_matches_scalar_graph():
    A = expander_assignment(M_WORKERS, 2, vertex_transitive=True, seed=0)
    masks = np.random.default_rng(1).random((32, A.m)) >= 0.35
    W, alphas = sw.batched_step_weights(A, masks)
    for t in range(masks.shape[0]):
        w_t, a_t = sw.step_weights(A, masks[t])
        np.testing.assert_allclose(W[t], w_t, atol=1e-12)
        np.testing.assert_allclose(alphas[t], a_t, atol=1e-12)


def test_batched_step_weights_matches_scalar_frc():
    A = frc_assignment(M_WORKERS, 2)
    masks = np.random.default_rng(2).random((32, A.m)) >= 0.4
    W, alphas = sw.batched_step_weights(A, masks)
    for t in range(masks.shape[0]):
        res = optimal_decode_frc(A, masks[t])
        np.testing.assert_allclose(W[t], res.w, atol=1e-12)
        np.testing.assert_allclose(alphas[t], res.alpha, atol=1e-12)


@pytest.mark.parametrize("model,decoding", [
    ("bernoulli", "optimal"), ("markov", "optimal"),
    ("bernoulli", "fixed")])
def test_weights_lookahead_equals_per_step(model, decoding):
    """The batched lookahead path must replay the per-step loop
    bit-for-bit over a fixed mask stream: same RNG consumption, same
    (cached) decodes, same float32 weights."""
    steps = 24
    rt_step = _runtime(straggler_model=model, decoding=decoding,
                       straggler_p=0.3, seed=7)
    rt_look = _runtime(straggler_model=model, decoding=decoding,
                       straggler_p=0.3, seed=7)
    per_w, per_alive = zip(*[rt_step.step_weights()
                             for _ in range(steps)])
    look_w, look_alive = [], []
    done = 0
    for horizon in (5, 11, steps):   # uneven chunks straddle the stream
        k = min(horizon, steps - done)
        W, alive = rt_look.weights_lookahead(k)
        look_w.append(W)
        look_alive.append(alive)
        done += k
    np.testing.assert_array_equal(np.stack(per_alive),
                                  np.concatenate(look_alive))
    np.testing.assert_array_equal(np.stack(per_w),
                                  np.concatenate(look_w))
    assert rt_look.steps_sampled == rt_step.steps_sampled == steps
    # the chunked path dedups within the horizon too, so it never
    # decodes more than the per-step memoised loop
    assert rt_look.decode_calls <= rt_step.decode_calls


def test_weights_lookahead_survives_cache_eviction():
    """A horizon larger than the memo cache must not lose rows to FIFO
    eviction mid-chunk: every returned weight row still matches a
    fresh decode of its mask."""
    rt = coded_train.CodingRuntime(
        CodingConfig(scheme="expander", replication=2,
                     straggler_p=0.5, seed=11),
        m=M_WORKERS, cache_size=4)
    W, alive = rt.weights_lookahead(32)  # >> cache_size distinct masks
    W_fresh, _ = rt.decode_batch(alive)
    np.testing.assert_array_equal(W, W_fresh.astype(np.float32))


def test_weights_lookahead_dedups_stagnant_masks():
    rt = _runtime(straggler_model="adversarial", straggler_p=0.25)
    W, alive = rt.weights_lookahead(16)
    assert W.shape == (16, M_WORKERS)
    assert rt.decode_calls == 1  # the adversarial mask never moves
    assert (W[~alive] == 0).all()
    with pytest.raises(ValueError):
        rt.weights_lookahead(0)


@pytest.mark.parametrize("model", ["bernoulli", "markov"])
def test_lookahead_prefetcher_equals_per_step(model):
    """The async prefetcher (train driver's batch-builder thread) must
    replay the synchronous per-step loop bit-for-bit: same RNG
    consumption, same decodes, same (w, alive) stream -- including a
    total not divisible by the horizon, where the final chunk must be
    capped by the remaining budget exactly like the old inline code."""
    from concurrent.futures import ThreadPoolExecutor

    steps, horizon = 23, 6   # 23 % 6 != 0: last chunk is short
    rt_sync = _runtime(straggler_model=model, straggler_p=0.3, seed=9)
    rt_pre = _runtime(straggler_model=model, straggler_p=0.3, seed=9)
    sync = [rt_sync.step_weights() for _ in range(steps)]
    with ThreadPoolExecutor(max_workers=1) as pool:
        pre_fetch = coded_train.LookaheadPrefetcher(
            rt_pre, pool, horizon, steps)
        pre = [pre_fetch.next() for _ in range(steps)]
        with pytest.raises(RuntimeError):
            pre_fetch.next()   # budget exhausted, no silent resample
    np.testing.assert_array_equal(np.stack([a for _, a in sync]),
                                  np.stack([a for _, a in pre]))
    np.testing.assert_array_equal(np.stack([w for w, _ in sync]),
                                  np.stack([w for w, _ in pre]))
    assert rt_pre.steps_sampled == rt_sync.steps_sampled == steps


def test_lookahead_prefetcher_rejects_bad_horizon():
    with pytest.raises(ValueError):
        coded_train.LookaheadPrefetcher(_runtime(), None, 0, 10)


def test_lookahead_prefetcher_propagates_worker_exception():
    """Thread-death hardening: an exception inside the prefetch task
    (here: a mask source that dies mid-stream) must re-raise on the
    consumer's next(), not strand the driver with a silently dead
    worker. The driver-level version (batch-builder thread) lives in
    tests/test_smoke_train.py."""
    from concurrent.futures import ThreadPoolExecutor

    masks = np.ones((3, M_WORKERS), dtype=bool)
    rt = coded_train.CodingRuntime(
        CodingConfig(scheme="expander", replication=2), m=M_WORKERS,
        mask_source=sw.ReplayedMaskSource(masks))
    with ThreadPoolExecutor(max_workers=1) as pool:
        pre = coded_train.LookaheadPrefetcher(rt, pool, 2, 10)
        pre.next()
        pre.next()
        # The worker's decode of the next chunk exhausts the replayed
        # stream on the worker thread; the failure must surface here.
        with pytest.raises(RuntimeError, match="exhausted"):
            for _ in range(8):
                pre.next()


def test_block_weights_scalar_and_batched():
    A = expander_assignment(M_WORKERS, 2, vertex_transitive=True, seed=0)
    rng = np.random.default_rng(3)
    W = rng.random((5, A.m))
    np.testing.assert_allclose(sw.block_weights(A, W), W @ A.A.T)
    for t in range(5):
        np.testing.assert_allclose(sw.block_weights(A, W[t]),
                                   A.A @ W[t])
    # decoder outputs: block weights ARE the decoder's alpha
    masks = rng.random((8, A.m)) >= 0.3
    Wd, alphas = sw.batched_step_weights(A, masks)
    np.testing.assert_allclose(sw.block_weights(A, Wd), alphas,
                               atol=1e-12)
    with pytest.raises(ValueError):
        sw.block_weights(A, np.ones(A.m + 1))


def test_fixed_decoding_runtime_unit_scale():
    rt = _runtime(decoding="fixed", straggler_p=0.2, seed=5)
    assert rt.scale == 1.0  # fixed weights are unbiased by construction
    w, alive = rt.step_weights()
    d = rt.assignment.replication_factor
    np.testing.assert_allclose(w[alive], 1.0 / (d * 0.8), rtol=1e-6)
