"""Sequence-mixer equivalences: chunked/parallel forms vs the exact
sequential recurrence, and blockwise attention vs the naive oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis "
                           "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.models import ssm as ssm_mod, xlstm as xlstm_mod
from repro.models.attention import blockwise_attention

KEY = jax.random.PRNGKey(0)


def _naive_attention(q, k, v, causal, window):
    B, S, H, D = q.shape
    KVH = k.shape[2]
    G = H // KVH
    qf = q.reshape(B, S, KVH, G, D).astype(np.float32)
    s = np.einsum("bqhgd,bkhd->bhgqk", qf, np.asarray(k, np.float32))
    s /= np.sqrt(D)
    i = np.arange(S)[:, None]
    j = np.arange(S)[None, :]
    mask = np.ones((S, S), bool)
    if causal:
        mask &= j <= i
    if window:
        mask &= j > i - window
    s = np.where(mask, s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    o = np.einsum("bhgqk,bkhd->bhgqd", p, np.asarray(v, np.float32))
    return o.transpose(0, 3, 1, 2, 4).reshape(B, S, H, D)


@given(st.integers(5, 80), st.booleans(),
       st.sampled_from([None, 8, 24]), st.sampled_from([8, 16, 32]))
@settings(max_examples=20, deadline=None)
def test_blockwise_attention_property(S, causal, window, block):
    rng = np.random.default_rng(S)
    B, H, KVH, D = 1, 4, 2, 8
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KVH, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KVH, D)), jnp.float32)
    out = blockwise_attention(q, k, v, causal=causal, window=window,
                              block_q=block, block_k=block)
    ref = _naive_attention(np.asarray(q), np.asarray(k), np.asarray(v),
                           causal, window)
    np.testing.assert_allclose(np.asarray(out), ref, atol=3e-5)


@pytest.mark.parametrize("S,chunk", [(37, 16), (64, 64), (5, 8),
                                     (129, 32)])
def test_ssd_chunked_equals_sequential(S, chunk):
    B, D, N = 2, 32, 8
    p = ssm_mod.init_ssm(KEY, D, N, expand=2, head_p=8)
    u = jax.random.normal(jax.random.PRNGKey(1), (B, S, D)) * 0.5
    y = ssm_mod.ssm_forward(p, u, d_state=N, expand=2, head_p=8,
                            chunk=chunk)
    state = ssm_mod.init_ssm_state(B, D, N, expand=2, head_p=8)
    outs = []
    for t in range(S):
        yt, state = ssm_mod.ssm_decode(p, u[:, t:t + 1], state,
                                       d_state=N, expand=2, head_p=8)
        outs.append(yt)
    ref = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=2e-5)


@pytest.mark.parametrize("S,chunk", [(29, 8), (48, 16), (7, 32)])
def test_mlstm_chunked_equals_sequential(S, chunk):
    B, D, H = 2, 32, 4
    p = xlstm_mod.init_mlstm(KEY, D, H)
    u = jax.random.normal(jax.random.PRNGKey(1), (B, S, D)) * 0.5
    y = xlstm_mod.mlstm_forward(p, u, n_heads=H, chunk=chunk)
    state = xlstm_mod.init_mlstm_state(B, D, H)
    outs = []
    for t in range(S):
        yt, state = xlstm_mod.mlstm_decode(p, u[:, t:t + 1], state,
                                           n_heads=H)
        outs.append(yt)
    ref = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=2e-5)


def test_slstm_scan_equals_sequential():
    B, S, D = 2, 33, 16
    p = xlstm_mod.init_slstm(KEY, D)
    u = jax.random.normal(jax.random.PRNGKey(1), (B, S, D)) * 0.5
    y = xlstm_mod.slstm_forward(p, u)
    st_ = xlstm_mod.init_slstm_state(B, D)
    outs = []
    for t in range(S):
        yt, st_ = xlstm_mod.slstm_decode(p, u[:, t:t + 1], st_)
        outs.append(yt)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(jnp.concatenate(outs, 1)), atol=2e-5)


def test_ssd_state_is_causal():
    """Changing a future input must not change past outputs."""
    B, S, D, N = 1, 24, 16, 4
    p = ssm_mod.init_ssm(KEY, D, N, expand=2, head_p=8)
    u = jax.random.normal(jax.random.PRNGKey(2), (B, S, D))
    y1 = ssm_mod.ssm_forward(p, u, d_state=N, expand=2, head_p=8,
                             chunk=8)
    u2 = u.at[:, -1].set(99.0)
    y2 = ssm_mod.ssm_forward(p, u2, d_state=N, expand=2, head_p=8,
                             chunk=8)
    np.testing.assert_allclose(np.asarray(y1[:, :-1]),
                               np.asarray(y2[:, :-1]), atol=1e-5)
