"""Matrix-free spectral toolkit vs closed forms and dense references.

Closed forms (the satellite contract): cycle lambda_2 = 2cos(2pi/n),
hypercube lambda = 2, Paley lambda_2 = (sqrt(q)-1)/2; matrix-free
covariance norm vs np.linalg.norm(cov, 2) to 1e-8 on small cases. Every
dispatch path (fft / dense / lanczos) is exercised against the others.
"""

import numpy as np
import pytest

from repro.core import graphs as G
from repro.core import spectral as S
from repro.kernels.spectral_matvec import ops as sm_ops

RNG = np.random.default_rng(0)

# The 1e-8 covariance agreement is a float64 contract; on TPU the Gram
# matvec runs the float32 Pallas kernel and only coarse bounds apply.
FLOAT64_MATVEC = not sm_ops.uses_pallas()


# ---------------------------------------------------------------------------
# graph lambda_2 / spectral expansion
# ---------------------------------------------------------------------------


def test_cycle_lambda2_closed_form_all_methods():
    for n in (10, 21, 64):
        g = G.cycle_graph(n)
        want = 2.0 * np.cos(2.0 * np.pi / n)
        assert g.circulant_offsets == (1,)
        for method in ("auto", "fft", "dense", "lanczos"):
            assert S.graph_lambda2(g, method) == pytest.approx(
                want, abs=1e-8), method
        assert g.spectral_expansion() == pytest.approx(2.0 - want,
                                                       abs=1e-8)


def test_hypercube_expansion_closed_form():
    for k in (3, 4):
        g = G.hypercube_graph(k)
        # lambda_2 = k - 2 exactly, with multiplicity k.
        assert g.spectral_expansion("dense") == pytest.approx(2.0,
                                                              abs=1e-8)
        assert g.spectral_expansion("lanczos") == pytest.approx(2.0,
                                                                abs=1e-8)


def test_paley_lambda2_closed_form():
    q = 13
    g = G.paley_graph(q)
    want = (np.sqrt(q) - 1) / 2
    assert g.circulant_offsets is not None  # exact FFT path
    for method in ("auto", "fft", "dense", "lanczos"):
        assert S.graph_lambda2(g, method) == pytest.approx(want, abs=1e-8)


def test_complete_graph_negative_lambda2():
    # K_n has lambda_2 = -1: the deflation shift must not clamp to 0.
    g = G.complete_graph(8)
    assert S.graph_lambda2(g, "dense") == pytest.approx(-1.0, abs=1e-8)
    assert S.graph_lambda2(g, "lanczos") == pytest.approx(-1.0, abs=1e-8)


def test_circulant_spectrum_matches_dense():
    for n, offs in [(16, (1, 3, 5)), (10, (2, 5)), (12, (1, 6)),
                    (9, (1, 2))]:
        g = G.circulant_graph(n, offs)
        dense = np.sort(np.linalg.eigvalsh(g.adjacency()))
        fft = np.sort(S.circulant_spectrum(n, offs))
        np.testing.assert_allclose(fft, dense, atol=1e-8)
        # Graph metadata reproduces the same spectrum (canonical form).
        fft_meta = np.sort(S.circulant_spectrum(n, g.circulant_offsets))
        np.testing.assert_allclose(fft_meta, dense, atol=1e-8)


def test_lambda2_multiplicity_disconnected():
    # Two 4-cycles: top eigenvalue 2 has multiplicity 2, so lambda_2 = 2
    # (the historical sort(eigvalsh)[-2] convention).
    edges = ((0, 1), (1, 2), (2, 3), (3, 0),
             (4, 5), (5, 6), (6, 7), (7, 4))
    g = G.Graph(8, edges)
    assert S.graph_lambda2(g, "dense") == pytest.approx(2.0, abs=1e-8)
    assert S.graph_lambda2(g, "lanczos") == pytest.approx(2.0, abs=1e-8)


def test_lanczos_rejects_irregular():
    g = G.Graph(4, ((0, 1), (1, 2), (2, 3), (1, 3)))
    with pytest.raises(ValueError, match="regular"):
        S.graph_lambda2(g, "lanczos")
    # auto must route irregular graphs to dense, not lanczos
    assert S.graph_lambda2(g, "auto") == pytest.approx(
        S.graph_lambda2(g, "dense"), abs=1e-12)


def test_metadata_excluded_from_eq_and_hash():
    base = G.cycle_graph(8)
    bare = G.Graph(8, base.edges)
    assert base == bare
    assert hash(base) == hash(bare)


def test_make_expander_cached_and_lps_like_metadata():
    a = G.make_expander(16, 4, vertex_transitive=True, seed=0)
    b = G.make_expander(16, 4, vertex_transitive=True, seed=0)
    assert a is b  # process-level construction cache
    g = G.lps_like_cayley_expander(16, 4, seed=0)
    assert g.circulant_offsets is not None
    assert g.is_regular() and (g.degrees() == 4).all()
    assert g.is_connected()
    # the FFT lambda agrees with the dense one on the built graph
    assert S.graph_lambda2(g, "fft") == pytest.approx(
        S.graph_lambda2(g, "dense"), abs=1e-8)


# ---------------------------------------------------------------------------
# matrix-free covariance norm
# ---------------------------------------------------------------------------


def test_covariance_norm_matches_dense_small_cases():
    for shape in [(12, 7), (5, 40), (30, 30), (40, 3), (2, 6), (64, 17)]:
        a = RNG.normal(size=shape) * RNG.uniform(0.5, 2.0, size=shape[1])
        dense = S.covariance_spectral_norm(a, method="dense")
        lanczos = S.covariance_spectral_norm(a, method="lanczos")
        assert dense == pytest.approx(
            float(np.linalg.norm(np.cov(a.T, bias=True), 2)), rel=1e-9)
        tol = 1e-8 if FLOAT64_MATVEC else 5e-3
        assert abs(lanczos - dense) <= tol * max(dense, 1.0), shape


def test_covariance_norm_dense_matches_historical_expression():
    a = RNG.normal(loc=1.0, scale=0.1, size=(25, 9))
    centered = a - a.mean(axis=0, keepdims=True)
    cov = centered.T @ centered / 25
    assert S.covariance_spectral_norm(a, method="dense") == \
        float(np.linalg.norm(cov, 2))


def test_covariance_norm_degenerate():
    assert S.covariance_spectral_norm(np.zeros((8, 5)),
                                      method="lanczos") == 0.0
    const = np.ones((6, 4)) * 3.7  # identical rows: zero covariance
    assert S.covariance_spectral_norm(const, method="lanczos") == \
        pytest.approx(0.0, abs=1e-12)
    assert S.covariance_spectral_norm(np.zeros((0, 5))) == 0.0
    with pytest.raises(ValueError, match="trials"):
        S.covariance_spectral_norm(np.zeros(5))
    with pytest.raises(ValueError, match="method"):
        S.covariance_spectral_norm(np.zeros((3, 3)), method="qr")


def test_lanczos_lambda_max_exhaustion_exact():
    # Symmetric matrix with clustered top eigenvalues: exhaustion must
    # still recover the max exactly.
    d = np.array([5.0, 5.0 - 1e-9, 4.999, -2.0, 0.0, 1.0])
    q, _ = np.linalg.qr(RNG.normal(size=(6, 6)))
    M = q @ np.diag(d) @ q.T
    lam = S.lanczos_lambda_max(lambda v: M @ v, 6)
    assert lam == pytest.approx(5.0, abs=1e-10)
    assert S.lanczos_lambda_max(lambda v: v * 0.0, 4) == 0.0


# ---------------------------------------------------------------------------
# Blocked (lockstep) Lanczos + top-k spectrum
# ---------------------------------------------------------------------------


def test_lanczos_lambda_max_batch_matches_scalar():
    B, dim = 7, 24
    mats = []
    for i in range(B):
        q, _ = np.linalg.qr(RNG.normal(size=(dim, dim)))
        d = RNG.uniform(-3.0, 3.0, size=dim) * (i + 1)
        mats.append(q @ np.diag(d) @ q.T)

    def mv(V, idx):
        # the lockstep compacts converged slices out: idx maps V's
        # rows back to original operators
        return np.stack([mats[i] @ V[j] for j, i in enumerate(idx)])

    lams = S.lanczos_lambda_max_batch(mv, dim, B)
    for i in range(B):
        ref = S.lanczos_lambda_max(lambda v: mats[i] @ v, dim)
        exact = float(np.linalg.eigvalsh(mats[i])[-1])
        assert lams[i] == pytest.approx(exact, rel=1e-9, abs=1e-9)
        assert lams[i] == pytest.approx(ref, rel=1e-9, abs=1e-9)


def test_lanczos_lambda_max_batch_degenerate_slices():
    """A zero slice, a rank-1 slice, and a full-rank slice in one
    lockstep run: per-slice breakdown/exhaustion handling must keep
    every result exact."""
    dim, B = 8, 3
    u = RNG.normal(size=dim)
    r1 = np.outer(u, u)
    q, _ = np.linalg.qr(RNG.normal(size=(dim, dim)))
    full = q @ np.diag(np.arange(1.0, dim + 1.0)) @ q.T
    mats = [np.zeros((dim, dim)), r1, full]

    def mv(V, idx):
        return np.stack([mats[i] @ V[j] for j, i in enumerate(idx)])

    lams = S.lanczos_lambda_max_batch(mv, dim, B)
    assert lams[0] == pytest.approx(0.0, abs=1e-10)
    assert lams[1] == pytest.approx(float(u @ u), rel=1e-10)
    assert lams[2] == pytest.approx(float(dim), rel=1e-10)
    assert S.lanczos_lambda_max_batch(mv, dim, 0).shape == (0,)
    assert np.all(
        S.lanczos_lambda_max_batch(lambda V, idx: V, 0, 3) == 0.0)


def test_covariance_spectral_norm_batch_blocked_vs_oracles():
    tol = 1e-8 if FLOAT64_MATVEC else 5e-3
    for B, T, n in [(1, 20, 9), (5, 12, 40), (4, 30, 30), (3, 50, 8)]:
        stack = RNG.normal(loc=1.0, scale=0.2, size=(B, T, n)) * \
            RNG.uniform(0.5, 2.0, size=(B, 1, 1))
        blocked = S.covariance_spectral_norm_batch(stack,
                                                   method="blocked")
        dense = S.covariance_spectral_norm_batch(stack, method="dense")
        per_slice = np.asarray([S.covariance_spectral_norm(s,
                                method="dense") for s in stack])
        np.testing.assert_array_equal(dense, per_slice)
        assert np.all(np.abs(blocked - dense) <=
                      tol * np.maximum(dense, 1.0)), (B, T, n)
    with pytest.raises(ValueError, match="B, trials, n"):
        S.covariance_spectral_norm_batch(np.zeros((3, 4)))
    with pytest.raises(ValueError, match="method"):
        S.covariance_spectral_norm_batch(np.zeros((2, 3, 4)),
                                         method="qr")
    assert np.all(S.covariance_spectral_norm_batch(
        np.zeros((2, 0, 4))) == 0.0)


def test_covariance_topk_matches_dense_svd():
    tol = 1e-8 if FLOAT64_MATVEC else 5e-3
    for T, n, k in [(30, 50, 5), (12, 80, 3), (40, 10, 10), (6, 64, 8)]:
        a = RNG.normal(loc=1.0, scale=0.3, size=(T, n)) * \
            RNG.uniform(0.2, 3.0, size=n)
        block = S.covariance_topk(a, k, method="block")
        centered = a - a.mean(axis=0, keepdims=True)
        cov = centered.T @ centered / T
        dense_full = np.maximum(np.linalg.eigvalsh(cov)[::-1][:k], 0.0)
        dense = S.covariance_topk(a, k, method="dense")
        np.testing.assert_allclose(dense, dense_full, atol=1e-12)
        assert block.shape == (k,)
        assert np.all(np.diff(block) <= 1e-12)  # descending
        np.testing.assert_allclose(block, dense, atol=tol,
                                   rtol=tol)
        # top-1 of the spectrum == the spectral norm path
        norm = S.covariance_spectral_norm(a, method="lanczos")
        assert abs(block[0] - norm) <= tol * max(norm, 1.0)


def test_covariance_topk_rank_deficient_and_validation():
    # rank <= trials - 1 = 3: requested k beyond rank pads exact zeros
    a = RNG.normal(size=(4, 30))
    top = S.covariance_topk(a, 6, method="block")
    assert top.shape == (6,)
    # beyond-rank values are zero up to Ritz rounding residue
    assert np.all(top[3:] <= 1e-10 * max(top[0], 1.0))
    dense = S.covariance_topk(a, 6, method="dense")
    np.testing.assert_allclose(top[:3], dense[:3], rtol=1e-8)
    with pytest.raises(ValueError, match="k must be"):
        S.covariance_topk(a, 0)
    with pytest.raises(ValueError, match="trials"):
        S.covariance_topk(np.zeros(3), 2)
    with pytest.raises(ValueError, match="method"):
        S.covariance_topk(a, 2, method="qr")
    assert np.all(S.covariance_topk(np.zeros((0, 4)), 2) == 0.0)
    assert np.all(S.covariance_topk(np.ones((5, 7)) * 2.5, 3,
                                    method="block") == 0.0)
