"""Dedup-block vs replicated-machine execution equivalence.

The replicated coded batch recomputes every block d times; the dedup
path runs each unique block once, weighted by v = A @ w. Those are the
same algebra (``sum_j w_j g_j == sum_i (A w)_i grad L_i``), so
gradients, optimizer updates, loss values and multi-step trajectories
must match to float32 tolerance for every scheme -- including padded
irregular assignments, where the replicated batch carries zero-weight
padding slots the dedup batch never materialises. Also covers the
manual ``coded_allreduce`` collective step against the GSPMD one and
the dedup sharding geometry.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.step_weights as sw
from repro.configs import get_config
from repro.core import expander_assignment
from repro.core.assignment import (Assignment, frc_assignment,
                                   uncoded_assignment)
from repro.data.pipeline import CodedBatcher, SyntheticLM
from repro.dist import coded_train
from repro.launch.mesh import make_test_mesh
from repro.models import model as M
from repro.optim import optimizers as opt_mod

KEY = jax.random.PRNGKey(0)


def _irregular_assignment() -> Assignment:
    """Machine loads {2, 1, 2, 1}: machines 1 and 3 get a padded
    (zero block_weight) slot in the replicated batch."""
    A = np.zeros((3, 4))
    A[0, 0] = A[1, 0] = 1.0
    A[0, 1] = 1.0
    A[1, 2] = A[2, 2] = 1.0
    A[2, 3] = 1.0
    return Assignment(A=A, name="irregular")


ASSIGNMENTS = {
    "expander": lambda: expander_assignment(
        4, 2, vertex_transitive=False, seed=1),
    "frc": lambda: frc_assignment(4, 2),
    "uncoded": lambda: uncoded_assignment(4),
    "irregular": _irregular_assignment,
}


def _setup(name, bs=3, S=16):
    cfg = get_config("granite-3-8b").smoke_variant()
    A = ASSIGNMENTS[name]()
    batcher = CodedBatcher(A, shuffle_seed=0)
    raw = SyntheticLM(cfg.vocab_size, S, seed=0).batch(A.n * bs, 0)
    coded = {k: jnp.asarray(v)
             for k, v in batcher.code_batch(raw).items()}
    blocks = {k: jnp.asarray(v)
              for k, v in batcher.unique_blocks(raw).items()}
    params = M.init_params(cfg, KEY)
    return cfg, A, coded, blocks, params


def _tree_allclose(a, b, rtol=2e-4, atol=2e-5):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)


@pytest.mark.parametrize("name", list(ASSIGNMENTS))
def test_dedup_gradient_matches_replicated(name):
    cfg, A, coded, blocks, params = _setup(name)
    assert blocks["tokens"].shape[0] == A.n  # no replication axis
    if name == "irregular":
        assert (np.asarray(coded["block_weight"]) == 0).any(), \
            "fixture must exercise padded slots"
    rng = np.random.default_rng(0)
    w = rng.random(A.m)
    w[A.m // 2] = 0.0                        # one straggler
    v = sw.block_weights(A, w)
    ns = coded_train.dedup_norm_scale(A)
    l_rep, g_rep = jax.value_and_grad(coded_train.coded_loss_fn)(
        params, coded, jnp.asarray(w, jnp.float32), cfg)
    l_dd, g_dd = jax.value_and_grad(coded_train.coded_loss_fn_dedup)(
        params, blocks, jnp.asarray(v, jnp.float32), cfg, ns)
    np.testing.assert_allclose(float(l_rep), float(l_dd), rtol=1e-5)
    _tree_allclose(g_rep, g_dd)


@pytest.mark.parametrize("name", ["expander", "frc", "uncoded"])
def test_dedup_trajectory_and_updates_match(name):
    """Optimizer updates and the multi-step loss trajectory must agree
    across paths under a shared straggler-weight stream."""
    cfg = get_config("granite-3-8b").smoke_variant()
    A = ASSIGNMENTS[name]()
    batcher = CodedBatcher(A, shuffle_seed=0)
    src = SyntheticLM(cfg.vocab_size, 16, seed=0)
    opt = opt_mod.get_optimizer("adamw", 1e-3)
    aw = coded_train.alpha_bar_weights(A)
    ns = coded_train.dedup_norm_scale(A)
    s_rep = coded_train.make_train_step(cfg, opt, alpha_weights=aw)
    s_dd = coded_train.make_train_step(cfg, opt, dedup=True,
                                       norm_scale=ns)
    p_rep = p_dd = M.init_params(cfg, KEY)
    st_rep, st_dd = opt.init(p_rep), opt.init(p_dd)
    rng = np.random.default_rng(1)
    for step in range(3):
        raw = src.batch(A.n * 2, step)
        w = rng.random(A.m) * (rng.random(A.m) > 0.3)
        v = sw.block_weights(A, w)
        coded = {k: jnp.asarray(x)
                 for k, x in batcher.code_batch(raw).items()}
        blocks = {k: jnp.asarray(x)
                  for k, x in batcher.unique_blocks(raw).items()}
        p_rep, st_rep, m_rep = s_rep(p_rep, st_rep, coded,
                                     jnp.asarray(w, jnp.float32))
        p_dd, st_dd, m_dd = s_dd(p_dd, st_dd, blocks,
                                 jnp.asarray(v, jnp.float32))
        np.testing.assert_allclose(float(m_rep["loss"]),
                                   float(m_dd["loss"]), rtol=1e-5)
        # on-device alpha-bar: (colsum(A)/n) . w == mean(A w)
        np.testing.assert_allclose(float(m_rep["alpha_bar"]),
                                   float(m_dd["alpha_bar"]), rtol=1e-5)
    # Adam divides by sqrt(v): near-zero second moments amplify
    # float32 reduction-order noise into lr-scale update differences
    # on isolated entries, so the trajectory check is a notch looser
    # than the single-step gradient pin above.
    _tree_allclose(p_rep, p_dd, rtol=2e-3, atol=5e-4)
    _tree_allclose(st_rep["m"], st_dd["m"], rtol=2e-3, atol=5e-4)


def test_dedup_microbatched_matches_single_shot():
    cfg, A, _, blocks, params = _setup("expander", bs=4)
    w = np.asarray([0.5, 1.5, 0.0, 1.0])
    v = jnp.asarray(sw.block_weights(A, w), jnp.float32)
    ns = coded_train.dedup_norm_scale(A)
    opt = opt_mod.sgd(1e-2)
    s1 = coded_train.make_train_step(cfg, opt, n_microbatches=1,
                                     dedup=True, norm_scale=ns)
    s4 = coded_train.make_train_step(cfg, opt, n_microbatches=4,
                                     dedup=True, norm_scale=ns)
    p1, _, m1 = s1(params, opt.init(params), blocks, v)
    p4, _, m4 = s4(params, opt.init(params), blocks, v)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5)
    _tree_allclose(p1, p4)


def test_manual_collective_step_matches_gspmd():
    cfg, A, coded, _, params = _setup("expander", bs=2)
    mesh = make_test_mesh((1, 1))
    opt = opt_mod.sgd(1e-2)
    aw = coded_train.alpha_bar_weights(A)
    s_auto = coded_train.make_train_step(cfg, opt, alpha_weights=aw)
    s_man = coded_train.make_manual_collective_train_step(
        cfg, opt, mesh, alpha_weights=aw)
    w = jnp.asarray([1.0, 0.0, 0.7, 2.0])
    with mesh:
        p1, _, m1 = s_auto(params, opt.init(params), coded, w)
        p2, _, m2 = s_man(params, opt.init(params), coded, w)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    np.testing.assert_allclose(float(m1["alpha_bar"]),
                               float(m2["alpha_bar"]), rtol=1e-6)
    _tree_allclose(p1, p2)


def test_block_shardings_divisibility_fallback():
    """On the real 8-virtual-device mesh: divisible leading dims shard
    over the worker axes, indivisible ones (FRC dedup: n < m) and
    scalars fall back to replication. Subprocess because the test
    process stays on the 1-CPU device by design (conftest)."""
    code = (
        "import os;"
        "os.environ['XLA_FLAGS']="
        "'--xla_force_host_platform_device_count=8';"
        "import jax, numpy as np;"
        "from jax.sharding import PartitionSpec as P;"
        "from repro.dist import sharding as rules;"
        "from repro.launch.mesh import make_test_mesh;"
        "mesh = make_test_mesh((4, 2));"
        "batch = {'a': np.zeros((4, 3, 5)), 'b': np.zeros((2, 3)),"
        " 's': np.zeros(())};"
        "sh = rules.block_shardings(mesh, batch);"
        "assert sh['a'].spec == P('data', None, None), sh['a'].spec;"
        "assert sh['b'].spec == P(), sh['b'].spec;"
        "assert sh['s'].spec == P(), sh['s'].spec;"
        "rep = rules.batch_shardings(mesh, {'a': np.zeros((4, 3))});"
        "assert rep['a'].spec == P('data', None), rep['a'].spec;"
        "print('OK')"
    )
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "OK" in proc.stdout
